"""L1 correctness: every Pallas building-block kernel vs its pure-jnp
oracle, with hypothesis sweeping shapes and dtypes.

This is the CORE correctness signal of the compile path: if these pass,
the HLO the artifacts are lowered from computes Eqs. (1)-(4) exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import kernels as K
from compile.kernels import ref

F32 = np.float32


def _randn(rng, *shape):
    return rng.standard_normal(shape).astype(F32)


def assert_matches_ref(got, want, dtype=jnp.float32):
    got = np.asarray(got, dtype=np.float32)
    want = np.asarray(want, dtype=np.float32)
    if dtype == jnp.bfloat16:
        np.testing.assert_allclose(got, want, rtol=0.06, atol=0.06)
    else:
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# fully connected
# ---------------------------------------------------------------------------


class TestFullyConnected:
    @given(
        b=st.integers(1, 9),
        cin=st.integers(1, 200),
        cout=st.integers(1, 150),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_matches_ref(self, b, cin, cout, seed):
        rng = np.random.default_rng(seed)
        x, k, bias = _randn(rng, b, cin), _randn(rng, cin, cout), _randn(rng, cout)
        got = K.fully_connected(jnp.array(x), jnp.array(k), jnp.array(bias))
        assert_matches_ref(got, ref.fully_connected(x, k, bias))

    def test_block_boundary_shapes(self, rng):
        # exactly at, below and above the default block sizes
        for b, cin, cout in [(8, 512, 128), (9, 513, 129), (1, 1, 1), (7, 511, 127)]:
            x, k, bias = _randn(rng, b, cin), _randn(rng, cin, cout), _randn(rng, cout)
            got = K.fully_connected(jnp.array(x), jnp.array(k), jnp.array(bias))
            assert_matches_ref(got, ref.fully_connected(x, k, bias))

    def test_bf16_within_tolerance(self, rng):
        x, k, bias = _randn(rng, 4, 64), _randn(rng, 64, 32), _randn(rng, 32)
        got = K.fully_connected(
            jnp.array(x, jnp.bfloat16),
            jnp.array(k, jnp.bfloat16),
            jnp.array(bias, jnp.bfloat16),
        )
        assert got.dtype == jnp.bfloat16
        assert_matches_ref(got, ref.fully_connected(x, k, bias), jnp.bfloat16)

    def test_ones_kernel_is_summation(self, rng):
        # paper §3.4: FC with ones kernel and Cout=1 sums the input
        x = _randn(rng, 1, 1000)
        got = K.fully_connected(
            jnp.array(x), jnp.ones((1000, 1), F32), jnp.zeros((1,), F32)
        )
        np.testing.assert_allclose(np.asarray(got)[0, 0], x.sum(), rtol=1e-3)

    def test_contraction_mismatch_raises(self, rng):
        with pytest.raises(AssertionError):
            K.fully_connected(
                jnp.zeros((2, 3)), jnp.zeros((4, 5)), jnp.zeros((5,))
            )


# ---------------------------------------------------------------------------
# pointwise convolution
# ---------------------------------------------------------------------------


class TestPointwiseConv:
    @given(
        t=st.integers(1, 3),
        cin=st.integers(1, 150),
        cout=st.integers(1, 150),
        s=st.integers(1, 160),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_matches_ref(self, t, cin, cout, s, seed):
        rng = np.random.default_rng(seed)
        x, k, b = _randn(rng, t, cin, s), _randn(rng, cin, cout), _randn(rng, cout)
        got = K.pointwise_conv(jnp.array(x), jnp.array(k), jnp.array(b))
        assert_matches_ref(got, ref.pointwise_conv(x, k, b))

    def test_matmul_carrier(self, rng):
        # §3.2: pointwise conv with channels=L computes X @ Y
        m, l, n = 17, 33, 9
        x, y = _randn(rng, m, l), _randn(rng, l, n)
        i = jnp.array(x.T.reshape(1, l, m))
        out = K.pointwise_conv(i, jnp.array(y), jnp.zeros((n,), F32))
        got = np.asarray(out)[0].T
        np.testing.assert_allclose(got, x @ y, rtol=1e-4, atol=1e-4)

    def test_identity_kernel_preserves(self, rng):
        x = _randn(rng, 2, 8, 5)
        got = K.pointwise_conv(jnp.array(x), jnp.eye(8, dtype=F32), jnp.zeros(8, F32))
        np.testing.assert_allclose(np.asarray(got), x, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# depthwise convolution
# ---------------------------------------------------------------------------


class TestDepthwiseConv:
    @given(
        t=st.integers(1, 3),
        c=st.integers(1, 300),
        w_extra=st.integers(0, 120),
        m=st.integers(1, 12),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_matches_ref(self, t, c, w_extra, m, seed):
        rng = np.random.default_rng(seed)
        w = m + w_extra
        x, k, b = _randn(rng, t, c, w), _randn(rng, c, m), _randn(rng, c)
        got = K.depthwise_conv(jnp.array(x), jnp.array(k), jnp.array(b))
        assert_matches_ref(got, ref.depthwise_conv(x, k, b))

    def test_elementwise_carrier(self, rng):
        # §3.1: depthwise with 1x1 spatial and C=H*W multiplies elementwise
        a, bmat = _randn(rng, 6, 7), _randn(rng, 6, 7)
        out = K.depthwise_conv(
            jnp.array(a.reshape(1, 42, 1)),
            jnp.array(bmat.reshape(42, 1)),
            jnp.zeros(42, F32),
        )
        np.testing.assert_allclose(
            np.asarray(out).reshape(6, 7), a * bmat, rtol=1e-5, atol=1e-6
        )

    def test_bias_carrier_is_addition(self, rng):
        # §3.3: ones kernel + bias=B adds elementwise
        a, bmat = _randn(rng, 4, 5), _randn(rng, 4, 5)
        out = K.depthwise_conv(
            jnp.array(a.reshape(1, 20, 1)),
            jnp.ones((20, 1), F32),
            jnp.array(bmat.reshape(20)),
        )
        np.testing.assert_allclose(
            np.asarray(out).reshape(4, 5), a + bmat, rtol=1e-5, atol=1e-6
        )

    @given(chunk=st.sampled_from([64, 257, 1000]), seed=st.integers(0, 2**31))
    def test_chunked_equals_unchunked(self, chunk, seed):
        rng = np.random.default_rng(seed)
        x, k, b = _randn(rng, 1, 5, 2111), _randn(rng, 5, 7), _randn(rng, 5)
        want = K.depthwise_conv(jnp.array(x), jnp.array(k), jnp.array(b))
        got = K.depthwise_conv_chunked(
            jnp.array(x), jnp.array(k), jnp.array(b), chunk_w=chunk
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)

    def test_window_longer_than_input_raises(self):
        with pytest.raises(AssertionError):
            K.depthwise_conv(jnp.zeros((1, 2, 3)), jnp.zeros((2, 5)), jnp.zeros(2))


# ---------------------------------------------------------------------------
# standard convolution
# ---------------------------------------------------------------------------


class TestStandardConv:
    @given(
        t=st.integers(1, 2),
        cin=st.integers(1, 6),
        cout=st.integers(1, 40),
        w_extra=st.integers(0, 100),
        n=st.integers(1, 16),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_matches_ref(self, t, cin, cout, w_extra, n, seed):
        rng = np.random.default_rng(seed)
        w = n + w_extra
        x = _randn(rng, t, cin, w)
        k = _randn(rng, cout, cin, n)
        b = _randn(rng, cout)
        got = K.standard_conv(jnp.array(x), jnp.array(k), jnp.array(b))
        assert_matches_ref(got, ref.standard_conv(x, k, b))

    def test_fir_carrier(self, rng):
        # §4.3: Cin=Cout=1, reversed taps = np.convolve(x, taps, 'valid')
        x = _randn(rng, 1, 1, 300)
        taps = _randn(rng, 24)
        k = jnp.array(taps[::-1].reshape(1, 1, 24).copy())
        out = K.standard_conv(jnp.array(x), k, jnp.zeros(1, F32))
        want = np.convolve(x[0, 0], taps, "valid")
        np.testing.assert_allclose(np.asarray(out)[0, 0], want, rtol=1e-4, atol=1e-4)

    def test_unfold_carrier(self, rng):
        # §4.4: identity kernel reproduces shifted copies
        j = 5
        x = _randn(rng, 1, 1, 40)
        k = jnp.array(np.eye(j, dtype=F32).reshape(j, 1, j))
        out = np.asarray(K.standard_conv(jnp.array(x), k, jnp.zeros(j, F32)))
        for co in range(j):
            np.testing.assert_array_equal(out[0, co], x[0, 0, co : co + 40 - j + 1])

    @given(chunk=st.sampled_from([100, 513]), seed=st.integers(0, 2**31))
    def test_chunked_equals_unchunked(self, chunk, seed):
        rng = np.random.default_rng(seed)
        x = _randn(rng, 1, 1, 1777)
        k = _randn(rng, 8, 1, 9)
        b = _randn(rng, 8)
        want = K.standard_conv(jnp.array(x), jnp.array(k), jnp.array(b))
        got = K.standard_conv_chunked(
            jnp.array(x), jnp.array(k), jnp.array(b), chunk_w=chunk
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# VMEM estimates (the §Perf L1 profile inputs)
# ---------------------------------------------------------------------------


class TestVmemEstimates:
    def test_default_blocks_fit_budget(self):
        # note: the package __init__ re-exports kernel *functions* under the
        # module names, so fetch the modules via importlib
        import importlib

        common = importlib.import_module("compile.kernels.common")
        dw = importlib.import_module("compile.kernels.depthwise_conv")
        fc = importlib.import_module("compile.kernels.fully_connected")
        pw = importlib.import_module("compile.kernels.pointwise_conv")
        sc = importlib.import_module("compile.kernels.standard_conv")

        assert fc.vmem_estimate() <= common.VMEM_BUDGET
        assert pw.vmem_estimate() <= common.VMEM_BUDGET
        assert dw.vmem_estimate() <= common.VMEM_BUDGET
        assert sc.vmem_estimate() <= common.VMEM_BUDGET

    def test_estimate_scales_with_blocks(self):
        import importlib

        fc = importlib.import_module("compile.kernels.fully_connected")
        assert fc.vmem_estimate(bm=16) > fc.vmem_estimate(bm=8)
