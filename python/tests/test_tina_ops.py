"""L2 correctness: every TINA op mapping vs numpy oracles, and agreement
between the TINA mapping and the direct-jnp (jaxref) comparator.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile import baselines as B
from compile import coeffs
from compile import tina_ops as T

F32 = np.float32


def _randn(rng, *shape):
    return rng.standard_normal(shape).astype(F32)


class TestArithmetic:
    @given(h=st.integers(1, 40), w=st.integers(1, 40), seed=st.integers(0, 2**31))
    def test_ewmult(self, h, w, seed):
        rng = np.random.default_rng(seed)
        a, b = _randn(rng, h, w), _randn(rng, h, w)
        np.testing.assert_allclose(T.ewmult(a, b), a * b, rtol=1e-5, atol=1e-5)

    @given(h=st.integers(1, 40), w=st.integers(1, 40), seed=st.integers(0, 2**31))
    def test_ewadd(self, h, w, seed):
        rng = np.random.default_rng(seed)
        a, b = _randn(rng, h, w), _randn(rng, h, w)
        np.testing.assert_allclose(T.ewadd(a, b), a + b, rtol=1e-5, atol=1e-5)

    @given(
        m=st.integers(1, 32),
        l=st.integers(1, 48),
        n=st.integers(1, 32),
        seed=st.integers(0, 2**31),
    )
    def test_matmul(self, m, l, n, seed):
        rng = np.random.default_rng(seed)
        x, y = _randn(rng, m, l), _randn(rng, l, n)
        np.testing.assert_allclose(T.matmul(x, y), x @ y, rtol=2e-4, atol=2e-4)

    @given(l=st.integers(1, 5000), seed=st.integers(0, 2**31))
    def test_summation(self, l, seed):
        rng = np.random.default_rng(seed)
        x = _randn(rng, l)
        got = np.asarray(T.summation(x))
        np.testing.assert_allclose(got, [x.sum()], rtol=1e-3, atol=1e-3)


class TestFourier:
    @given(
        n=st.sampled_from([4, 16, 33, 64, 100]),
        b=st.integers(1, 4),
        seed=st.integers(0, 2**31),
    )
    def test_dft_real_input(self, n, b, seed):
        rng = np.random.default_rng(seed)
        x = _randn(rng, b, n)
        re, im = T.dft(x)
        z = np.fft.fft(x, axis=-1)
        np.testing.assert_allclose(re, z.real, rtol=1e-3, atol=1e-3 * n)
        np.testing.assert_allclose(im, z.imag, rtol=1e-3, atol=1e-3 * n)

    @given(n=st.sampled_from([8, 32, 57]), seed=st.integers(0, 2**31))
    def test_idft_inverts(self, n, seed):
        rng = np.random.default_rng(seed)
        x = _randn(rng, 2, n)
        re, im = T.dft(x)
        back_re, back_im = T.idft(re, im)
        np.testing.assert_allclose(back_re, x, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(back_im, np.zeros_like(x), atol=1e-3)

    def test_tina_matches_jaxref(self):
        rng = np.random.default_rng(0)
        x = _randn(rng, 4, 64)
        tre, tim = T.dft(x)
        jre, jim = B.dft(jnp.array(x))
        np.testing.assert_allclose(tre, jre, rtol=1e-3, atol=1e-2)
        np.testing.assert_allclose(tim, jim, rtol=1e-3, atol=1e-2)

    def test_parseval(self):
        # energy preserved: sum |X|^2 = N sum |x|^2
        rng = np.random.default_rng(1)
        x = _randn(rng, 1, 128)
        re, im = T.dft(x)
        lhs = np.sum(np.asarray(re) ** 2 + np.asarray(im) ** 2)
        rhs = 128 * np.sum(x**2)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


class TestFirUnfold:
    @given(
        l=st.integers(70, 3000),
        m=st.sampled_from([4, 16, 64]),
        seed=st.integers(0, 2**31),
    )
    def test_fir_matches_convolve(self, l, m, seed):
        rng = np.random.default_rng(seed)
        x = _randn(rng, 2, l)
        taps = coeffs.fir_lowpass(m, 0.2)
        got = T.fir(x, taps)
        want = np.stack([np.convolve(r, taps, "valid") for r in x])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_fir_lowpass_attenuates(self):
        # a high-frequency tone should come out much smaller than a low one
        n = 4096
        t = np.arange(n)
        lo = np.cos(2 * np.pi * 0.01 * t).astype(F32)[None, :]
        hi = np.cos(2 * np.pi * 0.45 * t).astype(F32)[None, :]
        taps = coeffs.fir_lowpass(64, 0.1)
        out_lo = np.asarray(T.fir(lo, taps))
        out_hi = np.asarray(T.fir(hi, taps))
        assert np.abs(out_lo).mean() > 50 * np.abs(out_hi).mean()

    @given(
        l=st.integers(40, 2000),
        j=st.sampled_from([2, 8, 32]),
        seed=st.integers(0, 2**31),
    )
    def test_unfold(self, l, j, seed):
        rng = np.random.default_rng(seed)
        x = _randn(rng, 1, l)
        got = np.asarray(T.unfold(x, j))
        assert got.shape == (1, l - j + 1, j)
        want = np.stack([x[0, i : i + j] for i in range(l - j + 1)])
        np.testing.assert_array_equal(got[0], want)

    def test_unfold_paper_example(self):
        # §4.4: X=[1,2,3,4], J=2 -> [[1,2],[2,3],[3,4]]
        x = np.array([[1, 2, 3, 4]], dtype=F32)
        got = np.asarray(T.unfold(x, 2))
        np.testing.assert_array_equal(got[0], [[1, 2], [2, 3], [3, 4]])


class TestPfb:
    def _reference_fir(self, x, p, m):
        proto = coeffs.pfb_prototype(p, m)
        bank = coeffs.polyphase_decompose(proto, p)
        b, l = x.shape
        nspec = l // p
        xp = x.reshape(b, nspec, p).transpose(0, 2, 1)
        return np.stack(
            [
                np.stack([np.convolve(xp[bi, pi], bank[pi], "valid") for pi in range(p)])
                for bi in range(b)
            ]
        )

    @given(
        p=st.sampled_from([4, 8, 32]),
        m=st.sampled_from([2, 4, 8]),
        nspec=st.integers(10, 64),
        seed=st.integers(0, 2**31),
    )
    def test_pfb_fir(self, p, m, nspec, seed):
        rng = np.random.default_rng(seed)
        x = _randn(rng, 1, p * nspec)
        got = T.pfb_fir(x, p, m)
        want = self._reference_fir(x, p, m)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_pfb_full(self):
        rng = np.random.default_rng(3)
        p, m = 8, 4
        x = _randn(rng, 2, p * 40)
        re, im = T.pfb(x, p, m)
        y = self._reference_fir(x, p, m)
        z = np.fft.fft(y.transpose(0, 2, 1), axis=-1)
        np.testing.assert_allclose(re, z.real, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(im, z.imag, rtol=1e-3, atol=1e-4)

    def test_pfb_tina_matches_jaxref(self):
        rng = np.random.default_rng(4)
        p, m = 32, 8
        x = _randn(rng, 1, p * 64)
        tre, tim = T.pfb(x, p, m)
        jre, jim = B.pfb(jnp.array(x), p, m)
        np.testing.assert_allclose(tre, jre, rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(tim, jim, rtol=1e-3, atol=1e-4)

    def test_bf16_close_to_f32(self):
        rng = np.random.default_rng(5)
        p, m = 32, 8
        x = _randn(rng, 1, p * 64)
        f32 = np.asarray(T.pfb_fir(x, p, m, dtype="f32"))
        b16 = np.asarray(T.pfb_fir(x, p, m, dtype="bf16"))
        # bf16 has ~2^-8 relative precision; allow generous headroom
        np.testing.assert_allclose(b16, f32, rtol=0.12, atol=0.02)

    def test_tone_channelization(self):
        # a tone at channel k's center frequency concentrates power there
        p, m = 8, 4
        l = p * 128
        t = np.arange(l)
        x = np.cos(2 * np.pi * 3.0 * t / p).astype(F32)[None, :]
        re, im = T.pfb(x, p, m)
        power = np.asarray(re) ** 2 + np.asarray(im) ** 2
        mean_power = power.mean(axis=1)[0]  # (P,)
        peak = int(np.argmax(mean_power))
        assert peak in (3, p - 3), f"peak channel {peak}: {mean_power}"

    def test_indivisible_length_rejected(self):
        with pytest.raises(AssertionError):
            T.pfb_fir(np.zeros((1, 65), F32), 8, 4)


class TestCoeffs:
    def test_fir_lowpass_dc_gain(self):
        h = coeffs.fir_lowpass(64, 0.25)
        np.testing.assert_allclose(h.sum(), 1.0, rtol=1e-6)

    def test_prototype_symmetry(self):
        h = coeffs.pfb_prototype(16, 8)
        np.testing.assert_allclose(h, h[::-1], atol=1e-7)

    def test_polyphase_layout(self):
        h = np.arange(8, dtype=F32)
        bank = coeffs.polyphase_decompose(h, 4)
        np.testing.assert_array_equal(bank, [[0, 4], [1, 5], [2, 6], [3, 7]])

    def test_dft_matrix_unitary_up_to_n(self):
        fr, fi = coeffs.dft_matrix(16)
        f = fr + 1j * fi
        np.testing.assert_allclose(f @ f.conj().T, 16 * np.eye(16), atol=1e-3)

    def test_idft_is_inverse(self):
        fr, fi = coeffs.dft_matrix(8)
        ir, ii = coeffs.idft_matrix(8)
        f = fr + 1j * fi
        inv = ir + 1j * ii
        np.testing.assert_allclose(f @ inv, np.eye(8), atol=1e-6)

    def test_cutoff_validation(self):
        with pytest.raises(ValueError):
            coeffs.fir_lowpass(8, 0.7)


class TestStft:
    """Extension op (paper future work): STFT from three building blocks."""

    def _reference(self, x, nfft, hop):
        win = coeffs.hamming(nfft)
        b, l = x.shape
        frames = (l - nfft) // hop + 1
        return np.stack(
            [
                np.fft.fft(
                    np.stack([x[bi, i * hop : i * hop + nfft] * win for i in range(frames)]),
                    axis=-1,
                )
                for bi in range(b)
            ]
        )

    @given(
        l=st.integers(300, 3000),
        nfft=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**31),
    )
    def test_matches_reference(self, l, nfft, seed):
        rng = np.random.default_rng(seed)
        hop = nfft // 2
        x = _randn(rng, 1, l)
        re, im = T.stft(x, nfft, hop)
        want = self._reference(x, nfft, hop)
        assert re.shape == want.shape
        np.testing.assert_allclose(re, want.real, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(im, want.imag, rtol=1e-3, atol=1e-3)

    def test_tina_matches_jaxref(self):
        rng = np.random.default_rng(6)
        x = _randn(rng, 2, 2048)
        tre, tim = T.stft(x, 256, 128)
        jre, jim = B.stft(jnp.array(x), 256, 128)
        np.testing.assert_allclose(tre, jre, rtol=1e-3, atol=1e-2)
        np.testing.assert_allclose(tim, jim, rtol=1e-3, atol=1e-2)

    def test_chirp_ridge_moves(self):
        # a linear chirp's peak bin should increase over frames
        l, nfft, hop = 8192, 128, 64
        t = np.arange(l, dtype=np.float64)
        f0, f1 = 0.02, 0.35
        phase = 2 * np.pi * (f0 * t + (f1 - f0) * t**2 / (2 * l))
        x = np.cos(phase).astype(F32)[None, :]
        re, im = T.stft(x, nfft, hop)
        power = np.asarray(re) ** 2 + np.asarray(im) ** 2
        peaks = power[0, :, : nfft // 2].argmax(axis=-1)
        assert peaks[-1] > peaks[0] + 10, f"ridge did not move: {peaks[0]} -> {peaks[-1]}"
