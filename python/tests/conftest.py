"""Shared pytest fixtures and hypothesis settings for the compile path."""

import os
import sys

import numpy as np
import pytest

# make `compile` importable regardless of pytest's invocation directory
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    # offline image without hypothesis: install the in-repo shim so the
    # property sweeps still run (deterministic seeded examples, no shrinking)
    import _hypothesis_shim

    _hypothesis_shim.install()
    from hypothesis import HealthCheck, settings

# Kernel sweeps run interpret-mode Pallas; keep example counts modest so the
# suite stays fast, but always exercise shrinking on failure.
settings.register_profile(
    "tina",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("tina")


@pytest.fixture
def rng():
    return np.random.default_rng(421)
