"""Shared pytest fixtures and hypothesis settings for the compile path."""

import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# Kernel sweeps run interpret-mode Pallas; keep example counts modest so the
# suite stays fast, but always exercise shrinking on failure.
settings.register_profile(
    "tina",
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
settings.load_profile("tina")


@pytest.fixture
def rng():
    return np.random.default_rng(421)
