"""Minimal stand-in for the `hypothesis` API surface this suite uses.

The offline test image has no `hypothesis` wheel and no package index to
fetch one from.  Rather than skip the whole L1/L2 correctness suite,
`conftest.py` installs this shim into `sys.modules` when the real package
is absent: `@given` becomes a deterministic sweep of seeded random
examples drawn from the tiny strategy objects below.

Only the API the tests use is implemented: `given`, `settings`
(`register_profile` / `load_profile` with `max_examples`), `HealthCheck`,
`strategies.integers`, `strategies.sampled_from`.  With the real
hypothesis installed the shim is never imported, so CI environments with
an index get genuine shrinking back automatically.
"""

import functools
import inspect
import os
import random
import sys
import types
import zlib


class HealthCheck:
    too_slow = "too_slow"


class settings:  # noqa: N801 - mirrors hypothesis' public name
    _profiles = {}
    _current = {"max_examples": 25}

    def __init__(self, **kwargs):
        pass

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = {"max_examples": kwargs.get("max_examples", 25)}

    @classmethod
    def load_profile(cls, name):
        cls._current = dict(cls._profiles.get(name, cls._current))


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(seq):
    items = list(seq)
    return _Strategy(lambda rng: items[rng.randrange(len(items))])


def _max_examples():
    env = os.environ.get("TINA_HYPOTHESIS_MAX_EXAMPLES")
    if env is not None:
        return max(1, int(env))
    return settings._current.get("max_examples", 25)


def given(**strategies_kw):
    def decorator(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # stable per-test seed (hash() is process-randomized; crc32 is not)
            seed_base = zlib.crc32(fn.__qualname__.encode())
            for case in range(_max_examples()):
                rng = random.Random(seed_base + case)
                drawn = {k: s.example_from(rng) for k, s in strategies_kw.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__qualname__} failed at case {case} with {drawn}: {e}"
                    ) from e

        # pytest introspects the wrapper's signature to resolve fixtures;
        # hide the strategy-provided parameters (and the functools
        # `__wrapped__` pointer it would follow to the original).
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        sig = inspect.signature(fn)
        remaining = [
            p for name, p in sig.parameters.items() if name not in strategies_kw
        ]
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper

    return decorator


def install():
    """Register the shim as `hypothesis` / `hypothesis.strategies`."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = HealthCheck
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
