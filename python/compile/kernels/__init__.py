"""L1: the four TINA building blocks (paper §2) as Pallas kernels.

Every kernel is validated against the pure-jnp oracles in :mod:`ref` by the
pytest suite, and lowered with ``interpret=True`` so the resulting HLO runs
on the CPU PJRT backend used by the rust runtime.
"""

from .depthwise_conv import depthwise_conv, depthwise_conv_chunked
from .fully_connected import fully_connected
from .pointwise_conv import pointwise_conv
from .standard_conv import standard_conv, standard_conv_chunked
from . import common, ref

__all__ = [
    "depthwise_conv",
    "depthwise_conv_chunked",
    "fully_connected",
    "pointwise_conv",
    "standard_conv",
    "standard_conv_chunked",
    "common",
    "ref",
]
