"""TINA building block: fully connected layer (Eq. 4) as a Pallas kernel.

O = I @ K + b with I: (B, Cin), K: (Cin, Cout), b: (Cout,).

TPU mapping: a classic three-axis tiled matmul.  The grid is
(B/bm, Cout/bn, Cin/bk); each step stages an (bm, bk) input tile and a
(bk, bn) kernel tile into VMEM and feeds an MXU-shaped dot.  The output
block index is independent of the reduction axis, so the output tile stays
resident across the k-loop and accumulates in place (the standard Pallas
revisiting pattern) — no HBM round-trips inside the reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _fc_kernel(x_ref, k_ref, b_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step of the tiled matmul with bias."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], k_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(k_step == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...][None, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fully_connected(x, k, b, *, bm=8, bn=128, bk=512, interpret=True):
    """Fully connected layer O = x @ k + b via a tiled Pallas matmul.

    x: (B, Cin), k: (Cin, Cout), b: (Cout,) -> (B, Cout)

    Block sizes default to MXU-friendly shapes: bm rides the sublane axis
    (8), bn the lane axis (128), bk the reduction staged through VMEM.
    """
    bsz, cin = x.shape
    cin_k, cout = k.shape
    assert cin == cin_k, f"contraction mismatch: {cin} vs {cin_k}"
    assert b.shape == (cout,), f"bias shape {b.shape} != ({cout},)"

    bm = common.pick_block(bsz, bm)
    bn = common.pick_block(cout, bn)
    bk = common.pick_block(cin, bk)

    bp = common.round_up(bsz, bm)
    np_ = common.round_up(cout, bn)
    kp = common.round_up(cin, bk)

    x = common.pad_axis(common.pad_axis(x, 0, bp), 1, kp)
    k = common.pad_axis(common.pad_axis(k, 0, kp), 1, np_)
    b = common.pad_axis(b, 0, np_)

    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_fc_kernel, nk=nk),
        grid=(bp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), x.dtype),
        interpret=interpret,
    )(x, k, b)
    return out[:bsz, :cout]


def vmem_estimate(bm=8, bn=128, bk=512, dtype=jnp.float32) -> int:
    """VMEM working set of one grid step (input + kernel + output tiles)."""
    return common.vmem_bytes(
        ((bm, bk), dtype), ((bk, bn), dtype), ((bm, bn), dtype), ((bn,), dtype)
    )
