"""Pure-jnp oracles for the four TINA building blocks.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float tolerance (pytest enforces this, with
hypothesis sweeping shapes and dtypes).  They intentionally use the most
direct jnp formulation of Eqs. (1)-(4) of the paper, with no tiling.
"""

from __future__ import annotations

import jax.numpy as jnp


def fully_connected(x, k, b):
    """Eq. (4): O(c_out) = b(c_out) + sum_cin I(c_in) K(c_in, c_out).

    x: (B, Cin), k: (Cin, Cout), b: (Cout,) -> (B, Cout)
    """
    return jnp.dot(x, k, preferred_element_type=jnp.float32).astype(x.dtype) + b


def pointwise_conv(x, k, b):
    """Eq. (3): 1x1 convolution mixing channels.

    x: (T, Cin, S), k: (Cin, Cout), b: (Cout,) -> (T, Cout, S)
    """
    # O[t, co, s] = b[co] + sum_ci x[t, ci, s] * k[ci, co]
    out = jnp.einsum("tcs,cn->tns", x, k, preferred_element_type=jnp.float32)
    return out.astype(x.dtype) + b[None, :, None].astype(x.dtype)


def depthwise_conv(x, k, b):
    """Eq. (2): per-channel 1-D valid convolution (correlation form).

    x: (T, C, W), k: (C, M), b: (C,) -> (T, C, W - M + 1)
    O[t, c, w] = b[c] + sum_m x[t, c, w + m] * k[c, m]
    """
    t, c, w = x.shape
    _, m = k.shape
    wout = w - m + 1
    acc = jnp.zeros((t, c, wout), dtype=jnp.float32)
    for i in range(m):
        acc = acc + x[:, :, i : i + wout].astype(jnp.float32) * k[:, i][
            None, :, None
        ].astype(jnp.float32)
    return acc.astype(x.dtype) + b[None, :, None].astype(x.dtype)


def standard_conv(x, k, b):
    """Eq. (1): 1-D valid convolution with channels (correlation form).

    x: (T, Cin, W), k: (Cout, Cin, N), b: (Cout,) -> (T, Cout, W - N + 1)
    O[t, co, w] = b[co] + sum_ci sum_n x[t, ci, w + n] * k[co, ci, n]
    """
    t, cin, w = x.shape
    cout, _, n = k.shape
    wout = w - n + 1
    acc = jnp.zeros((t, cout, wout), dtype=jnp.float32)
    for i in range(n):
        # (T, Cin, Wout) x (Cout, Cin) -> (T, Cout, Wout)
        acc = acc + jnp.einsum(
            "tcw,oc->tow",
            x[:, :, i : i + wout],
            k[:, :, i],
            preferred_element_type=jnp.float32,
        )
    return acc.astype(x.dtype) + b[None, :, None].astype(x.dtype)
