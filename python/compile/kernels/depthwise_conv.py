"""TINA building block: depthwise 1-D convolution (Eq. 2) as a Pallas kernel.

O[t, c, w] = b[c] + sum_m I[t, c, w + m] * K[c, m]

Carries TINA's elementwise multiply (§3.1, M=1), elementwise add (§3.3,
ones-kernel + bias-as-operand) and the PFB's polyphase FIR bank (§5.2,
channels = branches, M = taps-per-branch).

TPU mapping: purely elementwise-and-shift work, so it targets the VPU, not
the MXU.  Channels are blocked along the sublane axis; each grid step holds
a (bc, W) slab of the input in VMEM and performs the M tap-shifts as
unrolled vector FMAs over lane-contiguous slices.  The tap loop is a python
loop — taps are static — so there is no grid-axis revisiting at all; one
pass over HBM per slab.  Large W is chunked by the caller (see
``depthwise_conv_chunked``) to bound the slab footprint.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _dw_kernel(x_ref, k_ref, b_ref, o_ref, *, m: int, wout: int):
    x = x_ref[0]  # (bc, W)
    k = k_ref[...]  # (bc, m)
    # f64 accumulation: each f32 x f32 tap product is exact in f64, so the
    # result is independent of the FMA/vectorization choices LLVM makes per
    # input shape — chunked and unchunked schedules agree bit for bit
    # (``depthwise_conv_chunked``'s contract; see kernels/common.py).
    acc = jnp.zeros((x.shape[0], wout), dtype=jnp.float64)
    for i in range(m):  # static tap loop -> unrolled shift-FMA
        acc = acc + x[:, i : i + wout].astype(jnp.float64) * k[:, i : i + 1].astype(
            jnp.float64
        )
    o_ref[0] = acc.astype(o_ref.dtype) + b_ref[...][:, None].astype(o_ref.dtype)


def depthwise_conv(x, k, b, *, bc=256, interpret=True):
    """Depthwise valid 1-D convolution (correlation form) with bias.

    x: (T, C, W), k: (C, M), b: (C,) -> (T, C, W - M + 1)
    """
    with common.x64_scope():
        return _depthwise_conv_jit(x, k, b, bc=bc, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def _depthwise_conv_jit(x, k, b, *, bc, interpret):
    t, c, w = x.shape
    ck, m = k.shape
    assert c == ck, f"channel mismatch: {c} vs {ck}"
    assert b.shape == (c,)
    assert w >= m, f"window {m} longer than input {w}"
    wout = w - m + 1

    bc = common.pick_block(c, bc)
    cp = common.round_up(c, bc)
    x = common.pad_axis(x, 1, cp)
    k = common.pad_axis(k, 0, cp)
    b = common.pad_axis(b, 0, cp)

    out = pl.pallas_call(
        functools.partial(_dw_kernel, m=m, wout=wout),
        grid=(t, cp // bc),
        in_specs=[
            pl.BlockSpec((1, bc, w), lambda ti, ci: (ti, ci, 0)),
            pl.BlockSpec((bc, m), lambda ti, ci: (ci, 0)),
            pl.BlockSpec((bc,), lambda ti, ci: (ci,)),
        ],
        out_specs=pl.BlockSpec((1, bc, wout), lambda ti, ci: (ti, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((t, cp, wout), x.dtype),
        interpret=interpret,
    )(x, k, b)
    return out[:, :c, :]


def depthwise_conv_chunked(x, k, b, *, bc=256, chunk_w=8192, interpret=True):
    """Depthwise conv with the W axis split into overlapping VMEM-sized chunks.

    Expresses the HBM->VMEM streaming schedule at the graph level: each chunk
    of ``chunk_w`` output samples re-reads the M-1 sample halo, exactly the
    overlap a TPU pipeline would prefetch.  Numerics are identical to
    ``depthwise_conv``.
    """
    t, c, w = x.shape
    _, m = k.shape
    wout = w - m + 1
    if wout <= chunk_w:
        return depthwise_conv(x, k, b, bc=bc, interpret=interpret)
    pieces = []
    for start in range(0, wout, chunk_w):
        stop = min(start + chunk_w, wout)
        xs = x[:, :, start : stop + m - 1]
        pieces.append(depthwise_conv(xs, k, b, bc=bc, interpret=interpret))
    return jnp.concatenate(pieces, axis=2)


def vmem_estimate(bc=32, w=8192, m=8, dtype=jnp.float32) -> int:
    """Defaults model the PFB bank config (bc = P = 32 channels, one
    chunk_w slab); the elementwise carriers use (bc=4096, w=1) which is
    far smaller."""
    return common.vmem_bytes(
        ((1, bc, w), dtype), ((bc, m), dtype), ((1, bc, w - m + 1), dtype), ((bc,), dtype)
    )
