"""TINA building block: pointwise (1x1) convolution (Eq. 3) as a Pallas kernel.

O[t, co, s] = b[co] + sum_ci I[t, ci, s] * K[ci, co]

This is the channel-mixing matmul that carries TINA's matrix-matrix multiply
(§3.2) and DFT/IDFT (§4.1/§4.2).  TPU mapping: for each (t, spatial-tile,
cout-tile) the kernel stages a (bk, bs) input slab and a (bk, bn) kernel tile
in VMEM and contracts over channels on the MXU; the reduction axis is the
innermost grid axis so the (bn, bs) output tile is revisited and accumulated
in place.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _pw_kernel(x_ref, k_ref, b_ref, o_ref, *, nk: int):
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # x block: (1, bk, bs); k block: (bk, bn) -> contribution (1, bn, bs)
    x = x_ref[0]  # (bk, bs)
    kk = k_ref[...]  # (bk, bn)
    o_ref[0] += jnp.dot(
        kk.T, x, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    @pl.when(k_step == nk - 1)
    def _bias():
        o_ref[0] += b_ref[...][:, None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "bn", "bk", "interpret"))
def pointwise_conv(x, k, b, *, bs=128, bn=128, bk=128, interpret=True):
    """Pointwise convolution O = K^T applied across channels, plus bias.

    x: (T, Cin, S), k: (Cin, Cout), b: (Cout,) -> (T, Cout, S)
    """
    t, cin, s = x.shape
    cin_k, cout = k.shape
    assert cin == cin_k, f"channel mismatch: {cin} vs {cin_k}"
    assert b.shape == (cout,)

    bs = common.pick_block(s, bs)
    bn = common.pick_block(cout, bn)
    bk = common.pick_block(cin, bk)

    sp = common.round_up(s, bs)
    np_ = common.round_up(cout, bn)
    kp = common.round_up(cin, bk)

    x = common.pad_axis(common.pad_axis(x, 1, kp), 2, sp)
    k = common.pad_axis(common.pad_axis(k, 0, kp), 1, np_)
    b = common.pad_axis(b, 0, np_)

    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_pw_kernel, nk=nk),
        grid=(t, sp // bs, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bk, bs), lambda ti, si, ni, ki: (ti, ki, si)),
            pl.BlockSpec((bk, bn), lambda ti, si, ni, ki: (ki, ni)),
            pl.BlockSpec((bn,), lambda ti, si, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((1, bn, bs), lambda ti, si, ni, ki: (ti, ni, si)),
        out_shape=jax.ShapeDtypeStruct((t, np_, sp), x.dtype),
        interpret=interpret,
    )(x, k, b)
    return out[:, :cout, :s]


def vmem_estimate(bs=128, bn=128, bk=128, dtype=jnp.float32) -> int:
    return common.vmem_bytes(
        ((1, bk, bs), dtype), ((bk, bn), dtype), ((1, bn, bs), dtype), ((bn,), dtype)
    )
