"""Shared helpers for the TINA Pallas building-block kernels.

All kernels in this package are written for the TPU execution model —
blocks tiled for VMEM residency, matmul tiles shaped for the MXU — but are
lowered with ``interpret=True`` so the emitted HLO is plain XLA ops that the
CPU PJRT plugin (and the rust runtime on top of it) can execute.  See
DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp

# Guards the process-global jax_enable_x64 flag: reentrant so nested scopes
# on one thread work, and held for the whole scope so overlapping scopes on
# other threads cannot restore the flag mid-trace.
_X64_LOCK = threading.RLock()
_X64_DEPTH = 0


@contextlib.contextmanager
def x64_scope():
    """Temporarily enable jax x64 for kernels that accumulate in float64.

    The depthwise kernel sums taps in f64 so chunked and unchunked
    schedules are bit-identical (an f32 x f32 product is exact in f64, so
    the result is immune to shape-dependent FMA contraction); without the
    flag jax silently narrows float64 to float32.  Scoped save/restore
    rather than a global `jax.config.update` at import, so importing this
    package does not change default dtypes for unrelated code; the lock +
    depth counter serialize scopes so a concurrent caller cannot flip the
    flag back mid-call.  (`jax.experimental.enable_x64` leaks the flag in
    this jax version.)
    """
    global _X64_DEPTH
    with _X64_LOCK:
        old = jax.config.jax_enable_x64
        if _X64_DEPTH == 0 and not old:
            jax.config.update("jax_enable_x64", True)
        _X64_DEPTH += 1
        try:
            yield
        finally:
            _X64_DEPTH -= 1
            if _X64_DEPTH == 0 and not old:
                jax.config.update("jax_enable_x64", False)

# MXU systolic array edge / VPU lane count on current TPUs.  Matmul block
# sizes are chosen as multiples of these so the same BlockSpecs would feed
# full tiles on real hardware.
MXU_EDGE = 128
VPU_LANES = 128
VPU_SUBLANES = 8

# Soft VMEM budget per kernel invocation (bytes).  Real cores have ~16 MiB;
# we keep the working set well under half of it to leave room for
# double-buffered prefetch of the next block.
VMEM_BUDGET = 4 * 1024 * 1024


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, m: int) -> int:
    return ceil_div(x, m) * m


def pad_axis(x, axis: int, target: int, value=0.0):
    """Zero-pad ``x`` along ``axis`` up to length ``target`` (no-op if equal)."""
    cur = x.shape[axis]
    if cur == target:
        return x
    if cur > target:
        raise ValueError(f"pad_axis: axis {axis} already {cur} > {target}")
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - cur)
    return jnp.pad(x, widths, constant_values=value)


def pick_block(dim: int, preferred: int, multiple: int = 1) -> int:
    """Choose a block size for a dimension of extent ``dim``.

    Returns ``preferred`` when the dimension is large enough, otherwise the
    dimension itself rounded up to ``multiple`` (the wrapper pads the array
    to that size).  The returned block always divides the padded extent.
    """
    if dim >= preferred:
        return preferred
    return round_up(max(dim, 1), multiple)


def compute_dtype(dtype) -> jnp.dtype:
    """Map a requested storage dtype to the kernel compute dtype."""
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.bfloat16):
        return jnp.dtype(jnp.bfloat16)
    return jnp.dtype(jnp.float32)


def vmem_bytes(*block_shapes_dtypes) -> int:
    """Estimate the VMEM working set of a kernel invocation.

    Each argument is ``(shape_tuple, dtype)``.  Used by tests and by the
    §Perf estimate table generator.
    """
    total = 0
    for shape, dtype in block_shapes_dtypes:
        n = 1
        for s in shape:
            n *= int(s)
        total += n * jnp.dtype(dtype).itemsize
    return total
