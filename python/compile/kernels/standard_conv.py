"""TINA building block: standard 1-D convolution (Eq. 1) as a Pallas kernel.

O[t, co, w] = b[co] + sum_ci sum_n I[t, ci, w + n] * K[co, ci, n]

Carries TINA's FIR filter (§4.3, Cin = Cout = 1) and the unfolding algorithm
(§4.4, Cin = 1, K = identity, Cout = window).

TPU mapping: the tap loop is static and unrolled; each tap contributes a
(bco, Cin) x (Cin, W') MXU contraction over a VMEM-resident input slab, so
the "data independent loop iterations" the paper exploits on CUDA become
shift-indexed systolic matmuls here.  Cout is blocked on the grid; the full
input slab (all Cin, a W-chunk) is staged once per grid step and reused by
every tap — one HBM pass per slab instead of one per tap.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _sc_kernel(x_ref, k_ref, b_ref, o_ref, *, n: int, wout: int):
    x = x_ref[0]  # (Cin, W)
    k = k_ref[...]  # (bco, Cin, n)
    bco = k.shape[0]
    acc = jnp.zeros((bco, wout), dtype=jnp.float32)
    for i in range(n):  # static tap loop -> unrolled shifted matmuls
        acc = acc + jnp.dot(
            k[:, :, i], x[:, i : i + wout], preferred_element_type=jnp.float32
        )
    o_ref[0] = acc.astype(o_ref.dtype) + b_ref[...][:, None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bco", "interpret"))
def standard_conv(x, k, b, *, bco=128, interpret=True):
    """Standard valid 1-D convolution (correlation form) with bias.

    x: (T, Cin, W), k: (Cout, Cin, N), b: (Cout,) -> (T, Cout, W - N + 1)
    """
    t, cin, w = x.shape
    cout, cin_k, n = k.shape
    assert cin == cin_k, f"channel mismatch: {cin} vs {cin_k}"
    assert b.shape == (cout,)
    assert w >= n, f"window {n} longer than input {w}"
    wout = w - n + 1

    bco = common.pick_block(cout, bco)
    cop = common.round_up(cout, bco)
    k = common.pad_axis(k, 0, cop)
    b = common.pad_axis(b, 0, cop)

    out = pl.pallas_call(
        functools.partial(_sc_kernel, n=n, wout=wout),
        grid=(t, cop // bco),
        in_specs=[
            pl.BlockSpec((1, cin, w), lambda ti, ci: (ti, 0, 0)),
            pl.BlockSpec((bco, cin, n), lambda ti, ci: (ci, 0, 0)),
            pl.BlockSpec((bco,), lambda ti, ci: (ci,)),
        ],
        out_specs=pl.BlockSpec((1, bco, wout), lambda ti, ci: (ti, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((t, cop, wout), x.dtype),
        interpret=interpret,
    )(x, k, b)
    return out[:, :cout, :]


def standard_conv_chunked(x, k, b, *, bco=128, chunk_w=8192, interpret=True):
    """Standard conv with the W axis split into overlapping VMEM-sized chunks.

    Same graph-level HBM->VMEM streaming schedule as
    ``depthwise_conv_chunked``; each chunk re-reads an (N-1)-sample halo.
    """
    t, cin, w = x.shape
    cout, _, n = k.shape
    wout = w - n + 1
    if wout <= chunk_w:
        return standard_conv(x, k, b, bco=bco, interpret=interpret)
    pieces = []
    for start in range(0, wout, chunk_w):
        stop = min(start + chunk_w, wout)
        xs = x[:, :, start : stop + n - 1]
        pieces.append(standard_conv(xs, k, b, bco=bco, interpret=interpret))
    return jnp.concatenate(pieces, axis=2)


def vmem_estimate(bco=32, cin=1, w=8192, n=64, dtype=jnp.float32) -> int:
    """Defaults model the unfold carrier (Cout = J = 32 over one chunk_w
    slab); the FIR carrier (Cout = 1) is far smaller."""
    return common.vmem_bytes(
        ((1, cin, w), dtype),
        ((bco, cin, n), dtype),
        ((1, bco, w - n + 1), dtype),
        ((bco,), dtype),
    )
