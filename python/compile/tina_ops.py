"""L2: the paper's §3/§4 function -> NN-layer mappings.

Every public function here implements one row of Table 1 by composing the
four L1 Pallas building blocks — never by calling a direct jnp equivalent
(those live in :mod:`baselines` as the "JAX" comparator).  The mapping
mirrors the paper exactly:

=====================  ======================  =============
Function               Building block          Paper section
=====================  ======================  =============
ewmult                 depthwise conv (M=1)    §3.1
matmul                 pointwise conv          §3.2
ewadd                  depthwise conv          §3.3
summation              fully connected         §3.4
dft / idft             pointwise conv (DFM)    §4.1 / §4.2
fir                    standard conv           §4.3
unfold                 standard conv (I)       §4.4
pfb_fir / pfb          depthwise bank (+DFT)   §5.2
=====================  ======================  =============

All functions take/return float32 at the interface; ``dtype="bf16"``
switches the internal compute to bfloat16 (the "TINA 16 bit" variant of the
paper, re-targeted from fp16 tensor cores to the MXU-native narrow type).

Complex values are carried as (re, im) float32 pairs throughout — see
DESIGN.md §6.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import coeffs
from . import kernels as K

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


def _cast_in(dtype: str, *xs):
    d = _DTYPES[dtype]
    out = tuple(jnp.asarray(x).astype(d) for x in xs)
    return out if len(out) > 1 else out[0]


def _cast_out(*xs):
    out = tuple(x.astype(jnp.float32) for x in xs)
    return out if len(out) > 1 else out[0]


# ---------------------------------------------------------------------------
# §3 arithmetic functions
# ---------------------------------------------------------------------------


def ewmult(a, b, *, dtype: str = "f32", bc: int = 4096):
    """§3.1 elementwise matrix multiply via depthwise conv.

    Both H x W operands are flattened along the channel axis (C = H*W,
    spatial extent 1x1); operand ``b`` becomes the per-channel kernel with
    window M = 1 and zero bias — Eq. (6).
    """
    a, b = _cast_in(dtype, a, b)
    h, w = a.shape
    c = h * w
    x = a.reshape(1, c, 1)  # (T=1, C, W=1)
    k = b.reshape(c, 1)  # (C, M=1)
    bias = jnp.zeros((c,), a.dtype)
    out = K.depthwise_conv(x, k, bias, bc=bc)
    return _cast_out(out.reshape(h, w))


def ewadd(a, b, *, dtype: str = "f32", bc: int = 4096):
    """§3.3 elementwise matrix add: ones-kernel depthwise conv with operand
    ``b`` injected through the bias port — Eq. (10)."""
    a, b = _cast_in(dtype, a, b)
    h, w = a.shape
    c = h * w
    x = a.reshape(1, c, 1)
    k = jnp.ones((c, 1), a.dtype)
    bias = b.reshape(c)
    out = K.depthwise_conv(x, k, bias, bc=bc)
    return _cast_out(out.reshape(h, w))


def matmul(x, y, *, dtype: str = "f32"):
    """§3.2 matrix-matrix multiply via pointwise conv.

    Each row of X (M, L) is a 1x1 "pixel" with channels = L (the
    contraction axis); Y (L, N) is the 1x1 kernel mixing L input channels
    into N output channels — Eq. (9).  Rows ride the batch dimension so the
    output (M, N, 1) is already row-major (no trailing transpose, which the
    PJRT entry ABI would otherwise lower to a column-major output buffer).
    """
    x, y = _cast_in(dtype, x, y)
    m, l = x.shape
    l2, n = y.shape
    assert l == l2
    i = x.T.reshape(1, l, m)  # (T=1, Cin=L, S=M)
    bias = jnp.zeros((n,), x.dtype)
    out = K.pointwise_conv(i, y, bias)  # (1, N, M)
    return _cast_out(out[0].T)  # (M, N)


def summation(x, *, dtype: str = "f32", bk: int = 4096):
    """§3.4 summation via a fully connected layer with a ones kernel,
    one output channel and zero bias — Eq. (11).  Returns shape (1,)."""
    x = _cast_in(dtype, x)
    (l,) = x.shape
    k = jnp.ones((l, 1), x.dtype)
    bias = jnp.zeros((1,), x.dtype)
    out = K.fully_connected(x.reshape(1, l), k, bias, bk=bk)
    return _cast_out(out.reshape(1))


# ---------------------------------------------------------------------------
# §4 signal processing functions
# ---------------------------------------------------------------------------


def _real_pointwise(x, k):
    """(B, L) x (L, N) through one pointwise convolution, batch on S.

    Batch rows ride the conv's spatial axis (channels = contraction axis),
    so one grid step feeds the MXU a full (bk, B) slab instead of B
    single-row steps — 40x faster under interpret-mode lowering
    (EXPERIMENTS.md §Perf L2).  The trailing transpose is safe because
    aot.py forces row-major entry layouts and prints full constants.
    """
    b, l = x.shape
    bias = jnp.zeros((k.shape[1],), x.dtype)
    out = K.pointwise_conv(x.T.reshape(1, l, b), k, bias)  # (1, N, B)
    return out[0].T  # (B, N)


def _complex_pointwise(re, im, k_re, k_im, dtype: str):
    """(re + j im) @ (k_re + j k_im) through four pointwise convolutions.

    Inputs re/im: (B, L); kernels: (L, N).  Returns (B, N) re/im.
    """
    rr = _real_pointwise(re, k_re)
    ri = _real_pointwise(re, k_im)
    ir = _real_pointwise(im, k_re)
    ii = _real_pointwise(im, k_im)
    return rr - ii, ri + ir


def dft(x_re, x_im=None, *, dtype: str = "f32"):
    """§4.1 DFT: pointwise conv whose kernel is the Discrete Fourier Matrix.

    x_re/x_im: (B, N) -> (re, im) each (B, N).  A None imaginary part means
    a real input signal (the common case in the paper's benchmarks) and
    skips the imaginary-branch convolutions entirely.
    """
    n = x_re.shape[1]
    f_re, f_im = coeffs.dft_matrix(n)
    if x_im is None:
        x_re, f_re, f_im = _cast_in(dtype, x_re, f_re, f_im)
        out_re = _real_pointwise(x_re, f_re)
        out_im = _real_pointwise(x_re, f_im)
        return _cast_out(out_re, out_im)
    x_re, x_im, f_re, f_im = _cast_in(dtype, x_re, x_im, f_re, f_im)
    out_re, out_im = _complex_pointwise(x_re, x_im, f_re, f_im, dtype)
    return _cast_out(out_re, out_im)


def idft(x_re, x_im, *, dtype: str = "f32"):
    """§4.2 IDFT: pointwise conv with the inverse DFM as kernel."""
    n = x_re.shape[1]
    f_re, f_im = coeffs.idft_matrix(n)
    x_re, x_im, f_re, f_im = _cast_in(dtype, x_re, x_im, f_re, f_im)
    out_re, out_im = _complex_pointwise(x_re, x_im, f_re, f_im, dtype)
    return _cast_out(out_re, out_im)


def fir(x, taps, *, dtype: str = "f32", chunk_w: int = 8192):
    """§4.3 FIR filter via standard conv (Cin = Cout = 1).

    x: (B, L), taps a(k): (M,) -> (B, L - M + 1), valid convolution
    y(i) = sum_k a(k) x(i - k).  Eq. (16) is a correlation, so the kernel
    holds the taps reversed; numerics match np.convolve(x, a, 'valid').
    """
    x, taps = _cast_in(dtype, x, taps)
    b, l = x.shape
    (m,) = taps.shape
    k = taps[::-1].reshape(1, 1, m)  # (Cout=1, Cin=1, N=M)
    bias = jnp.zeros((1,), x.dtype)
    out = K.standard_conv_chunked(x.reshape(b, 1, l), k, bias, chunk_w=chunk_w)
    return _cast_out(out.reshape(b, l - m + 1))


def unfold(x, window: int, *, dtype: str = "f32", chunk_w: int = 8192):
    """§4.4 unfolding via standard conv with an identity kernel.

    x: (B, L) -> (B, L - J + 1, J) with Y[i, j] = X[i + j] — Eq. (19).
    """
    x = _cast_in(dtype, x)
    b, l = x.shape
    j = window
    k = jnp.eye(j, dtype=x.dtype).reshape(j, 1, j)  # (Cout=J, Cin=1, N=J)
    bias = jnp.zeros((j,), x.dtype)
    out = K.standard_conv_chunked(x.reshape(b, 1, l), k, bias, chunk_w=chunk_w)
    return _cast_out(jnp.transpose(out, (0, 2, 1)))  # (B, Wout, J)


def stft(x, nfft: int, hop: int, *, dtype: str = "f32", chunk_w: int = 8192):
    """Short-time Fourier transform — an *extension op* in the spirit of the
    paper's future work ("mapping more non-NN operations into TINA layers"),
    built entirely from Table-1 building blocks:

      1. framing   = unfolding via standard conv with an identity kernel
                     (§4.4), strided by `hop` (the stride parameter of §2.1);
      2. windowing = elementwise multiply with a Hamming window via
                     depthwise conv (§3.1);
      3. DFT       = pointwise conv with the DFM kernel (§4.1).

    x: (B, L) -> (re, im) each (B, F, nfft) with F = (L - nfft)//hop + 1.
    """
    x = _cast_in(dtype, x)
    b, l = x.shape
    frames = (l - nfft) // hop + 1
    assert frames >= 1, f"signal {l} shorter than one {nfft} frame"

    # 1. framing: unfold (stride 1) then stride the frame axis by `hop`
    k = jnp.eye(nfft, dtype=x.dtype).reshape(nfft, 1, nfft)
    bias0 = jnp.zeros((nfft,), x.dtype)
    unfolded = K.standard_conv_chunked(
        x.reshape(b, 1, l), k, bias0, chunk_w=chunk_w
    )  # (B, nfft, L - nfft + 1)
    framed = unfolded[:, :, ::hop][:, :, :frames]  # (B, nfft, F)
    framed = jnp.transpose(framed, (0, 2, 1)).reshape(b * frames, nfft)

    # 2. windowing: depthwise conv with channels = sample-in-frame (M = 1),
    #    frames on T — the per-channel kernel *is* the window, broadcast
    #    across frames exactly like §3.1's elementwise multiply
    win = _cast_in(dtype, coeffs.hamming(nfft).astype(np.float32))
    xw = K.depthwise_conv(
        framed.reshape(b * frames, nfft, 1),
        win.reshape(nfft, 1),
        jnp.zeros((nfft,), x.dtype),
        bc=min(nfft, 4096),
    ).reshape(b * frames, nfft)

    # 3. DFT across the frame samples: pointwise conv with the DFM
    f_re, f_im = _cast_in(dtype, *coeffs.dft_matrix(nfft))
    out_re = _real_pointwise(xw, f_re).reshape(b, frames, nfft)
    out_im = _real_pointwise(xw, f_im).reshape(b, frames, nfft)
    return _cast_out(out_re, out_im)


# ---------------------------------------------------------------------------
# §5.2 polyphase filter bank use case
# ---------------------------------------------------------------------------


def pfb_fir(x, branches: int, taps_per_branch: int, *, dtype: str = "f32",
            prototype=None):
    """§5.2 Eq. (20): the polyphase FIR bank (the paper's "subfiltered
    signals", Fig. 3 left column) via one depthwise convolution.

    x: (B, L) with L divisible by P.  The signal is decomposed into P
    branches x_p(n') = x(n' P + p), which become the channels of a
    depthwise conv whose per-channel kernels are the (time-reversed)
    polyphase taps h_p.  Returns (B, P, L/P - M + 1).
    """
    p, m = branches, taps_per_branch
    if prototype is None:
        prototype = coeffs.pfb_prototype(p, m)
    bank = coeffs.polyphase_decompose(np.asarray(prototype), p)  # (P, M)
    x, bank = _cast_in(dtype, x, bank)
    b, l = x.shape
    assert l % p == 0, f"signal length {l} not divisible by branches {p}"
    nspec = l // p
    # polyphase decomposition: (B, Nspec, P) -> channels-first (B, P, Nspec)
    xp = jnp.transpose(x.reshape(b, nspec, p), (0, 2, 1))
    k = bank[:, ::-1]  # correlation kernel = reversed taps
    bias = jnp.zeros((p,), x.dtype)
    out = K.depthwise_conv_chunked(xp, k, bias)
    return _cast_out(out)  # (B, P, Nspec - M + 1)


def pfb(x, branches: int, taps_per_branch: int, *, dtype: str = "f32",
        prototype=None):
    """§5.2 full PFB (Fig. 3 right column): polyphase FIR bank followed by a
    DFT across branches, both as TINA layers (depthwise conv -> pointwise
    conv with the DFM kernel).

    x: (B, L) -> (re, im) each (B, L/P - M + 1, P): per-spectrum channel
    outputs.
    """
    p = branches
    y = pfb_fir(x, branches, taps_per_branch, dtype=dtype, prototype=prototype)
    y = _cast_in(dtype, y)
    b, _, ns = y.shape
    f_re, f_im = _cast_in(dtype, *coeffs.dft_matrix(p))
    bias = jnp.zeros((p,), y.dtype)
    # DFT across the branch (channel) axis: spectra[b, k, n'] = sum_p y[b,p,n'] F[p,k]
    out_re = K.pointwise_conv(y, f_re, bias)  # (B, P, Ns)
    out_im = K.pointwise_conv(y, f_im, bias)
    out_re = jnp.transpose(out_re, (0, 2, 1))  # (B, Ns, P)
    out_im = jnp.transpose(out_im, (0, 2, 1))
    return _cast_out(out_re, out_im)
