"""Filter-coefficient design shared between the python compile path and the
rust runtime (rust/src/dsp/firdesign.rs implements the same closed forms).

Everything is computed in float64 and cast to float32 at the end so both
languages agree to ~1 ULP; all cross-language tests compare with float
tolerances anyway.
"""

from __future__ import annotations

import numpy as np


def hamming(n: int) -> np.ndarray:
    """Hamming window, periodic-symmetric form w[i] = 0.54 - 0.46 cos(2 pi i / (n-1))."""
    if n == 1:
        return np.ones(1)
    i = np.arange(n, dtype=np.float64)
    return 0.54 - 0.46 * np.cos(2.0 * np.pi * i / (n - 1))


def hann(n: int) -> np.ndarray:
    if n == 1:
        return np.ones(1)
    i = np.arange(n, dtype=np.float64)
    return 0.5 - 0.5 * np.cos(2.0 * np.pi * i / (n - 1))


def sinc(x: np.ndarray) -> np.ndarray:
    """Normalized sinc: sin(pi x) / (pi x)."""
    return np.sinc(x)


def fir_lowpass(num_taps: int, cutoff: float) -> np.ndarray:
    """Hamming-windowed-sinc lowpass FIR, unit DC gain, float32.

    cutoff is the normalized frequency in (0, 0.5] (1.0 = sample rate).
    """
    if not 0.0 < cutoff <= 0.5:
        raise ValueError(f"cutoff {cutoff} outside (0, 0.5]")
    center = (num_taps - 1) / 2.0
    n = np.arange(num_taps, dtype=np.float64)
    h = 2.0 * cutoff * sinc(2.0 * cutoff * (n - center))
    h *= hamming(num_taps)
    h /= h.sum()
    return h.astype(np.float32)


def pfb_prototype(branches: int, taps_per_branch: int) -> np.ndarray:
    """Prototype lowpass for a P-branch polyphase filter bank.

    Standard design (Price 2021 "pfb_introduction"): windowed sinc with
    cutoff at the channel width 1/P, length P*M, unit DC gain.
    Returns float32 of shape (P * M,).
    """
    length = branches * taps_per_branch
    center = (length - 1) / 2.0
    n = np.arange(length, dtype=np.float64)
    h = sinc((n - center) / branches)
    h *= hamming(length)
    h /= h.sum()
    return h.astype(np.float32)


def polyphase_decompose(h: np.ndarray, branches: int) -> np.ndarray:
    """Split prototype h (P*M,) into the branch bank h_p(m) = h[m*P + p].

    Returns (P, M) float32.
    """
    if h.shape[0] % branches != 0:
        raise ValueError("prototype length not divisible by branch count")
    m = h.shape[0] // branches
    return h.reshape(m, branches).T.astype(np.float32).copy()


def dft_matrix(n: int) -> tuple[np.ndarray, np.ndarray]:
    """DFM F[l, k] = exp(-2 pi i l k / n) as (re, im) float32 matrices."""
    lk = np.outer(np.arange(n, dtype=np.float64), np.arange(n, dtype=np.float64))
    ang = -2.0 * np.pi * lk / n
    return np.cos(ang).astype(np.float32), np.sin(ang).astype(np.float32)


def idft_matrix(n: int) -> tuple[np.ndarray, np.ndarray]:
    """IDFM IF[k, j] = exp(+2 pi i k j / n) / n as (re, im) float32 matrices."""
    kj = np.outer(np.arange(n, dtype=np.float64), np.arange(n, dtype=np.float64))
    ang = 2.0 * np.pi * kj / n
    return (np.cos(ang) / n).astype(np.float32), (np.sin(ang) / n).astype(np.float32)
