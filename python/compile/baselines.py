"""The "JAX" comparator of the paper's evaluation: every TINA op written the
direct way in jnp, with no NN-layer reformulation.

These lower through the *same* AOT path and execute on the *same* PJRT
runtime as the TINA variants, so benchmark deltas isolate the mapping, not
the plumbing — mirroring how the paper ran JAX-on-GPU against TINA-on-GPU.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import coeffs


def ewmult(a, b):
    return a * b


def ewadd(a, b):
    return a + b


def matmul(x, y):
    return jnp.dot(x, y)


def summation(x):
    return jnp.sum(x).reshape(1)


def dft(x_re, x_im=None):
    """Direct jnp FFT.  Returns (re, im) to match the TINA artifact ABI."""
    if x_im is None:
        z = jnp.fft.fft(x_re, axis=-1)
    else:
        z = jnp.fft.fft(x_re + 1j * x_im, axis=-1)
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def idft(x_re, x_im):
    z = jnp.fft.ifft(x_re + 1j * x_im, axis=-1)
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def fir(x, taps):
    """Valid-mode FIR via jnp.convolve, vmapped over the batch."""
    import jax

    return jax.vmap(lambda row: jnp.convolve(row, taps, mode="valid"))(x)


def unfold(x, window: int):
    """Direct unfolding: stacked shifted slices (the loop the paper says
    frameworks handle poorly)."""
    b, l = x.shape
    wout = l - window + 1
    cols = [x[:, j : j + wout] for j in range(window)]
    return jnp.stack(cols, axis=-1)  # (B, Wout, J)


def stft(x, nfft: int, hop: int):
    """Direct STFT: strided frame slices, window multiply, jnp FFT."""
    b, l = x.shape
    frames = (l - nfft) // hop + 1
    win = jnp.asarray(coeffs.hamming(nfft), jnp.float32)
    stacked = jnp.stack(
        [x[:, i * hop : i * hop + nfft] * win for i in range(frames)], axis=1
    )  # (B, F, nfft)
    z = jnp.fft.fft(stacked, axis=-1)
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)


def pfb_fir(x, branches: int, taps_per_branch: int, prototype=None):
    """Direct polyphase FIR bank: reshape + per-branch valid convolve."""
    import jax

    p, m = branches, taps_per_branch
    if prototype is None:
        prototype = coeffs.pfb_prototype(p, m)
    bank = coeffs.polyphase_decompose(np.asarray(prototype), p)  # (P, M)
    b, l = x.shape
    nspec = l // p
    xp = jnp.transpose(x.reshape(b, nspec, p), (0, 2, 1))  # (B, P, Nspec)

    def one(row, taps):  # row (Nspec,), taps (M,)
        return jnp.convolve(row, taps, mode="valid")

    # vmap over branches then batch
    per_batch = jax.vmap(one, in_axes=(0, 0))  # (P, Nspec) x (P, M)
    out = jax.vmap(lambda rows: per_batch(rows, jnp.asarray(bank)))(xp)
    return out  # (B, P, Nspec - M + 1)


def pfb(x, branches: int, taps_per_branch: int, prototype=None):
    """Direct full PFB: FIR bank + jnp FFT across branches."""
    y = pfb_fir(x, branches, taps_per_branch, prototype=prototype)
    z = jnp.fft.fft(jnp.transpose(y, (0, 2, 1)), axis=-1)  # (B, Ns, P)
    return jnp.real(z).astype(jnp.float32), jnp.imag(z).astype(jnp.float32)
