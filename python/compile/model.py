"""L2 variant registry: every (op, impl, dtype, size) point of the paper's
evaluation, as a jax callable plus example input specs.

This is the single source of truth for what `aot.py` lowers and what the
rust runtime finds in `artifacts/manifest.json`.  Figure-to-variant mapping
lives in DESIGN.md §5; sizes follow the paper's sweeps scaled to this
testbed (see EXPERIMENTS.md).

Conventions baked into every artifact ABI:
  * interface dtype is always float32 (bf16 variants cast internally);
  * complex values are (re, im) float32 pairs;
  * layer weights — FIR taps, PFB prototype, DFM — are compile-time
    constants (they are the NN weights in the TINA view); signals are the
    runtime inputs;
  * every callable returns a tuple (lowered with return_tuple=True).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from . import baselines, coeffs, tina_ops

# ---------------------------------------------------------------------------
# sweep parameters (the paper's x-axes, scaled to this testbed)
# ---------------------------------------------------------------------------

EWMULT_SIZES = (32, 64, 128, 256)       # Fig 1a (N x N matrices)
MATMUL_SIZES = (32, 64, 128, 256)       # Fig 1b
EWADD_SIZES = (32, 64, 128, 256)        # Fig 1c
SUMMATION_SIZES = (1024, 4096, 16384, 65536)  # Fig 1d
DFT_SIZES = (64, 128, 256, 512)         # Fig 2a/2b (signal length)
DFT_BATCH = 4
FIR_SIZES = (1024, 4096, 16384, 65536)  # Fig 2c
FIR_TAPS = 64
FIR_CUTOFF = 0.25
UNFOLD_SIZES = (1024, 4096, 16384, 65536)  # Fig 2d
UNFOLD_WINDOW = 32
PFB_BRANCHES = 32                        # Fig 3
PFB_TAPS = 8
PFB_SIZES = (4096, 16384, 65536)
PFB_BATCHES = (1, 8)                     # 8 feeds the coordinator's batcher
STFT_NFFT = 256                          # extension op (paper future work)
STFT_HOP = 128
STFT_SIZES = (4096, 16384)


@dataclasses.dataclass(frozen=True)
class Variant:
    """One lowerable artifact: a concrete jax callable and its ABI."""

    name: str
    op: str
    impl: str  # "tina" | "jaxref"
    dtype: str  # "f32" | "bf16" (internal compute; interface is f32)
    params: dict
    fn: Callable
    input_specs: Sequence[jax.ShapeDtypeStruct]

    @property
    def filename(self) -> str:
        return f"{self.name}.hlo.txt"

    def output_specs(self):
        return jax.eval_shape(self.fn, *self.input_specs)


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _tuple_wrap(fn):
    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


# ---------------------------------------------------------------------------
# variant builders, one per op
# ---------------------------------------------------------------------------


def _arith_variants() -> list:
    out = []
    for op, sizes, tina_fn, jax_fn in (
        ("ewmult", EWMULT_SIZES, tina_ops.ewmult, baselines.ewmult),
        ("ewadd", EWADD_SIZES, tina_ops.ewadd, baselines.ewadd),
        ("matmul", MATMUL_SIZES, tina_ops.matmul, baselines.matmul),
    ):
        for n in sizes:
            specs = [_spec(n, n), _spec(n, n)]
            out.append(
                Variant(
                    name=f"{op}_tina_f32_N{n}",
                    op=op, impl="tina", dtype="f32", params={"n": n},
                    fn=_tuple_wrap(lambda a, b, f=tina_fn: f(a, b)),
                    input_specs=specs,
                )
            )
            out.append(
                Variant(
                    name=f"{op}_jaxref_f32_N{n}",
                    op=op, impl="jaxref", dtype="f32", params={"n": n},
                    fn=_tuple_wrap(lambda a, b, f=jax_fn: f(a, b)),
                    input_specs=specs,
                )
            )
    for l in SUMMATION_SIZES:
        specs = [_spec(l)]
        out.append(
            Variant(
                name=f"summation_tina_f32_L{l}",
                op="summation", impl="tina", dtype="f32", params={"l": l},
                fn=_tuple_wrap(tina_ops.summation),
                input_specs=specs,
            )
        )
        out.append(
            Variant(
                name=f"summation_jaxref_f32_L{l}",
                op="summation", impl="jaxref", dtype="f32", params={"l": l},
                fn=_tuple_wrap(baselines.summation),
                input_specs=specs,
            )
        )
    return out


def _fourier_variants() -> list:
    out = []
    for n in DFT_SIZES:
        b = DFT_BATCH
        # DFT of a real signal: one f32 input, (re, im) outputs.
        out.append(
            Variant(
                name=f"dft_tina_f32_B{b}_N{n}",
                op="dft", impl="tina", dtype="f32", params={"n": n, "batch": b},
                fn=_tuple_wrap(lambda x: tina_ops.dft(x)),
                input_specs=[_spec(b, n)],
            )
        )
        out.append(
            Variant(
                name=f"dft_jaxref_f32_B{b}_N{n}",
                op="dft", impl="jaxref", dtype="f32", params={"n": n, "batch": b},
                fn=_tuple_wrap(lambda x: baselines.dft(x)),
                input_specs=[_spec(b, n)],
            )
        )
        # IDFT of a complex spectrum: (re, im) in and out.
        out.append(
            Variant(
                name=f"idft_tina_f32_B{b}_N{n}",
                op="idft", impl="tina", dtype="f32", params={"n": n, "batch": b},
                fn=_tuple_wrap(tina_ops.idft),
                input_specs=[_spec(b, n), _spec(b, n)],
            )
        )
        out.append(
            Variant(
                name=f"idft_jaxref_f32_B{b}_N{n}",
                op="idft", impl="jaxref", dtype="f32", params={"n": n, "batch": b},
                fn=_tuple_wrap(baselines.idft),
                input_specs=[_spec(b, n), _spec(b, n)],
            )
        )
    return out


def _fir_unfold_variants() -> list:
    out = []
    taps = coeffs.fir_lowpass(FIR_TAPS, FIR_CUTOFF)
    for l in FIR_SIZES:
        params = {"l": l, "taps": FIR_TAPS, "cutoff": FIR_CUTOFF, "batch": 1}
        out.append(
            Variant(
                name=f"fir_tina_f32_B1_L{l}",
                op="fir", impl="tina", dtype="f32", params=params,
                fn=_tuple_wrap(lambda x, t=taps: tina_ops.fir(x, t)),
                input_specs=[_spec(1, l)],
            )
        )
        out.append(
            Variant(
                name=f"fir_jaxref_f32_B1_L{l}",
                op="fir", impl="jaxref", dtype="f32", params=params,
                fn=_tuple_wrap(lambda x, t=jnp.asarray(taps): baselines.fir(x, t)),
                input_specs=[_spec(1, l)],
            )
        )
    # batched FIR for the coordinator's dynamic batcher
    l = 4096
    out.append(
        Variant(
            name=f"fir_tina_f32_B8_L{l}",
            op="fir", impl="tina", dtype="f32",
            params={"l": l, "taps": FIR_TAPS, "cutoff": FIR_CUTOFF, "batch": 8},
            fn=_tuple_wrap(lambda x, t=taps: tina_ops.fir(x, t)),
            input_specs=[_spec(8, l)],
        )
    )
    for l in UNFOLD_SIZES:
        params = {"l": l, "window": UNFOLD_WINDOW, "batch": 1}
        out.append(
            Variant(
                name=f"unfold_tina_f32_B1_L{l}",
                op="unfold", impl="tina", dtype="f32", params=params,
                fn=_tuple_wrap(lambda x: tina_ops.unfold(x, UNFOLD_WINDOW)),
                input_specs=[_spec(1, l)],
            )
        )
        out.append(
            Variant(
                name=f"unfold_jaxref_f32_B1_L{l}",
                op="unfold", impl="jaxref", dtype="f32", params=params,
                fn=_tuple_wrap(lambda x: baselines.unfold(x, UNFOLD_WINDOW)),
                input_specs=[_spec(1, l)],
            )
        )
    return out


def _pfb_variants() -> list:
    out = []
    p, m = PFB_BRANCHES, PFB_TAPS
    for l in PFB_SIZES:
        for batch in PFB_BATCHES:
            if batch != 1 and l != 16384:
                continue  # batched artifacts only at the serving size
            params = {"l": l, "branches": p, "taps_per_branch": m, "batch": batch}
            for op, tina_fn, jax_fn in (
                ("pfb_fir", tina_ops.pfb_fir, baselines.pfb_fir),
                ("pfb", tina_ops.pfb, baselines.pfb),
            ):
                out.append(
                    Variant(
                        name=f"{op}_tina_f32_B{batch}_L{l}",
                        op=op, impl="tina", dtype="f32", params=params,
                        fn=_tuple_wrap(lambda x, f=tina_fn: f(x, p, m, dtype="f32")),
                        input_specs=[_spec(batch, l)],
                    )
                )
                out.append(
                    Variant(
                        name=f"{op}_tina_bf16_B{batch}_L{l}",
                        op=op, impl="tina", dtype="bf16", params=params,
                        fn=_tuple_wrap(lambda x, f=tina_fn: f(x, p, m, dtype="bf16")),
                        input_specs=[_spec(batch, l)],
                    )
                )
                out.append(
                    Variant(
                        name=f"{op}_jaxref_f32_B{batch}_L{l}",
                        op=op, impl="jaxref", dtype="f32", params=params,
                        fn=_tuple_wrap(lambda x, f=jax_fn: f(x, p, m)),
                        input_specs=[_spec(batch, l)],
                    )
                )
    return out


def _stft_variants() -> list:
    out = []
    for l in STFT_SIZES:
        params = {"l": l, "nfft": STFT_NFFT, "hop": STFT_HOP, "batch": 1}
        out.append(
            Variant(
                name=f"stft_tina_f32_B1_L{l}",
                op="stft", impl="tina", dtype="f32", params=params,
                fn=_tuple_wrap(lambda x: tina_ops.stft(x, STFT_NFFT, STFT_HOP)),
                input_specs=[_spec(1, l)],
            )
        )
        out.append(
            Variant(
                name=f"stft_jaxref_f32_B1_L{l}",
                op="stft", impl="jaxref", dtype="f32", params=params,
                fn=_tuple_wrap(lambda x: baselines.stft(x, STFT_NFFT, STFT_HOP)),
                input_specs=[_spec(1, l)],
            )
        )
    return out


def build_variants() -> list:
    """All lowerable variants, in manifest order."""
    variants = (
        _arith_variants()
        + _fourier_variants()
        + _fir_unfold_variants()
        + _pfb_variants()
        + _stft_variants()
    )
    names = [v.name for v in variants]
    assert len(names) == len(set(names)), "duplicate variant names"
    return variants


def get_variant(name: str):
    for v in build_variants():
        if v.name == name:
            return v
    raise KeyError(name)
