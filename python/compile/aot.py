"""AOT compile path: lower every registry variant to HLO *text* and write
`artifacts/manifest.json`.

HLO text — NOT `lowered.compile()` or a serialized HloModuleProto — is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which the rust side's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly.

Run via `make artifacts` (i.e. `cd python && python -m compile.aot
--out-dir ../artifacts`).  Python never runs again after this: the rust
coordinator is self-contained once the artifact directory exists.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax

from . import model

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassignment-safe)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default HLO printer ELIDES big constants
    # ("constant({...})"), which the rust-side text parser would silently
    # read back as zeros — the baked DFM / FIR-tap weights must survive.
    text = comp.as_hlo_text(print_large_constants=True)
    if "{...}" in text:
        raise RuntimeError("HLO text still contains elided constants")
    return _force_row_major_entry(text)


def _force_row_major_entry(hlo: str) -> str:
    """Rewrite the entry_computation_layout to default (row-major) layouts.

    Functions ending in a transpose lower with column-major output layouts
    (e.g. ``f32[4,64]{0,1}``); the rust side's ``Literal::to_vec`` assumes
    row-major, and xla_extension 0.5.1 aborts with a foreign exception on
    some non-default entry layouts.  Forcing the *entry* layout is always
    legal — the compiler inserts the transposes it needs.
    """
    lines = hlo.split("\n", 1)
    head = re.sub(
        r"\[([0-9,]*)\]\{([0-9,]+)\}",
        lambda m: "[{}]{{{}}}".format(
            m.group(1),
            ",".join(str(i) for i in reversed(range(m.group(1).count(",") + 1))),
        ),
        lines[0],
    )
    return head + ("\n" + lines[1] if len(lines) > 1 else "")


def _spec_json(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def lower_variant(variant, out_dir: Path) -> dict:
    """Lower one variant, write its HLO text, return its manifest entry."""
    t0 = time.perf_counter()
    lowered = jax.jit(variant.fn).lower(*variant.input_specs)
    text = to_hlo_text(lowered)
    path = out_dir / variant.filename
    path.write_text(text)
    outputs = variant.output_specs()
    dt = time.perf_counter() - t0
    entry = {
        "name": variant.name,
        "op": variant.op,
        "impl": variant.impl,
        "dtype": variant.dtype,
        "params": variant.params,
        "inputs": [_spec_json(s) for s in variant.input_specs],
        "outputs": [_spec_json(s) for s in outputs],
        "file": variant.filename,
        "hlo_bytes": len(text),
    }
    print(f"  {variant.name:42s} {len(text) / 1024:9.1f} KiB  {dt:6.2f}s")
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--filter", default=None, help="regex over variant names")
    ap.add_argument("--list", action="store_true", help="list variants and exit")
    args = ap.parse_args(argv)

    variants = model.build_variants()
    if args.filter:
        rx = re.compile(args.filter)
        variants = [v for v in variants if rx.search(v.name)]
    if args.list:
        for v in variants:
            print(v.name)
        return 0

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    print(f"lowering {len(variants)} variants -> {out_dir}")
    t0 = time.perf_counter()
    entries = [lower_variant(v, out_dir) for v in variants]
    manifest = {
        "version": MANIFEST_VERSION,
        "jax_version": jax.__version__,
        "entries": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(entries)} artifacts + manifest.json "
          f"in {time.perf_counter() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
