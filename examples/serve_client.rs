//! Client/server demo: starts the TCP JSON-line server in-process, then
//! talks to it as a client — the wire protocol a non-rust frontend
//! (python, telescope control system, ...) would use.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_client
//! ```

use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use tina::coordinator::{server, Coordinator, CoordinatorConfig};
use tina::util::json::{self, Json};

const ADDR: &str = "127.0.0.1:7071";

fn main() -> Result<()> {
    // ---- server ----------------------------------------------------------
    let coord = Arc::new(Coordinator::from_dir(
        "artifacts",
        CoordinatorConfig::default(),
    )?);
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || server::serve(coord, ADDR, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(300));

    // ---- client ----------------------------------------------------------
    let mut stream = TcpStream::connect(ADDR)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut call = |line: String| -> Result<Json> {
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        let mut resp = String::new();
        reader.read_line(&mut resp)?;
        Ok(json::parse(&resp).map_err(|e| anyhow::anyhow!("{e}"))?)
    };

    // list artifacts
    let resp = call(r#"{"id": 1, "cmd": "artifacts"}"#.to_string())?;
    let n = resp.get("artifacts").and_then(Json::as_arr).map(|a| a.len());
    println!("server exposes {n:?} artifacts");

    // run a summation
    let data: Vec<String> = (1..=1024).map(|i| i.to_string()).collect();
    let resp = call(format!(
        r#"{{"id": 2, "op": "summation", "inputs": [{{"shape": [1024], "data": [{}]}}]}}"#,
        data.join(",")
    ))?;
    let sum = resp.get("outputs").and_then(Json::as_arr).and_then(|o| {
        o[0].get("data")
            .and_then(Json::as_arr)
            .and_then(|d| d[0].as_f64())
    });
    println!(
        "summation(1..=1024) = {:?} (served_by {:?}, {}us)",
        sum,
        resp.get("served_by").and_then(Json::as_str),
        resp.get("latency_us").and_then(Json::as_f64).unwrap_or(0.0)
    );
    assert_eq!(sum, Some(524800.0));

    // run a DFT and verify Parseval on the client side
    let sig: Vec<f32> = (0..64)
        .map(|i| (2.0 * std::f64::consts::PI * 5.0 * i as f64 / 64.0).cos() as f32)
        .collect();
    let sig_json: Vec<String> = sig.iter().map(|v| format!("{v}")).collect();
    let resp = call(format!(
        r#"{{"id": 3, "op": "dft", "inputs": [{{"shape": [1, 64], "data": [{}]}}]}}"#,
        sig_json.join(",")
    ))?;
    let get = |k: usize| -> Vec<f64> {
        resp.get("outputs").unwrap().as_arr().unwrap()[k]
            .get("data")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect()
    };
    let (re, im) = (get(0), get(1));
    let spec_energy: f64 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum();
    let sig_energy: f64 = sig.iter().map(|&v| (v * v) as f64).sum();
    println!(
        "dft Parseval: spectrum {spec_energy:.1} vs 64 x signal {:.1}",
        64.0 * sig_energy
    );
    assert!((spec_energy - 64.0 * sig_energy).abs() / spec_energy < 1e-3);

    // stats
    let resp = call(r#"{"id": 4, "cmd": "stats"}"#.to_string())?;
    println!(
        "server stats:\n{}",
        resp.get("report").and_then(Json::as_str).unwrap_or("")
    );

    // close BOTH socket handles (the closure holds the reader clone) so the
    // server's connection thread sees EOF before we join it
    drop(call);
    drop(reader);
    drop(stream);
    stop.store(true, Ordering::Release);
    server_thread.join().unwrap()?;
    println!("done");
    Ok(())
}
