//! Client/server demo: starts the TCP server in-process, then talks to it
//! as a client in BOTH protocol modes — the JSON line compat mode a
//! non-rust frontend (python, telescope control system, ...) would use
//! for debugging, and the binary framed mode a production client uses
//! (raw little-endian f32 payloads, pipelined requests, streaming
//! sessions).  The server auto-detects the mode per connection from its
//! first byte.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_client
//! ```

use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use tina::coordinator::{
    server, wire, Coordinator, CoordinatorConfig, ImplPref, OpKind, Precision, ServerFrame,
};
use tina::tensor::Tensor;
use tina::util::json::{self, Json};

const ADDR: &str = "127.0.0.1:7071";

fn read_frame(reader: &mut BufReader<TcpStream>) -> Result<ServerFrame> {
    let mut payload = Vec::new();
    let ft = wire::read_frame(reader, &mut payload, wire::DEFAULT_MAX_FRAME)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .ok_or_else(|| anyhow::anyhow!("server closed the connection"))?;
    wire::decode_server_frame(ft, &payload).map_err(|e| anyhow::anyhow!("{e}"))
}

fn main() -> Result<()> {
    // ---- server ----------------------------------------------------------
    let coord = Arc::new(Coordinator::from_dir(
        "artifacts",
        CoordinatorConfig::default(),
    )?);
    let stop = Arc::new(AtomicBool::new(false));
    let server_thread = {
        let coord = Arc::clone(&coord);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || server::serve(coord, ADDR, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(300));

    // ---- JSON line client (debug/compat mode) ----------------------------
    let mut stream = TcpStream::connect(ADDR)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut call = |line: String| -> Result<Json> {
        stream.write_all(line.as_bytes())?;
        stream.write_all(b"\n")?;
        let mut resp = String::new();
        reader.read_line(&mut resp)?;
        Ok(json::parse(&resp).map_err(|e| anyhow::anyhow!("{e}"))?)
    };

    // list artifacts
    let resp = call(r#"{"id": 1, "cmd": "artifacts"}"#.to_string())?;
    let n = resp.get("artifacts").and_then(Json::as_arr).map(|a| a.len());
    println!("server exposes {n:?} artifacts");

    // run a summation
    let data: Vec<String> = (1..=1024).map(|i| i.to_string()).collect();
    let resp = call(format!(
        r#"{{"id": 2, "op": "summation", "inputs": [{{"shape": [1024], "data": [{}]}}]}}"#,
        data.join(",")
    ))?;
    let sum = resp.get("outputs").and_then(Json::as_arr).and_then(|o| {
        o[0].get("data")
            .and_then(Json::as_arr)
            .and_then(|d| d[0].as_f64())
    });
    println!(
        "json   summation(1..=1024) = {:?} (served_by {:?}, {}us)",
        sum,
        resp.get("served_by").and_then(Json::as_str),
        resp.get("latency_us").and_then(Json::as_f64).unwrap_or(0.0)
    );
    assert_eq!(sum, Some(524800.0));

    // run a DFT and verify Parseval on the client side
    let sig: Vec<f32> = (0..64)
        .map(|i| (2.0 * std::f64::consts::PI * 5.0 * i as f64 / 64.0).cos() as f32)
        .collect();
    let sig_json: Vec<String> = sig.iter().map(|v| format!("{v}")).collect();
    let resp = call(format!(
        r#"{{"id": 3, "op": "dft", "inputs": [{{"shape": [1, 64], "data": [{}]}}]}}"#,
        sig_json.join(",")
    ))?;
    let get = |k: usize| -> Vec<f64> {
        resp.get("outputs").unwrap().as_arr().unwrap()[k]
            .get("data")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect()
    };
    let (re, im) = (get(0), get(1));
    let spec_energy: f64 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum();
    let sig_energy: f64 = sig.iter().map(|&v| (v * v) as f64).sum();
    println!(
        "json   dft Parseval: spectrum {spec_energy:.1} vs 64 x signal {:.1}",
        64.0 * sig_energy
    );
    assert!((spec_energy - 64.0 * sig_energy).abs() / spec_energy < 1e-3);

    // close BOTH socket handles (the closure holds the reader clone) so the
    // server's connection thread sees EOF before we join it
    drop(call);
    drop(reader);
    drop(stream);

    // ---- binary framed client (production mode) --------------------------
    let mut bin = TcpStream::connect(ADDR)?;
    let mut breader = BufReader::new(bin.try_clone()?);

    // pipelining: write three requests back-to-back, then read the three
    // replies (they come back in frame order, matched by id)
    for (id, scale) in [(10u64, 1.0f32), (11, 2.0), (12, 3.0)] {
        let t = Tensor::new(&[1024], (1..=1024).map(|i| i as f32 * scale).collect())?;
        bin.write_all(&wire::encode_request(
            id,
            OpKind::Summation,
            ImplPref::Auto,
            Precision::F32,
            None,
            &[t],
        ))?;
    }
    for (id, scale) in [(10u64, 1.0f32), (11, 2.0), (12, 3.0)] {
        let ServerFrame::Response {
            id: got,
            outputs,
            served_by,
            latency_us,
            ..
        } = read_frame(&mut breader)?
        else {
            anyhow::bail!("expected a response frame");
        };
        assert_eq!(got, id);
        let want = 524800.0 * scale;
        assert_eq!(outputs[0].data(), &[want]);
        println!("binary summation x{scale} = {want} (served_by {served_by}, {latency_us:.0}us)");
    }

    // streaming session: push a long FIR signal in chunks; the server
    // carries the overlap tail, so the chunked output continues the
    // one-shot run bit-for-bit
    bin.write_all(&wire::encode_session_open(20, OpKind::Fir))?;
    let ServerFrame::SessionOpened {
        session, overlap, ..
    } = read_frame(&mut breader)?
    else {
        anyhow::bail!("expected session-opened");
    };
    println!("binary session {session} opened (overlap {overlap})");
    let signal = Tensor::randn(&[1, 4000], 7);
    let mut streamed = 0usize;
    for (i, chunk) in signal.data().chunks(1000).enumerate() {
        bin.write_all(&wire::encode_session_push(
            21 + i as u64,
            session,
            None,
            chunk,
        ))?;
        let ServerFrame::SessionData { samples, .. } = read_frame(&mut breader)? else {
            anyhow::bail!("expected session-data");
        };
        streamed += samples.len();
    }
    bin.write_all(&wire::encode_session_close(30, session))?;
    let ServerFrame::SessionClosed {
        chunks,
        samples_in,
        samples_out,
        ..
    } = read_frame(&mut breader)?
    else {
        anyhow::bail!("expected session-closed");
    };
    assert_eq!(streamed as u64, samples_out);
    println!(
        "binary session closed: {chunks} chunks, {samples_in} samples in, {samples_out} out"
    );

    // stats over the binary protocol
    bin.write_all(&wire::encode_stats(40))?;
    let ServerFrame::StatsReply { report, .. } = read_frame(&mut breader)? else {
        anyhow::bail!("expected a stats reply");
    };
    println!("server stats:\n{report}");

    drop(breader);
    drop(bin);
    stop.store(true, Ordering::Release);
    server_thread.join().unwrap()?;
    println!("done");
    Ok(())
}
