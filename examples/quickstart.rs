//! Quickstart: load the AOT artifacts, run a few TINA ops through the
//! coordinator, and cross-check against the pure-rust baselines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use tina::baselines::naive;
use tina::coordinator::{Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest};
use tina::tensor::Tensor;

fn main() -> Result<()> {
    // 1. bring up the coordinator over the artifact directory
    let coord = Coordinator::from_dir("artifacts", CoordinatorConfig::default())?;
    println!("artifacts loaded: {}", coord.router().registry().len());

    // 2. elementwise multiply via the TINA depthwise-conv artifact (§3.1)
    let a = Tensor::randn(&[64, 64], 1);
    let b = Tensor::randn(&[64, 64], 2);
    let resp = coord.execute(
        OpRequest::new(OpKind::EwMult, vec![a.clone(), b.clone()]).with_impl(ImplPref::Tina),
    )?;
    let want = naive::ewmult(&a, &b)?;
    println!(
        "ewmult     served_by={:<24} allclose={}",
        resp.served_by,
        resp.outputs[0].allclose(&want, 1e-4, 1e-4)
    );

    // 3. FIR filter via the standard-conv artifact (§4.3)
    let x = Tensor::randn(&[1, 4096], 3);
    let resp = coord.execute(
        OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Tina),
    )?;
    let taps = tina::dsp::fir_lowpass(64, 0.25)?;
    let want = naive::fir(&x, &taps)?;
    println!(
        "fir        served_by={:<24} allclose={}",
        resp.served_by,
        resp.outputs[0].allclose(&want, 1e-3, 1e-4)
    );

    // 4. DFT via the pointwise-conv artifact (§4.1): real signal in,
    //    (re, im) out
    let sig = Tensor::randn(&[4, 256], 4);
    let resp = coord.execute(OpRequest::new(OpKind::Dft, vec![sig.clone()]))?;
    let want = naive::dft(&tina::tensor::ComplexTensor::from_real(sig))?;
    println!(
        "dft        served_by={:<24} re allclose={} im allclose={}",
        resp.served_by,
        resp.outputs[0].allclose(&want.re, 1e-2, 1e-2),
        resp.outputs[1].allclose(&want.im, 1e-2, 1e-2)
    );

    // 5. a request with no matching artifact falls back to the pure-rust
    //    interpreter transparently
    let odd = Tensor::randn(&[1, 999], 5);
    let resp = coord.execute(OpRequest::new(OpKind::Fir, vec![odd]))?;
    println!("fir(L=999) served_by={:<24} (interpreter fallback)", resp.served_by);

    println!("\nmetrics:\n{}", coord.metrics().report());
    Ok(())
}
