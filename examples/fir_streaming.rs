//! Streaming FIR service demo: many concurrent single-signal requests ride
//! the coordinator's dynamic batcher, which coalesces them into the
//! batched `fir_tina_f32_B8_L4096` artifact.
//!
//! Shows the serving-layer contribution: requests/s and padding overhead
//! with batching on vs off — and the coordinator's **streaming sessions**
//! (the overlap-carry idiom this example pioneered at the library level,
//! now server-side state): an unbounded signal pushed in chunks produces
//! the one-shot output bit-for-bit.
//!
//! ```bash
//! make artifacts && cargo run --release --example fir_streaming
//! ```

use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use tina::coordinator::{Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest};
use tina::tensor::Tensor;

const CHUNK: usize = 4096;
const REQUESTS: usize = 200;

fn run_wave(coord: &Arc<Coordinator>, label: &str) -> Result<f64> {
    let t0 = std::time::Instant::now();
    let slots: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let chunk = Tensor::randn(&[1, CHUNK], 10 + i as u64);
            coord.submit(OpRequest::new(OpKind::Fir, vec![chunk]).with_impl(ImplPref::Tina))
        })
        .collect();
    let mut batched = 0usize;
    for s in slots {
        let resp = s.wait()?;
        assert_eq!(resp.outputs[0].shape(), &[1, CHUNK - 64 + 1]);
        if resp.batched {
            batched += 1;
        }
    }
    let dt = t0.elapsed();
    let rps = REQUESTS as f64 / dt.as_secs_f64();
    println!(
        "{label:<16} {REQUESTS} requests in {dt:?} -> {rps:8.0} req/s ({batched} rode batches)"
    );
    Ok(rps)
}

fn main() -> Result<()> {
    println!("== streaming FIR: {REQUESTS} x (1, {CHUNK}) chunks, 64-tap lowpass ==\n");

    // batching ON
    let coord = Arc::new(Coordinator::from_dir(
        "artifacts",
        CoordinatorConfig::default(),
    )?);
    coord.warmup(Some("fir"))?;
    let with_batching = run_wave(&coord, "batching on")?;
    let m = coord.metrics();
    println!(
        "  batches executed: {}, rows padded: {}",
        m.batches_executed.load(Ordering::Relaxed),
        m.padded_rows.load(Ordering::Relaxed),
    );
    if let Some(h) = m.latency_of("fir") {
        println!("  fir latency: {}", h.summary());
    }
    coord.shutdown();

    // batching OFF
    let coord = Arc::new(Coordinator::from_dir(
        "artifacts",
        CoordinatorConfig {
            batching: false,
            ..Default::default()
        },
    )?);
    coord.warmup(Some("fir"))?;
    let without = run_wave(&coord, "batching off")?;
    coord.shutdown();

    println!(
        "\nbatching throughput gain: {:.2}x",
        with_batching / without
    );

    // streaming session: the coordinator holds the carry tail, every
    // chunk rides the normal serving path, and the concatenated outputs
    // equal the one-shot run bit-for-bit
    let coord = Arc::new(Coordinator::from_dir(
        "artifacts",
        CoordinatorConfig::default(),
    )?);
    let signal = Tensor::randn(&[1, 3 * CHUNK], 99);
    let one_shot = coord.execute(OpRequest::new(OpKind::Fir, vec![signal.clone()]))?;
    let (sid, overlap) = coord.session_open(OpKind::Fir)?;
    let mut streamed: Vec<f32> = Vec::new();
    for chunk in signal.data().chunks(1000) {
        streamed.extend_from_slice(&coord.session_push(sid, chunk, None)?.samples);
    }
    let summary = coord.session_close(sid)?;
    let want = one_shot.outputs[0].data();
    assert_eq!(streamed.len(), want.len());
    for (a, b) in streamed.iter().zip(want) {
        assert_eq!(a.to_bits(), b.to_bits(), "chunked output must be bit-exact");
    }
    println!(
        "\nstreaming session (overlap {overlap}): {} chunks, {} samples in, {} out \
         — bit-identical to the one-shot run",
        summary.chunks, summary.samples_in, summary.samples_out
    );
    coord.shutdown();
    Ok(())
}
