//! Streaming FIR service demo: many concurrent single-signal requests ride
//! the coordinator's dynamic batcher, which coalesces them into the
//! batched `fir_tina_f32_B8_L4096` artifact.
//!
//! Shows the serving-layer contribution: requests/s and padding overhead
//! with batching on vs off.
//!
//! ```bash
//! make artifacts && cargo run --release --example fir_streaming
//! ```

use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use tina::coordinator::{Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest};
use tina::tensor::Tensor;

const CHUNK: usize = 4096;
const REQUESTS: usize = 200;

fn run_wave(coord: &Arc<Coordinator>, label: &str) -> Result<f64> {
    let t0 = std::time::Instant::now();
    let slots: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let chunk = Tensor::randn(&[1, CHUNK], 10 + i as u64);
            coord.submit(OpRequest::new(OpKind::Fir, vec![chunk]).with_impl(ImplPref::Tina))
        })
        .collect();
    let mut batched = 0usize;
    for s in slots {
        let resp = s.wait()?;
        assert_eq!(resp.outputs[0].shape(), &[1, CHUNK - 64 + 1]);
        if resp.batched {
            batched += 1;
        }
    }
    let dt = t0.elapsed();
    let rps = REQUESTS as f64 / dt.as_secs_f64();
    println!(
        "{label:<16} {REQUESTS} requests in {dt:?} -> {rps:8.0} req/s ({batched} rode batches)"
    );
    Ok(rps)
}

fn main() -> Result<()> {
    println!("== streaming FIR: {REQUESTS} x (1, {CHUNK}) chunks, 64-tap lowpass ==\n");

    // batching ON
    let coord = Arc::new(Coordinator::from_dir(
        "artifacts",
        CoordinatorConfig::default(),
    )?);
    coord.warmup(Some("fir"))?;
    let with_batching = run_wave(&coord, "batching on")?;
    let m = coord.metrics();
    println!(
        "  batches executed: {}, rows padded: {}",
        m.batches_executed.load(Ordering::Relaxed),
        m.padded_rows.load(Ordering::Relaxed),
    );
    if let Some(h) = m.latency_of("fir") {
        println!("  fir latency: {}", h.summary());
    }
    coord.shutdown();

    // batching OFF
    let coord = Arc::new(Coordinator::from_dir(
        "artifacts",
        CoordinatorConfig {
            batching: false,
            ..Default::default()
        },
    )?);
    coord.warmup(Some("fir"))?;
    let without = run_wave(&coord, "batching off")?;
    coord.shutdown();

    println!(
        "\nbatching throughput gain: {:.2}x",
        with_batching / without
    );
    Ok(())
}
