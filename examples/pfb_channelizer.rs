//! End-to-end driver (the paper's §5.2 use case): channelize a synthetic
//! radio-astronomy observation with the TINA polyphase filter bank and
//! report the headline Fig.-3 metric — speedup of every implementation
//! over the naive CPU baseline — plus a correctness check of where each
//! injected tone lands.
//!
//! The workload mimics a LOFAR-style subband recording: a P = 32 branch
//! PFB over 64k-sample frames, three injected tones (two stationary, one
//! strong) in white noise, 32 frames of integration.
//!
//! ```bash
//! make artifacts && cargo run --release --example pfb_channelizer
//! ```
//!
//! Results of a reference run are recorded in EXPERIMENTS.md §E2E.

use anyhow::Result;
use tina::baselines::{naive, optimized};
use tina::benchkit::{black_box, run, BenchConfig, Table};
use tina::coordinator::{Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest, Precision};
use tina::dsp::PfbConfig;
use tina::tensor::Tensor;
use tina::util::histogram::fmt_ns;
use tina::util::prng::Xoshiro256;

const P: usize = 32; // branches (must match the artifact sweep)
const M: usize = 8; // taps per branch
const FRAME: usize = 65536; // samples per frame
const FRAMES: usize = 32; // integration length

/// Synthesize one frame: white noise + three tones (channel centers 5, 12,
/// 21 with SNRs ~0.5, 2, 8).
fn synth_frame(seed: u64) -> Tensor {
    let mut rng = Xoshiro256::new(seed);
    let mut data = vec![0.0f32; FRAME];
    for (i, v) in data.iter_mut().enumerate() {
        let t = i as f64;
        let tone = |ch: f64, amp: f64| amp * (2.0 * std::f64::consts::PI * ch * t / P as f64).cos();
        *v = (tone(5.0, 0.5) + tone(12.0, 2.0) + tone(21.0, 8.0)) as f32 + rng.normal() * 1.0;
    }
    Tensor::new(&[1, FRAME], data).unwrap()
}

fn main() -> Result<()> {
    let cfg = PfbConfig::new(P, M);
    let coord = Coordinator::from_dir("artifacts", CoordinatorConfig::default())?;
    println!("== TINA PFB channelizer: P={P} branches, M={M} taps, {FRAMES} x {FRAME}-sample frames ==\n");

    // ---- integrate the observation through the TINA (PJRT) path ---------
    let ns = cfg.output_spectra(FRAME)?;
    let mut accum = vec![0.0f64; P];
    let t0 = std::time::Instant::now();
    for f in 0..FRAMES {
        let frame = synth_frame(1000 + f as u64);
        let resp = coord.execute(
            OpRequest::new(OpKind::Pfb, vec![frame]).with_impl(ImplPref::Tina),
        )?;
        let (re, im) = (&resp.outputs[0], &resp.outputs[1]);
        for n in 0..ns {
            for k in 0..P {
                let (r, i_) = (re.at(&[0, n, k]), im.at(&[0, n, k]));
                accum[k] += (r * r + i_ * i_) as f64;
            }
        }
    }
    let integrate_time = t0.elapsed();
    for a in &mut accum {
        *a /= (FRAMES * ns) as f64;
    }

    // ---- report the integrated spectrum ---------------------------------
    println!("integrated power spectrum ({} PFB executions, {:?} total):", FRAMES, integrate_time);
    let max_p = accum.iter().cloned().fold(0.0, f64::max);
    for (k, &p) in accum.iter().enumerate() {
        let bar = "#".repeat(((p / max_p) * 50.0) as usize);
        let mark = match k {
            5 | 12 | 21 => " <- injected tone",
            27 | 20 | 11 => " (mirror)",
            _ => "",
        };
        println!("  ch {k:>2} {p:>10.4} {bar}{mark}");
    }
    // correctness: the three injected channels must dominate their neighbours
    for &ch in &[5usize, 12, 21] {
        assert!(
            accum[ch] > 2.0 * accum[(ch + 2) % P],
            "channel {ch} power {} not dominant",
            accum[ch]
        );
    }
    println!("  tone placement check: OK\n");

    // ---- Fig. 3 headline: speedups vs naive on one frame ----------------
    let bench = BenchConfig::from_env();
    let frame = synth_frame(7);

    let naive_s = run(&bench, || {
        black_box(naive::pfb(&frame, cfg).unwrap());
    })
    .summary();
    let opt_s = run(&bench, || {
        black_box(optimized::pfb(&frame, cfg).unwrap());
    })
    .summary();

    let mut artifact_case = |impl_pref: ImplPref, precision: Precision| {
        let req = OpRequest::new(OpKind::Pfb, vec![frame.clone()])
            .with_impl(impl_pref)
            .with_precision(precision);
        coord.execute(req.clone()).expect("warm");
        run(&bench, || {
            black_box(coord.execute(req.clone()).unwrap());
        })
        .summary()
    };
    let tina32 = artifact_case(ImplPref::Tina, Precision::F32);
    let tina16 = artifact_case(ImplPref::Tina, Precision::Bf16);
    let jaxref = artifact_case(ImplPref::JaxRef, Precision::F32);

    let mut table = Table::new(
        &format!("full PFB, one {FRAME}-sample frame (median of {} iters)", naive_s.n),
        &["impl", "median", "speedup vs naive"],
    );
    for (name, s) in [
        ("naive (NumPy analog)", &naive_s),
        ("optimized (CuPy analog)", &opt_s),
        ("TINA 32-bit (PJRT)", &tina32),
        ("TINA 16-bit (PJRT)", &tina16),
        ("JAX direct (PJRT)", &jaxref),
    ] {
        table.row(vec![
            name.into(),
            fmt_ns(s.median_ns as u64),
            format!("{:.1}x", s.speedup_vs(&naive_s)),
        ]);
    }
    print!("{}", table.render());
    println!("\nmetrics:\n{}", coord.metrics().report());
    Ok(())
}
