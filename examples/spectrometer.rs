//! Spectrometer: the classic radio-astronomy pipeline (Price 2021) built
//! as ONE TINA graph — `lower::spectrometer` fuses PFB-channelization,
//! power detection (|·|²), and time integration into a single lowered
//! graph, compiled once and run once per frame.  No staged unfold → pfb →
//! host-power calls, no intermediate copies: the compiled plan is
//! asserted copy-free (`materialize_count() == 0`).
//!
//! The input tone drifts across channels over time, so the dumped
//! waterfall shows a moving ridge.
//!
//! ```bash
//! cargo run --release --example spectrometer
//! ```

use anyhow::Result;
use tina::dsp::PfbConfig;
use tina::tensor::Tensor;
use tina::tina::{lower, Arena, ExecPlan};
use tina::util::prng::Xoshiro256;

const P: usize = 32;
const M: usize = 8;
const FRAME: usize = 16384;
const STEPS: usize = 12;

fn main() -> Result<()> {
    let cfg = PfbConfig::new(P, M);
    let ns = cfg.output_spectra(FRAME)?;
    println!("== spectrometer: {STEPS} time steps, P={P}, frame={FRAME} ==\n");

    // ONE compile: the whole instrument — polyphase FIR bank, DFT across
    // branches, squared magnitude, integration over the Ns spectra — is a
    // single graph and a single execution plan
    let graph = lower::spectrometer(1, FRAME, cfg)?;
    let plan = ExecPlan::compile(&graph)?;
    plan.verify()?;
    assert_eq!(
        plan.materialize_count(),
        0,
        "the fused spectrometer plan must be copy-free"
    );
    println!(
        "one-plan spectrometer: {} steps, {} fused, 0 materialized copies\n",
        plan.step_count(),
        plan.fused_steps()
    );

    let mut rng = Xoshiro256::new(99);
    let mut arena = Arena::new();
    let mut waterfall: Vec<Vec<f64>> = Vec::new();

    for step in 0..STEPS {
        // drifting tone: channel center moves 4 -> 15 across the run
        let ch = 4.0 + 11.0 * step as f64 / (STEPS - 1) as f64;
        let mut data = vec![0.0f32; FRAME];
        for (i, v) in data.iter_mut().enumerate() {
            *v = (4.0 * (2.0 * std::f64::consts::PI * ch * i as f64 / P as f64).cos()) as f32
                + rng.normal() * 0.7;
        }
        let frame = Tensor::new(&[1, FRAME], data)?;

        // ONE run: (1, FRAME) in, (1, P) integrated channel power out;
        // the graph sums |X|² over the Ns spectra, the host only
        // normalizes by Ns for display
        let out = plan.run_in(&mut arena, std::slice::from_ref(&frame))?;
        let power: Vec<f64> = (0..P)
            .map(|k| out[0].at(&[0, k]) as f64 / ns as f64)
            .collect();
        waterfall.push(power);
    }

    // render the waterfall (first P/2 channels; real input -> symmetric)
    println!("waterfall (rows = time, cols = channel 0..{}):", P / 2 - 1);
    let peak = waterfall
        .iter()
        .flat_map(|r| r.iter())
        .cloned()
        .fold(0.0, f64::max);
    let glyphs = [' ', '.', ':', '+', '*', '#', '@'];
    for (step, row) in waterfall.iter().enumerate() {
        let line: String = row[..P / 2]
            .iter()
            .map(|&p| {
                let idx = ((p / peak).sqrt() * (glyphs.len() - 1) as f64).round() as usize;
                glyphs[idx.min(glyphs.len() - 1)]
            })
            .collect();
        println!("  t{step:>2} |{line}|");
    }

    // the ridge must drift: peak channel at the last step > at the first
    let peak_ch = |row: &Vec<f64>| -> usize {
        row[..P / 2]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0
    };
    let (first, last) = (peak_ch(&waterfall[0]), peak_ch(&waterfall[STEPS - 1]));
    println!("\npeak channel drifted {first} -> {last}");
    assert!(first <= 5 && last >= 13, "unexpected drift {first} -> {last}");
    println!("drift check: OK");
    Ok(())
}
