//! Spectrometer: the classic radio-astronomy pipeline (Price 2021) built
//! from TINA serving ops — unfold the stream into frames, PFB-channelize
//! each frame, accumulate power, dump a waterfall.
//!
//! Demonstrates composing multiple TINA ops (unfold -> pfb as a
//! [`Pipeline`]-style chain) on a signal whose tone drifts across
//! channels over time, so the waterfall shows a moving ridge.
//!
//! ```bash
//! make artifacts && cargo run --release --example spectrometer
//! ```

use anyhow::Result;
use tina::coordinator::{Coordinator, CoordinatorConfig, OpKind, OpRequest};
use tina::dsp::PfbConfig;
use tina::tensor::Tensor;
use tina::util::prng::Xoshiro256;

const P: usize = 32;
const M: usize = 8;
const FRAME: usize = 16384;
const STEPS: usize = 12;

fn main() -> Result<()> {
    let cfg = PfbConfig::new(P, M);
    let coord = Coordinator::from_dir("artifacts", CoordinatorConfig::default())?;
    let ns = cfg.output_spectra(FRAME)?;
    println!("== spectrometer: {STEPS} time steps, P={P}, frame={FRAME} ==\n");

    let mut rng = Xoshiro256::new(99);
    let mut waterfall: Vec<Vec<f64>> = Vec::new();

    for step in 0..STEPS {
        // drifting tone: channel center moves 4 -> 15 across the run
        let ch = 4.0 + 11.0 * step as f64 / (STEPS - 1) as f64;
        let mut data = vec![0.0f32; FRAME];
        for (i, v) in data.iter_mut().enumerate() {
            *v = (4.0 * (2.0 * std::f64::consts::PI * ch * i as f64 / P as f64).cos()) as f32
                + rng.normal() * 0.7;
        }
        let frame = Tensor::new(&[1, FRAME], data)?;

        // full PFB through the coordinator (artifact if present)
        let resp = coord.execute(OpRequest::new(OpKind::Pfb, vec![frame]))?;
        let (re, im) = (&resp.outputs[0], &resp.outputs[1]);

        // accumulate power over spectra
        let mut power = vec![0.0f64; P];
        for n in 0..ns {
            for k in 0..P {
                let (r, i_) = (re.at(&[0, n, k]), im.at(&[0, n, k]));
                power[k] += (r * r + i_ * i_) as f64 / ns as f64;
            }
        }
        waterfall.push(power);
    }

    // render the waterfall (first P/2 channels; real input -> symmetric)
    println!("waterfall (rows = time, cols = channel 0..{}):", P / 2 - 1);
    let peak = waterfall
        .iter()
        .flat_map(|r| r.iter())
        .cloned()
        .fold(0.0, f64::max);
    let glyphs = [' ', '.', ':', '+', '*', '#', '@'];
    for (step, row) in waterfall.iter().enumerate() {
        let line: String = row[..P / 2]
            .iter()
            .map(|&p| {
                let idx = ((p / peak).sqrt() * (glyphs.len() - 1) as f64).round() as usize;
                glyphs[idx.min(glyphs.len() - 1)]
            })
            .collect();
        println!("  t{step:>2} |{line}|");
    }

    // the ridge must drift: peak channel at the last step > at the first
    let peak_ch = |row: &Vec<f64>| -> usize {
        row[..P / 2]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0
    };
    let (first, last) = (peak_ch(&waterfall[0]), peak_ch(&waterfall[STEPS - 1]));
    println!("\npeak channel drifted {first} -> {last}");
    assert!(first <= 5 && last >= 13, "unexpected drift {first} -> {last}");
    println!("drift check: OK");
    Ok(())
}
