#!/usr/bin/env python3
"""Perf-trajectory regression gate.

Diffs a fresh ``BENCH_exec.json`` (written by ``cargo bench --bench
ablation``) against the latest committed ``BENCH_pr<N>.json`` snapshot at
the repo root and exits non-zero when any ablation's headline metric
regressed by more than the threshold (default 25%).

Comparison rules (per ablation object, top-level numeric fields only —
the per-case breakdowns under ``"cases"`` are informational):

* ``_speedup`` / ``_benefit`` fields -> higher is better, GATED: these
  are same-machine ratios (e.g. interp-vs-planned, batched-vs-solo), so
  they are robust to which runner the job landed on.
* ``_ns`` (lower is better), ``_req_s`` (higher is better) and
  ``fill_ratio`` (higher is better) -> WARN-only by default: absolute
  nanoseconds and requests/second are not comparable across the
  heterogeneous shared runners CI lands on, and fill ratio tracks
  arrival-pattern luck.  ``--gate-absolute`` turns their regressions
  into failures too (for pinned hardware).
* anything else -> ignored

Ablations present in only one of the two files are skipped with a note
(artifact-dependent ablations only run when artifacts exist).  When
auto-selecting the baseline, the highest-numbered *measured* snapshot
wins; ``{"pending": true}`` placeholders are used only if nothing
measured exists, and then pass with a warning (CI's snapshot-commit
step replaces them).

Usage:
    bench_compare.py NEW_JSON [--baseline OLD_JSON] [--threshold 0.25]
                     [--exclude BENCH_prN.json] [--gate-absolute]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys

LOWER_BETTER_SUFFIXES = ("_ns",)
HIGHER_BETTER_SUFFIXES = ("_req_s", "_speedup", "_benefit", "fill_ratio")
# only same-machine ratio metrics hard-fail by default; absolute
# per-runner numbers (_ns, _req_s) and workload-dependent fill_ratio
# merely warn unless --gate-absolute
GATED_SUFFIXES = ("_speedup", "_benefit")

# Headlines an *armed* baseline must carry: --require-armed proves the
# regression gate actually covers these going forward, not merely that
# some measured snapshot exists.  (ablation, top-level field) pairs; the
# listed ablations run on every build (no artifacts needed), so a
# measured snapshot lacking one means the bench silently dropped it.
REQUIRED_ARMED_HEADLINES = (
    ("ablation9_vaccel_backend", "geomean_vaccel_vs_planned_speedup"),
    ("ablation10_new_lowerings", "geomean_staged_vs_fused_spectrometer_speedup"),
    ("ablation10_new_lowerings", "geomean_iir_planned_speedup"),
)


def latest_snapshot(root: pathlib.Path, exclude: str | None) -> pathlib.Path | None:
    """The committed BENCH_pr<N>.json with the highest N, if any.

    ``exclude`` names a snapshot to skip — CI passes the *current* PR's
    own file so the gate always anchors to a snapshot that predates the
    PR, instead of re-baselining against numbers the PR itself committed
    (which would let sub-threshold regressions compound push over push).
    A snapshot whose ``backfilled_by_pr`` equals the excluded PR's number
    is skipped for the same reason: its numbers were measured by that
    PR's own CI run, so anchoring to it would let the PR baseline against
    itself through the backfill side door.
    """
    exclude_n: int | None = None
    if exclude:
        m = re.fullmatch(r"BENCH_pr(\d+)\.json", exclude)
        if m:
            exclude_n = int(m.group(1))
    candidates: list[tuple[int, pathlib.Path]] = []
    for p in root.glob("BENCH_pr*.json"):
        if exclude and p.name == exclude:
            continue
        m = re.fullmatch(r"BENCH_pr(\d+)\.json", p.name)
        if m:
            candidates.append((int(m.group(1)), p))
    candidates.sort(reverse=True)

    def load(p: pathlib.Path) -> dict:
        try:
            d = json.loads(p.read_text())
            return d if isinstance(d, dict) else {}
        except (json.JSONDecodeError, OSError):
            return {}

    noted: set[str] = set()

    def self_baselined(p: pathlib.Path) -> bool:
        if exclude_n is None:
            return False
        if load(p).get("backfilled_by_pr") == exclude_n:
            if p.name not in noted:
                noted.add(p.name)
                print(
                    f"note: skipping {p.name} as baseline — it was backfilled "
                    f"by the excluded PR {exclude_n}'s own measurements"
                )
            return True
        return False

    # the highest-numbered measured snapshot beats any pending placeholder
    # (a stale placeholder with a high N must not disarm the gate forever)
    for _, p in candidates:
        if not load(p).get("pending") and not self_baselined(p):
            return p
    for _, p in candidates:
        if not self_baselined(p):
            return p
    return None


def direction(field: str) -> str | None:
    if field.endswith(LOWER_BETTER_SUFFIXES):
        return "lower"
    if field.endswith(HIGHER_BETTER_SUFFIXES):
        return "higher"
    return None


def compare(old: dict, new: dict, threshold: float, gate_absolute: bool) -> list[str]:
    regressions: list[str] = []
    for ablation, old_metrics in old.items():
        if not isinstance(old_metrics, dict):
            continue
        new_metrics = new.get(ablation)
        if not isinstance(new_metrics, dict):
            print(f"note: ablation '{ablation}' absent from fresh run; skipping")
            continue
        for field, old_v in old_metrics.items():
            d = direction(field)
            if d is None or not isinstance(old_v, (int, float)):
                continue
            new_v = new_metrics.get(field)
            if not isinstance(new_v, (int, float)):
                print(f"note: {ablation}.{field} absent from fresh run; skipping")
                continue
            if old_v <= 0:
                continue
            gated = gate_absolute or field.endswith(GATED_SUFFIXES)
            if d == "lower":
                ratio = new_v / old_v
                regressed = ratio > 1.0 + threshold
                verdict = f"{old_v:.4g} -> {new_v:.4g} ns ({ratio:.2f}x)"
            else:
                ratio = old_v / new_v if new_v > 0 else float("inf")
                regressed = ratio > 1.0 + threshold
                verdict = f"{old_v:.4g} -> {new_v:.4g} ({ratio:.2f}x worse)"
            if regressed and gated:
                status = "REGRESSION"
                regressions.append(f"{ablation}.{field}: {verdict}")
            elif regressed:
                status = "warn"
            else:
                status = "ok"
            print(f"{status:>10}  {ablation}.{field}: {verdict}")
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("new", type=pathlib.Path, help="fresh BENCH_exec.json")
    ap.add_argument(
        "--baseline",
        type=pathlib.Path,
        default=None,
        help="snapshot to diff against (default: latest BENCH_pr<N>.json "
        "next to the fresh file)",
    )
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="allowed fractional regression per metric (default 0.25)",
    )
    ap.add_argument(
        "--exclude",
        default=None,
        help="snapshot filename to skip when auto-selecting the baseline "
        "(CI passes the current PR's own BENCH_pr<N>.json so the gate "
        "never baselines against numbers this PR committed)",
    )
    ap.add_argument(
        "--require-armed",
        action="store_true",
        help="fail (exit 1) when the gate cannot actually gate: no "
        "committed snapshot, or the selected baseline is a pending "
        "placeholder.  CI runs this after the snapshot backfill step to "
        "prove the regression gate is armed for the next run",
    )
    ap.add_argument(
        "--gate-absolute",
        action="store_true",
        help="hard-fail on absolute _ns regressions too (only meaningful "
        "on pinned hardware; shared CI runners should leave this off)",
    )
    args = ap.parse_args()

    if not args.new.exists():
        print(f"error: fresh benchmark file '{args.new}' not found", file=sys.stderr)
        return 2
    baseline = args.baseline or latest_snapshot(args.new.resolve().parent, args.exclude)
    if baseline is None:
        if args.require_armed:
            print(
                "FAIL: regression gate is un-armed — no committed "
                "BENCH_pr<N>.json snapshot to gate against",
                file=sys.stderr,
            )
            return 1
        print("no committed BENCH_pr<N>.json snapshot yet; nothing to gate against")
        return 0
    print(f"baseline: {baseline}")

    old = json.loads(baseline.read_text())
    new = json.loads(args.new.read_text())
    if old.get("pending"):
        if args.require_armed:
            print(
                f"FAIL: regression gate is un-armed — baseline {baseline} "
                "is still a pending placeholder (the backfill step should "
                "have replaced it with measured numbers)",
                file=sys.stderr,
            )
            return 1
        print(
            "baseline snapshot is marked pending (no measured numbers "
            "committed yet); passing — CI's snapshot step will replace it"
        )
        return 0

    if args.require_armed:
        missing = [
            f"{abl}.{field}"
            for abl, field in REQUIRED_ARMED_HEADLINES
            if not isinstance(old.get(abl), dict) or field not in old[abl]
        ]
        if missing:
            print(
                f"FAIL: regression gate is un-armed — baseline {baseline} "
                f"lacks required headline(s): {', '.join(missing)}",
                file=sys.stderr,
            )
            return 1

    regressions = compare(old, new, args.threshold, args.gate_absolute)
    if regressions:
        print(
            f"\nFAIL: {len(regressions)} metric(s) regressed more than "
            f"{args.threshold:.0%}:",
            file=sys.stderr,
        )
        for r in regressions:
            print(f"  {r}", file=sys.stderr)
        return 1
    print(f"\nOK: no metric regressed more than {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
