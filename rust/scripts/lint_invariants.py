#!/usr/bin/env python3
"""Repo-invariant structural lints over rust/src (run in CI).

Grep-resistant invariants the type system cannot express:

1. **No raw thread spawns outside the owners.**  `std::thread::spawn`
   (detached, panic-swallowing) and `thread::Builder` spawns are allowed
   only in the modules that own thread lifecycles: the TCP server
   (per-connection threads), the thread/exec pools, and the coordinator
   service (its single named drain-loop thread, joined on shutdown).
   Everything else must submit work to the exec pool — batch execution
   in particular must never regress to detached per-batch threads.

1b. **`spawn_batch_exec` is retired.**  The detached per-batch
   execution helper was replaced by the bounded, panic-isolating
   `ExecPool`; the identifier must not reappear anywhere (tests
   included) — resurrecting it would silently undo panic containment.

2. **No bare `.unwrap()` on the coordinator serving paths.**  In
   `rust/src/coordinator/`, `.unwrap()` is allowed only for mutex /
   condvar poisoning results (`.lock()`, `.wait(`, `wait_timeout(` on
   the same chain) — a poisoned lock is already a crashed process.
   Everything else must use `.expect("...")` with a message documenting
   the invariant, or propagate the error.

3. **No timing calls inside kernel inner loops.**  `Instant::now()` in
   the hot kernel files (`tina/exec/fused.rs`, `baselines/optimized.rs`)
   would perturb the very numbers the benchmarks measure; timing belongs
   to the callers (benchkit, coordinator metrics).

4. **Every `unsafe` is justified.**  Each `unsafe` keyword must carry a
   `// SAFETY:` comment on the same line or in the contiguous comment
   block immediately above it (companion to
   `#![deny(unsafe_op_in_unsafe_fn)]` in lib.rs).

5. **Tensor data never serializes as decimal JSON outside the compat
   path.**  `Json::Arr` construction is allowed only in the JSON value
   model itself (`util/json.rs`) and the server's debug/compat surface
   (`coordinator/server.rs`, `tensor_to_json` and the session compat
   replies).  Anywhere else, bulk f32 samples must ride the binary wire
   protocol (`coordinator/wire.rs`, raw little-endian bytes) — a
   `Json::Arr` of samples in a new module would silently regress the
   hot path to decimal text formatting.

Test code (`#[cfg(test)]` and below — test modules sit at the bottom of
their files in this repo) is exempt from rules 1-3 and 5 but not from
rule 4.

Exit status: 0 clean, 1 violations (printed one per line), 2 usage error.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

SPAWN_ALLOWLIST = {
    "coordinator/server.rs",  # per-connection threads, capped and reaped
    "coordinator/service.rs",  # the drain-loop thread, joined on shutdown
    "runtime/handle.rs",  # the single engine thread, joined on Drop
    "runtime/vaccel.rs",  # the virtual accelerator's bounded worker set
    "util/threadpool.rs",  # the pools own their workers
}

# matches both `std::thread::spawn(...)` and the `std::thread::Builder`
# named-thread form (the builder line, not the `.spawn(` call, so plain
# `.spawn(` receivers like EngineHandle::spawn stay out of scope)
THREAD_SPAWN_RE = re.compile(r"thread::spawn|thread::Builder")

KERNEL_NO_TIMING = {
    "tina/exec/fused.rs",
    "tina/exec/linear.rs",
    "baselines/optimized.rs",
}

UNSAFE_RE = re.compile(r"\bunsafe\b")
POISON_CHAIN_RE = re.compile(r"\.lock\(\)|\.wait\(|wait_timeout\(")

# rule 5: the only modules allowed to build JSON arrays (the value model
# itself, and the server's debug/compat mode — the one place tensor data
# may serialize as decimal text)
JSON_ARR_ALLOWLIST = {
    "util/json.rs",
    "coordinator/server.rs",
}


def strip_comments_and_strings(line: str) -> str:
    """Drop line comments and string literal contents (crude but
    sufficient: the codebase has no multi-line /* */ comments and no
    string containing `// `)."""
    line = re.sub(r'"(?:[^"\\]|\\.)*"', '""', line)
    cut = line.find("//")
    return line if cut < 0 else line[:cut]


def test_boundary(lines: list[str]) -> int:
    """First line index of `#[cfg(test)]`, or len(lines).  Test modules
    live at the bottom of their files in this repo, so everything after
    the marker is test code."""
    for i, line in enumerate(lines):
        if "#[cfg(test)]" in line:
            return i
    return len(lines)


def lint_file(root: Path, path: Path) -> list[str]:
    rel = path.relative_to(root / "src").as_posix()
    lines = path.read_text().splitlines()
    boundary = test_boundary(lines)
    errors: list[str] = []

    def err(i: int, msg: str) -> None:
        errors.append(f"{path.relative_to(root.parent)}:{i + 1}: {msg}")

    for i, raw in enumerate(lines):
        code = strip_comments_and_strings(raw)
        in_test = i >= boundary

        # rule 1: raw thread spawns
        if (
            not in_test
            and THREAD_SPAWN_RE.search(code)
            and rel not in SPAWN_ALLOWLIST
        ):
            err(i, "thread spawn outside server.rs/service.rs/"
                   "threadpool.rs (submit work to the exec pool instead "
                   "of spawning threads)")

        # rule 1b: the retired detached per-batch helper must not return
        # (checked in test code too — even a test resurrecting it would
        # re-normalize detached batch execution)
        if "spawn_batch_exec" in code:
            err(i, "spawn_batch_exec is retired (batch execution goes "
                   "through the bounded, panic-isolating ExecPool)")

        # rule 2: bare unwrap on coordinator serving paths
        if not in_test and rel.startswith("coordinator/") and ".unwrap()" in code:
            # multi-line method chains: the receiver may sit on the
            # previous non-empty line(s)
            chain = code
            j = i
            while j > 0 and not POISON_CHAIN_RE.search(chain) and \
                    chain.lstrip().startswith("."):
                j -= 1
                chain = strip_comments_and_strings(lines[j]) + chain
            if not POISON_CHAIN_RE.search(chain):
                err(i, "bare .unwrap() on a coordinator serving path "
                       "(use .expect(\"why this cannot fail\") or propagate)")

        # rule 3: timing inside kernels
        if not in_test and rel in KERNEL_NO_TIMING and "Instant::now" in code:
            err(i, "Instant::now() in a kernel file (timing belongs to "
                   "benchkit / coordinator metrics, not inner loops)")

        # rule 5: Json::Arr construction outside the compat path — bulk
        # samples must use the binary wire protocol, never decimal text
        if not in_test and "Json::Arr" in code and rel not in JSON_ARR_ALLOWLIST:
            err(i, "Json::Arr outside util/json.rs / coordinator/server.rs "
                   "(tensor data rides the binary wire protocol; decimal "
                   "JSON text is the server's debug/compat mode only)")

        # rule 4: undocumented unsafe — accept SAFETY: on the same line
        # or anywhere in the contiguous comment block directly above
        if UNSAFE_RE.search(code):
            ok = "SAFETY:" in raw
            j = i - 1
            while not ok and j >= 0 and lines[j].lstrip().startswith("//"):
                if "SAFETY:" in lines[j]:
                    ok = True
                j -= 1
            if not ok:
                err(i, "unsafe without a // SAFETY: comment on the same "
                       "line or in the comment block above")

    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent  # rust/
    src = root / "src"
    if not src.is_dir():
        print(f"error: {src} not found", file=sys.stderr)
        return 2
    errors: list[str] = []
    for path in sorted(src.rglob("*.rs")):
        errors.extend(lint_file(root, path))
    if errors:
        print(f"FAIL: {len(errors)} repo-invariant violation(s):",
              file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print("repo invariants hold (thread spawns, exec-pool ownership, "
          "coordinator unwraps, kernel timing, unsafe documentation, "
          "Json::Arr compat-path containment)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
