//! Direct-form IIR recurrence reference for the unrolled-iteration
//! lowering (`tina::lower::iir`).
//!
//! The lowering unrolls a fixed number of Richardson-style iterations of
//! the recurrence on the accelerator substrate; this module computes the
//! recurrence's exact fixed point on the CPU so property tests can bound
//! the unrolling's truncation error.
//!
//! Convention (anti-causal, prefix-aligned — chosen because the graph
//! substrate's `StridedSlice` crops prefixes):
//!
//! ```text
//! ff[n] = Σ_k b[k] · x[n + k]                 (correlation, valid mode)
//! y[n]  = ff[n] − Σ_{j=1..na} a[j−1] · y[n + j],   y[m ≥ W0] = 0
//! ```
//!
//! with `W0 = L − len(b) + 1`.  Solved backward from `n = W0 − 1`, this
//! is the limit the depth-`d` unrolled graph approaches: each unroll
//! level applies one more substitution starting from `y⁽⁰⁾ = ff`, so on
//! the surviving output prefix the error contracts like `‖a‖₁^d` when
//! `‖a‖₁ < 1`.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Exact fixed point of the anti-causal IIR recurrence, per batch row.
///
/// Input `(B, L)`, output `(B, L − len(b_taps) + 1)`.  All arithmetic in
/// f32, feedforward taps accumulated in ascending-tap order to match the
/// conv kernel's oracle reduction order.
pub fn iir_reference(x: &Tensor, b_taps: &[f32], a_taps: &[f32]) -> Result<Tensor> {
    if x.rank() != 2 {
        bail!("iir_reference expects (B, L), got {:?}", x.shape());
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    let (mb, na) = (b_taps.len(), a_taps.len());
    if mb == 0 || na == 0 {
        bail!("iir_reference needs nonempty feedforward and feedback taps");
    }
    if l < mb {
        bail!("signal length {l} shorter than feedforward filter {mb}");
    }
    let w0 = l - mb + 1;
    let mut out = Tensor::zeros(&[b, w0]);
    for bi in 0..b {
        let row = &x.data()[bi * l..(bi + 1) * l];
        let mut ff = vec![0.0f32; w0];
        for (n, f) in ff.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (k, &bk) in b_taps.iter().enumerate() {
                acc += bk * row[n + k];
            }
            *f = acc;
        }
        let mut y = vec![0.0f32; w0];
        for n in (0..w0).rev() {
            let mut acc = ff[n];
            for (j, &aj) in a_taps.iter().enumerate() {
                let m = n + j + 1;
                if m < w0 {
                    acc -= aj * y[m];
                }
            }
            y[n] = acc;
        }
        out.data_mut()[bi * w0..(bi + 1) * w0].copy_from_slice(&y);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pure_feedforward_matches_fir() {
        // a single zero feedback tap degenerates to plain correlation
        let x = Tensor::new(&[1, 6], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let y = iir_reference(&x, &[0.5, 0.25], &[0.0]).unwrap();
        let want: Vec<f32> = (0..5).map(|n| 0.5 * (n as f32 + 1.0) + 0.25 * (n as f32 + 2.0)).collect();
        assert_eq!(y.data(), &want[..]);
    }

    #[test]
    fn recurrence_feeds_back_future_outputs() {
        // W0 = 3, a = [0.5]: y[2] = ff[2]; y[1] = ff[1] − 0.5·y[2]; …
        let x = Tensor::new(&[1, 3], vec![1.0, 2.0, 4.0]).unwrap();
        let y = iir_reference(&x, &[1.0], &[0.5]).unwrap();
        let y2 = 4.0f32;
        let y1 = 2.0 - 0.5 * y2;
        let y0 = 1.0 - 0.5 * y1;
        assert_eq!(y.data(), &[y0, y1, y2]);
    }

    #[test]
    fn rejects_bad_configs() {
        let x = Tensor::zeros(&[1, 4]);
        assert!(iir_reference(&x, &[], &[0.5]).is_err());
        assert!(iir_reference(&x, &[0.5], &[]).is_err());
        assert!(iir_reference(&Tensor::zeros(&[1, 2]), &[0.5; 3], &[0.1]).is_err());
        assert!(iir_reference(&Tensor::zeros(&[4]), &[0.5], &[0.1]).is_err());
    }
}
