//! Fourier substrate: DFT matrices (the TINA kernels), a direct O(n^2) DFT
//! (the NumPy-naive analog) and an iterative radix-2 FFT (the CuPy/
//! optimized analog).

use crate::tensor::{ComplexTensor, Tensor};
use anyhow::{bail, Result};
use std::f64::consts::PI;

/// DFM F[l, k] = exp(-2 pi i l k / n) as (re, im) f32 matrices — the
/// pointwise-conv kernel of paper §4.1.
pub fn dft_matrix(n: usize) -> (Tensor, Tensor) {
    let mut re = vec![0.0f32; n * n];
    let mut im = vec![0.0f32; n * n];
    for l in 0..n {
        for k in 0..n {
            let ang = -2.0 * PI * (l as f64) * (k as f64) / n as f64;
            re[l * n + k] = ang.cos() as f32;
            im[l * n + k] = ang.sin() as f32;
        }
    }
    (
        Tensor::new(&[n, n], re).unwrap(),
        Tensor::new(&[n, n], im).unwrap(),
    )
}

/// IDFM IF[k, j] = exp(+2 pi i k j / n) / n — paper §4.2.
pub fn idft_matrix(n: usize) -> (Tensor, Tensor) {
    let mut re = vec![0.0f32; n * n];
    let mut im = vec![0.0f32; n * n];
    for k in 0..n {
        for j in 0..n {
            let ang = 2.0 * PI * (k as f64) * (j as f64) / n as f64;
            re[k * n + j] = (ang.cos() / n as f64) as f32;
            im[k * n + j] = (ang.sin() / n as f64) as f32;
        }
    }
    (
        Tensor::new(&[n, n], re).unwrap(),
        Tensor::new(&[n, n], im).unwrap(),
    )
}

/// Direct O(n^2) DFT of each row of a (B, N) complex tensor, accumulating
/// in f64 — the numerically-trustworthy oracle.
pub fn dft_direct(x: &ComplexTensor) -> Result<ComplexTensor> {
    if x.re.rank() != 2 {
        bail!("dft_direct expects (B, N), got {:?}", x.re.shape());
    }
    let (b, n) = (x.shape()[0], x.shape()[1]);
    let mut out_re = vec![0.0f32; b * n];
    let mut out_im = vec![0.0f32; b * n];
    for bi in 0..b {
        let row_re = &x.re.data()[bi * n..(bi + 1) * n];
        let row_im = &x.im.data()[bi * n..(bi + 1) * n];
        for k in 0..n {
            let (mut sr, mut si) = (0.0f64, 0.0f64);
            for l in 0..n {
                let ang = -2.0 * PI * (l as f64) * (k as f64) / n as f64;
                let (c, s) = (ang.cos(), ang.sin());
                let (xr, xi) = (row_re[l] as f64, row_im[l] as f64);
                sr += xr * c - xi * s;
                si += xr * s + xi * c;
            }
            out_re[bi * n + k] = sr as f32;
            out_im[bi * n + k] = si as f32;
        }
    }
    ComplexTensor::new(
        Tensor::new(&[b, n], out_re)?,
        Tensor::new(&[b, n], out_im)?,
    )
}

/// Iterative radix-2 Cooley-Tukey FFT over each row of a (B, N) complex
/// tensor.  N must be a power of two.  This is the "vendor library" path
/// of the optimized CPU baseline.
pub fn fft_radix2(x: &ComplexTensor) -> Result<ComplexTensor> {
    if x.re.rank() != 2 {
        bail!("fft_radix2 expects (B, N), got {:?}", x.re.shape());
    }
    let (b, n) = (x.shape()[0], x.shape()[1]);
    if !n.is_power_of_two() {
        bail!("fft_radix2 needs power-of-two length, got {n}");
    }
    let mut re = x.re.data().to_vec();
    let mut im = x.im.data().to_vec();

    // Precompute twiddles for the largest stage once per call.
    let mut tw_re = vec![0.0f32; n / 2];
    let mut tw_im = vec![0.0f32; n / 2];
    for i in 0..n / 2 {
        let ang = -2.0 * PI * i as f64 / n as f64;
        tw_re[i] = ang.cos() as f32;
        tw_im[i] = ang.sin() as f32;
    }

    let levels = n.trailing_zeros();
    for bi in 0..b {
        let re = &mut re[bi * n..(bi + 1) * n];
        let im = &mut im[bi * n..(bi + 1) * n];
        // bit-reversal permutation
        for i in 0..n {
            let j = (i.reverse_bits() >> (usize::BITS - levels)) & (n - 1);
            if j > i {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        // butterflies
        let mut len = 2;
        while len <= n {
            let half = len / 2;
            let stride = n / len; // twiddle step into the n/2 table
            let mut start = 0;
            while start < n {
                for k in 0..half {
                    let (wr, wi) = (tw_re[k * stride], tw_im[k * stride]);
                    let (i0, i1) = (start + k, start + k + half);
                    let (ar, ai) = (re[i0], im[i0]);
                    let (br, bi_) = (re[i1], im[i1]);
                    let tr = br * wr - bi_ * wi;
                    let ti = br * wi + bi_ * wr;
                    re[i0] = ar + tr;
                    im[i0] = ai + ti;
                    re[i1] = ar - tr;
                    im[i1] = ai - ti;
                }
                start += len;
            }
            len <<= 1;
        }
    }
    ComplexTensor::new(Tensor::new(&[b, n], re)?, Tensor::new(&[b, n], im)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randc(b: usize, n: usize, seed: u64) -> ComplexTensor {
        ComplexTensor::new(Tensor::randn(&[b, n], seed), Tensor::randn(&[b, n], seed + 1))
            .unwrap()
    }

    #[test]
    fn dft_matrix_first_row_is_ones() {
        let (re, im) = dft_matrix(8);
        for k in 0..8 {
            assert!((re.at(&[0, k]) - 1.0).abs() < 1e-6);
            assert!(im.at(&[0, k]).abs() < 1e-6);
        }
    }

    #[test]
    fn dft_of_impulse_is_flat() {
        let mut x = ComplexTensor::from_real(Tensor::zeros(&[1, 16]));
        x.re.set(&[0, 0], 1.0);
        let z = dft_direct(&x).unwrap();
        for k in 0..16 {
            assert!((z.re.at(&[0, k]) - 1.0).abs() < 1e-5);
            assert!(z.im.at(&[0, k]).abs() < 1e-5);
        }
    }

    #[test]
    fn dft_of_single_tone_peaks_at_bin() {
        let n = 32;
        let data: Vec<f32> = (0..n)
            .map(|i| (2.0 * PI * 5.0 * i as f64 / n as f64).cos() as f32)
            .collect();
        let x = ComplexTensor::from_real(Tensor::new(&[1, n], data).unwrap());
        let z = dft_direct(&x).unwrap();
        let p = z.power();
        let peak = (0..n).max_by(|&a, &b| p.at(&[0, a]).total_cmp(&p.at(&[0, b]))).unwrap();
        assert!(peak == 5 || peak == n - 5, "peak at {peak}");
    }

    #[test]
    fn fft_matches_direct_dft() {
        for n in [2usize, 4, 8, 64, 256] {
            let x = randc(2, n, 33);
            let want = dft_direct(&x).unwrap();
            let got = fft_radix2(&x).unwrap();
            assert!(
                got.allclose(&want, 1e-3, 1e-3),
                "n={n} max diff re {}",
                got.re.max_abs_diff(&want.re).unwrap()
            );
        }
    }

    #[test]
    fn fft_rejects_non_power_of_two() {
        let x = randc(1, 12, 1);
        assert!(fft_radix2(&x).is_err());
    }

    #[test]
    fn idft_inverts_dft() {
        let n = 16;
        let x = randc(1, n, 7);
        let z = dft_direct(&x).unwrap();
        let (ifr, ifi) = idft_matrix(n);
        let back = z.matmul(&ComplexTensor::new(ifr, ifi).unwrap()).unwrap();
        assert!(back.allclose(&x, 1e-4, 1e-4));
    }

    #[test]
    fn dft_matrix_matches_direct() {
        let n = 8;
        let x = randc(1, n, 21);
        let (fr, fi) = dft_matrix(n);
        let via_mat = x.matmul(&ComplexTensor::new(fr, fi).unwrap()).unwrap();
        let direct = dft_direct(&x).unwrap();
        assert!(via_mat.allclose(&direct, 1e-4, 1e-4));
    }
}
