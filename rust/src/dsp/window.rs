//! Window functions (f64 internally, matching python/compile/coeffs.py).

use std::f64::consts::PI;

/// Hamming window: w[i] = 0.54 - 0.46 cos(2 pi i / (n-1)).
pub fn hamming(n: usize) -> Vec<f64> {
    if n == 1 {
        return vec![1.0];
    }
    (0..n)
        .map(|i| 0.54 - 0.46 * (2.0 * PI * i as f64 / (n - 1) as f64).cos())
        .collect()
}

/// Hann window: w[i] = 0.5 - 0.5 cos(2 pi i / (n-1)).
pub fn hann(n: usize) -> Vec<f64> {
    if n == 1 {
        return vec![1.0];
    }
    (0..n)
        .map(|i| 0.5 - 0.5 * (2.0 * PI * i as f64 / (n - 1) as f64).cos())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hamming_endpoints_and_peak() {
        let w = hamming(11);
        assert!((w[0] - 0.08).abs() < 1e-12);
        assert!((w[10] - 0.08).abs() < 1e-12);
        assert!((w[5] - 1.0).abs() < 1e-12); // midpoint of odd-length window
    }

    #[test]
    fn hann_endpoints_zero() {
        let w = hann(9);
        assert!(w[0].abs() < 1e-12);
        assert!(w[8].abs() < 1e-12);
        assert!((w[4] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn symmetry() {
        for n in [4usize, 5, 16, 33] {
            let w = hamming(n);
            for i in 0..n {
                assert!((w[i] - w[n - 1 - i]).abs() < 1e-12, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn length_one() {
        assert_eq!(hamming(1), vec![1.0]);
        assert_eq!(hann(1), vec![1.0]);
    }
}
