//! Polyphase filter bank reference implementation (paper §5.2, Eq. 20).
//!
//! This is the ground-truth the TINA artifacts, the rust interpreter and
//! both CPU baselines are all validated against, written the clearest
//! possible way (f64 accumulation, no tricks).

use super::firdesign::{pfb_prototype, polyphase_decompose};
use crate::tensor::{ComplexTensor, Tensor};
use anyhow::{bail, Result};

/// PFB configuration shared across implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PfbConfig {
    /// Branch / channel count P.
    pub branches: usize,
    /// Taps per branch M.
    pub taps_per_branch: usize,
}

impl PfbConfig {
    /// Configuration with P branches and M taps per branch.
    pub fn new(branches: usize, taps_per_branch: usize) -> Self {
        Self {
            branches,
            taps_per_branch,
        }
    }

    /// Spectra produced from a signal of length `len` (valid convolution).
    pub fn output_spectra(&self, len: usize) -> Result<usize> {
        if len % self.branches != 0 {
            bail!(
                "signal length {len} not divisible by {} branches",
                self.branches
            );
        }
        let nspec = len / self.branches;
        if nspec < self.taps_per_branch {
            bail!(
                "signal too short: {nspec} samples/branch < {} taps",
                self.taps_per_branch
            );
        }
        Ok(nspec - self.taps_per_branch + 1)
    }

    /// The polyphase bank h_p(m), row-major (P, M).
    pub fn bank(&self) -> Result<Vec<f32>> {
        let proto = pfb_prototype(self.branches, self.taps_per_branch)?;
        polyphase_decompose(&proto, self.branches)
    }
}

/// Reference polyphase FIR bank (Fig. 3 left column): returns (B, P, Ns')
/// subfiltered signals, f64 accumulation.
///
/// y_p(n') = sum_m h_p(m) x_p(n' - m), valid range only.
pub fn pfb_fir_reference(x: &Tensor, cfg: PfbConfig) -> Result<Tensor> {
    if x.rank() != 2 {
        bail!("pfb_fir_reference expects (B, L), got {:?}", x.shape());
    }
    let (b, l) = (x.shape()[0], x.shape()[1]);
    let (p, m) = (cfg.branches, cfg.taps_per_branch);
    let ns_out = cfg.output_spectra(l)?;
    let nspec = l / p;
    let bank = cfg.bank()?; // (P, M)

    let mut out = Tensor::zeros(&[b, p, ns_out]);
    for bi in 0..b {
        for pi in 0..p {
            for n in 0..ns_out {
                // valid convolution starting at n + M - 1
                let mut acc = 0.0f64;
                for t in 0..m {
                    // x_p(n') = x[n' * P + p]
                    let np = n + m - 1 - t;
                    debug_assert!(np < nspec);
                    let xv = x.data()[bi * l + np * p + pi] as f64;
                    acc += bank[pi * m + t] as f64 * xv;
                }
                out.data_mut()[(bi * p + pi) * ns_out + n] = acc as f32;
            }
        }
    }
    Ok(out)
}

/// Reference full PFB (Fig. 3 right column): FIR bank + DFT across
/// branches.  Returns (B, Ns', P) complex spectra.
pub fn pfb_reference(x: &Tensor, cfg: PfbConfig) -> Result<ComplexTensor> {
    let y = pfb_fir_reference(x, cfg)?; // (B, P, Ns')
    let (b, p, ns) = (y.shape()[0], y.shape()[1], y.shape()[2]);
    let mut out_re = Tensor::zeros(&[b, ns, p]);
    let mut out_im = Tensor::zeros(&[b, ns, p]);
    for bi in 0..b {
        for n in 0..ns {
            for k in 0..p {
                let (mut sr, mut si) = (0.0f64, 0.0f64);
                for pi in 0..p {
                    let ang =
                        -2.0 * std::f64::consts::PI * (pi as f64) * (k as f64) / p as f64;
                    let v = y.data()[(bi * p + pi) * ns + n] as f64;
                    sr += v * ang.cos();
                    si += v * ang.sin();
                }
                out_re.data_mut()[(bi * ns + n) * p + k] = sr as f32;
                out_im.data_mut()[(bi * ns + n) * p + k] = si as f32;
            }
        }
    }
    ComplexTensor::new(out_re, out_im)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_spectra_counting() {
        let cfg = PfbConfig::new(4, 3);
        assert_eq!(cfg.output_spectra(40).unwrap(), 8); // 10 spectra - 3 + 1
        assert!(cfg.output_spectra(41).is_err()); // not divisible
        assert!(cfg.output_spectra(8).is_err()); // too short
    }

    #[test]
    fn dc_signal_passes_dc_branch_only() {
        // A constant signal: every branch FIR outputs sum(h_p); the DFT
        // across branches then concentrates power in bin 0 since
        // sum_p sum_m h_p(m) = sum h = 1.
        let cfg = PfbConfig::new(8, 4);
        let x = Tensor::ones(&[1, 8 * 16]);
        let z = pfb_reference(&x, cfg).unwrap();
        let ns = cfg.output_spectra(8 * 16).unwrap();
        for n in 0..ns {
            let dc = z.re.at(&[0, n, 0]);
            assert!((dc - 1.0).abs() < 1e-4, "dc bin {dc}");
            for k in 1..8 {
                // branch DC gains differ by tiny window asymmetries, so the
                // non-DC bins see ~1e-3-amplitude leakage, not exact zero
                let p = z.re.at(&[0, n, k]).powi(2) + z.im.at(&[0, n, k]).powi(2);
                assert!(p < 1e-4, "bin {k} power {p}");
            }
        }
    }

    #[test]
    fn tone_lands_in_matching_channel() {
        // Tone at channel-3 center frequency: f = 3 / P (cycles/sample).
        let p = 8;
        let cfg = PfbConfig::new(p, 4);
        let l = p * 64;
        let data: Vec<f32> = (0..l)
            .map(|i| {
                (2.0 * std::f64::consts::PI * 3.0 * i as f64 / p as f64).cos() as f32
            })
            .collect();
        let x = Tensor::new(&[1, l], data).unwrap();
        let z = pfb_reference(&x, cfg).unwrap();
        let ns = cfg.output_spectra(l).unwrap();
        // average channel powers over spectra
        let mut power = vec![0.0f64; p];
        for n in 0..ns {
            for k in 0..p {
                power[k] +=
                    (z.re.at(&[0, n, k]).powi(2) + z.im.at(&[0, n, k]).powi(2)) as f64;
            }
        }
        let peak = (0..p).max_by(|&a, &b| power[a].total_cmp(&power[b])).unwrap();
        // real tone -> bins 3 and P-3
        assert!(peak == 3 || peak == p - 3, "peak channel {peak}: {power:?}");
    }

    #[test]
    fn batch_rows_independent() {
        let cfg = PfbConfig::new(4, 2);
        let x0 = Tensor::randn(&[1, 64], 5);
        let x1 = Tensor::randn(&[1, 64], 6);
        let both = Tensor::concat(&[&x0, &x1], 0).unwrap();
        let z = pfb_reference(&both, cfg).unwrap();
        let z0 = pfb_reference(&x0, cfg).unwrap();
        let z1 = pfb_reference(&x1, cfg).unwrap();
        let ns = cfg.output_spectra(64).unwrap();
        assert!(z
            .re
            .slice_axis(0, 0, 1)
            .unwrap()
            .allclose(&z0.re, 1e-6, 1e-6));
        assert!(z
            .re
            .slice_axis(0, 1, 2)
            .unwrap()
            .allclose(&z1.re, 1e-6, 1e-6));
        assert_eq!(z.shape(), &[2, ns, 4]);
    }
}
