//! DSP reference substrate: window functions, FIR design, Fourier
//! transforms and polyphase filter-bank coefficients.
//!
//! `firdesign` mirrors `python/compile/coeffs.py` closed-form for closed-
//! form (both compute in f64, cast to f32 at the end) so the rust runtime
//! can regenerate the exact weights that were baked into the AOT artifacts.

pub mod firdesign;
pub mod fourier;
pub mod iir;
pub mod pfb;
pub mod window;

pub use firdesign::{fir_lowpass, pfb_prototype, polyphase_decompose};
pub use fourier::{dft_direct, dft_matrix, fft_radix2, idft_matrix};
pub use iir::iir_reference;
pub use pfb::{pfb_reference, PfbConfig};
pub use window::{hamming, hann};
