//! FIR filter design — closed forms identical to python/compile/coeffs.py
//! so the runtime regenerates exactly the weights baked into the AOT
//! artifacts (f64 math, f32 cast at the end; cross-language tests compare
//! with float tolerance).

use super::window::hamming;
use anyhow::{bail, Result};

/// Normalized sinc: sin(pi x) / (pi x).
pub fn sinc(x: f64) -> f64 {
    if x == 0.0 {
        1.0
    } else {
        let px = std::f64::consts::PI * x;
        px.sin() / px
    }
}

/// Hamming-windowed-sinc lowpass FIR with unit DC gain.
///
/// `cutoff` is the normalized frequency in (0, 0.5] (1.0 = sample rate).
pub fn fir_lowpass(num_taps: usize, cutoff: f64) -> Result<Vec<f32>> {
    if !(0.0 < cutoff && cutoff <= 0.5) {
        bail!("cutoff {cutoff} outside (0, 0.5]");
    }
    if num_taps == 0 {
        bail!("num_taps must be positive");
    }
    let center = (num_taps - 1) as f64 / 2.0;
    let w = hamming(num_taps);
    let mut h: Vec<f64> = (0..num_taps)
        .map(|n| 2.0 * cutoff * sinc(2.0 * cutoff * (n as f64 - center)) * w[n])
        .collect();
    let s: f64 = h.iter().sum();
    for v in &mut h {
        *v /= s;
    }
    Ok(h.into_iter().map(|v| v as f32).collect())
}

/// Prototype lowpass for a P-branch polyphase filter bank (cutoff at the
/// channel width 1/P, length P*M, unit DC gain) — Price 2021 design.
pub fn pfb_prototype(branches: usize, taps_per_branch: usize) -> Result<Vec<f32>> {
    if branches == 0 || taps_per_branch == 0 {
        bail!("branches and taps_per_branch must be positive");
    }
    let length = branches * taps_per_branch;
    let center = (length - 1) as f64 / 2.0;
    let w = hamming(length);
    let mut h: Vec<f64> = (0..length)
        .map(|n| sinc((n as f64 - center) / branches as f64) * w[n])
        .collect();
    let s: f64 = h.iter().sum();
    for v in &mut h {
        *v /= s;
    }
    Ok(h.into_iter().map(|v| v as f32).collect())
}

/// Split a prototype h (P*M) into the branch bank h_p(m) = h[m*P + p].
/// Returns row-major (P, M).
pub fn polyphase_decompose(h: &[f32], branches: usize) -> Result<Vec<f32>> {
    if h.len() % branches != 0 {
        bail!(
            "prototype length {} not divisible by branch count {}",
            h.len(),
            branches
        );
    }
    let m = h.len() / branches;
    let mut out = vec![0.0f32; h.len()];
    for p in 0..branches {
        for t in 0..m {
            out[p * m + t] = h[t * branches + p];
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowpass_unit_dc_gain() {
        let h = fir_lowpass(64, 0.25).unwrap();
        let s: f64 = h.iter().map(|&x| x as f64).sum();
        assert!((s - 1.0).abs() < 1e-6, "DC gain {s}");
    }

    #[test]
    fn lowpass_symmetric() {
        let h = fir_lowpass(33, 0.1).unwrap();
        for i in 0..h.len() {
            assert!((h[i] - h[h.len() - 1 - i]).abs() < 1e-7);
        }
    }

    #[test]
    fn lowpass_attenuates_high_freq() {
        // frequency response at DC vs Nyquist
        let h = fir_lowpass(64, 0.1).unwrap();
        let resp = |f: f64| -> f64 {
            let (mut re, mut im) = (0.0, 0.0);
            for (n, &v) in h.iter().enumerate() {
                let ang = -2.0 * std::f64::consts::PI * f * n as f64;
                re += v as f64 * ang.cos();
                im += v as f64 * ang.sin();
            }
            (re * re + im * im).sqrt()
        };
        assert!((resp(0.0) - 1.0).abs() < 1e-6);
        assert!(resp(0.45) < 1e-3, "stopband leak {}", resp(0.45));
    }

    #[test]
    fn invalid_args_rejected() {
        assert!(fir_lowpass(0, 0.2).is_err());
        assert!(fir_lowpass(8, 0.0).is_err());
        assert!(fir_lowpass(8, 0.6).is_err());
        assert!(pfb_prototype(0, 4).is_err());
    }

    #[test]
    fn polyphase_decompose_layout() {
        // h = [0..8), P=4, M=2: h_p(m) = h[m*4+p]
        let h: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let bank = polyphase_decompose(&h, 4).unwrap();
        // branch p=0: [0, 4]; p=1: [1, 5]; ...
        assert_eq!(bank, vec![0., 4., 1., 5., 2., 6., 3., 7.]);
        assert!(polyphase_decompose(&h, 3).is_err());
    }

    #[test]
    fn prototype_sums_to_one() {
        let h = pfb_prototype(32, 8).unwrap();
        assert_eq!(h.len(), 256);
        let s: f64 = h.iter().map(|&x| x as f64).sum();
        assert!((s - 1.0).abs() < 1e-6);
    }
}
