//! `tina` — leader binary for the TINA serving runtime.
//!
//! Subcommands:
//!   info                         platform + artifact inventory
//!   validate [--op <op>]         cross-check artifacts vs the interpreter
//!   run <artifact> [--seed N]    execute one artifact on random input
//!   serve [--addr HOST:PORT]     TCP JSON-line server
//!   bench-smoke                  tiny end-to-end sanity benchmark
//!
//! Global options: --artifacts <dir> (default: ./artifacts)

use anyhow::{anyhow, bail, Context, Result};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use tina::coordinator::{Coordinator, CoordinatorConfig, ImplPref, OpKind, OpRequest};
use tina::runtime::{Engine, Registry};
use tina::tensor::Tensor;
use tina::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("tina: error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => info(args),
        Some("validate") => validate(args),
        Some("run") => run(args),
        Some("serve") => serve(args),
        Some("bench-smoke") => bench_smoke(args),
        Some(other) => bail!("unknown subcommand '{other}' (try: info, validate, run, serve, bench-smoke)"),
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}

fn usage() -> &'static str {
    "tina — TINA serving runtime (rust + JAX + Pallas reproduction)\n\
     \n\
     usage: tina <subcommand> [options]\n\
     \n\
     subcommands:\n\
       info          platform + artifact inventory\n\
       validate      cross-check artifacts against the rust interpreter\n\
       run <name>    execute one artifact on seeded random input\n\
       serve         TCP JSON-line server (--addr 127.0.0.1:7070)\n\
       bench-smoke   tiny end-to-end sanity benchmark\n\
     \n\
     options:\n\
       --artifacts <dir>   artifact directory (default ./artifacts)\n\
       --addr <host:port>  serve address\n\
       --op <op>           restrict validate to one op\n\
       --seed <n>          input seed for run\n\
       --no-batching       disable the dynamic batcher"
}

fn artifact_dir(args: &Args) -> String {
    args.opt_or("artifacts", "artifacts").to_string()
}

fn info(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let registry = Registry::load(&dir)
        .with_context(|| tina::coordinator::service::missing_artifacts_hint(dir.as_ref()))?;
    registry.check_files()?;
    let engine = Engine::new(registry.clone())?;
    println!("platform:  {}", engine.platform());
    println!("artifacts: {} ({})", registry.len(), dir);
    let mut by_op: std::collections::BTreeMap<&str, usize> = Default::default();
    for e in registry.entries() {
        *by_op.entry(e.op.as_str()).or_default() += 1;
    }
    for (op, n) in by_op {
        println!("  {op:<10} {n} variants");
    }
    Ok(())
}

/// Cross-check every (or one op's) tina artifact against the interpreter.
fn validate(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let op_filter = args.opt("op");
    let engine = Engine::from_dir(&dir)
        .with_context(|| tina::coordinator::service::missing_artifacts_hint(dir.as_ref()))?;
    let registry = engine.registry().clone();
    let router = tina::coordinator::Router::new(registry.clone(), Default::default());

    let mut checked = 0;
    let mut skipped = 0;
    for meta in registry.entries() {
        if meta.impl_ != "tina" || meta.dtype != "f32" {
            skipped += 1;
            continue;
        }
        if let Some(f) = op_filter {
            if meta.op != f {
                continue;
            }
        }
        let op = OpKind::parse(&meta.op)?;
        let inputs: Vec<Tensor> = meta
            .inputs
            .iter()
            .enumerate()
            .map(|(i, spec)| Tensor::randn(&spec.shape, 42 + i as u64))
            .collect();
        let got = engine.execute(&meta.name, &inputs)?;
        let req = OpRequest::new(op, inputs.clone()).with_impl(ImplPref::Interp);
        let target = router.route(&req)?;
        let tina::coordinator::Target::Interp { key } = target else {
            bail!("interp route expected");
        };
        let want = router.interpreter(&key, &req)?.run(&inputs)?;
        if got.len() != want.len() {
            bail!("{}: output arity {} vs {}", meta.name, got.len(), want.len());
        }
        for (g, w) in got.iter().zip(&want) {
            let ok = g.allclose(w, 2e-3, 2e-3);
            if !ok {
                bail!(
                    "{}: PJRT vs interpreter mismatch (max abs diff {})",
                    meta.name,
                    g.max_abs_diff(w).unwrap_or(f32::NAN)
                );
            }
        }
        println!("ok  {}", meta.name);
        checked += 1;
    }
    println!("validated {checked} artifacts ({skipped} non-tina/f32 skipped)");
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let name = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: tina run <artifact-name>"))?;
    let seed = args.opt_usize("seed", 42)? as u64;
    let engine = Engine::from_dir(&dir)?;
    let meta = engine
        .registry()
        .get(name)
        .ok_or_else(|| anyhow!("unknown artifact '{name}' (see `tina info`)"))?
        .clone();
    let inputs: Vec<Tensor> = meta
        .inputs
        .iter()
        .enumerate()
        .map(|(i, spec)| Tensor::randn(&spec.shape, seed + i as u64))
        .collect();
    let t0 = std::time::Instant::now();
    let outputs = engine.execute(name, &inputs)?;
    let dt = t0.elapsed();
    println!("artifact: {name}");
    println!("first-run (incl. compile): {dt:?}");
    let t1 = std::time::Instant::now();
    let _ = engine.execute(name, &inputs)?;
    println!("second-run (cached exe):   {:?}", t1.elapsed());
    for (i, o) in outputs.iter().enumerate() {
        let preview: Vec<f32> = o.data().iter().take(4).copied().collect();
        println!("output[{i}]: shape {:?}, head {:?}", o.shape(), preview);
    }
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let addr = args.opt_or("addr", "127.0.0.1:7070").to_string();
    let config = CoordinatorConfig {
        batching: !args.flag("no-batching"),
        ..Default::default()
    };
    let coord = Arc::new(
        Coordinator::from_dir(&dir, config)
            .with_context(|| tina::coordinator::service::missing_artifacts_hint(dir.as_ref()))?,
    );
    let warmed = coord.warmup(None)?;
    eprintln!("tina: warmed {warmed} executables");
    let stop = Arc::new(AtomicBool::new(false));
    tina::coordinator::server::serve(coord, &addr, stop)
}

/// Tiny smoke benchmark: one op through every path (artifact if present,
/// interpreter, naive, optimized).
fn bench_smoke(args: &Args) -> Result<()> {
    let dir = artifact_dir(args);
    let cfg = tina::benchkit::BenchConfig::quick();
    let x = Tensor::randn(&[1, 4096], 7);
    let taps = tina::dsp::fir_lowpass(64, 0.25)?;

    let mut table = tina::benchkit::Table::new(
        "bench-smoke: fir L=4096 (median)",
        &["impl", "median", "speedup vs naive"],
    );
    let naive = tina::benchkit::run(&cfg, || {
        tina::benchkit::black_box(tina::baselines::naive::fir(&x, &taps).unwrap());
    })
    .summary();
    let opt = tina::benchkit::run(&cfg, || {
        tina::benchkit::black_box(tina::baselines::optimized::fir(&x, &taps).unwrap());
    })
    .summary();
    table.row(vec![
        "naive".into(),
        tina::util::histogram::fmt_ns(naive.median_ns as u64),
        "1.0x".into(),
    ]);
    table.row(vec![
        "optimized".into(),
        tina::util::histogram::fmt_ns(opt.median_ns as u64),
        format!("{:.1}x", opt.speedup_vs(&naive)),
    ]);

    if let Ok(engine) = Engine::from_dir(&dir) {
        if engine.registry().get("fir_tina_f32_B1_L4096").is_some() {
            engine.prepare("fir_tina_f32_B1_L4096")?;
            let stats = tina::benchkit::run(&cfg, || {
                tina::benchkit::black_box(
                    engine
                        .execute("fir_tina_f32_B1_L4096", std::slice::from_ref(&x))
                        .unwrap(),
                );
            })
            .summary();
            table.row(vec![
                "tina (PJRT)".into(),
                tina::util::histogram::fmt_ns(stats.median_ns as u64),
                format!("{:.1}x", stats.speedup_vs(&naive)),
            ]);
        }
    }
    print!("{}", table.render());
    Ok(())
}
