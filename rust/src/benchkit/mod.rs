//! Benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock over warmup + N timed iterations, reports
//! mean/median/p95/stddev, and renders the paper-style tables the
//! `rust/benches/*` binaries print.  The measurement protocol mirrors the
//! paper's: average over repeated runs, input data already resident
//! (uploads excluded from the timed region when the runner says so).

mod stats;
mod table;

pub use stats::{Stats, Summary};
pub use table::{csv_escape, fmt_ns, Table};

use std::time::{Duration, Instant};

/// Measurement configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Untimed warmup iterations before measurement.
    pub warmup_iters: usize,
    /// Timed iterations.
    pub iters: usize,
    /// Hard cap on total measurement time per case.
    pub max_total: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            iters: 30,
            max_total: Duration::from_secs(10),
        }
    }
}

impl BenchConfig {
    /// Paper protocol: 100 timed iterations (use `quick` for CI).
    pub fn paper() -> Self {
        Self {
            warmup_iters: 5,
            iters: 100,
            max_total: Duration::from_secs(30),
        }
    }

    /// CI profile: few iterations, tight time cap.
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            iters: 5,
            max_total: Duration::from_secs(2),
        }
    }

    /// Honour TINA_BENCH_PROFILE=quick|default|paper (CI knob).
    pub fn from_env() -> Self {
        match std::env::var("TINA_BENCH_PROFILE").as_deref() {
            Ok("quick") => Self::quick(),
            Ok("paper") => Self::paper(),
            _ => Self::default(),
        }
    }
}

/// Time `f` under `cfg`, returning per-iteration statistics.
///
/// `f` is called once per iteration; it should perform exactly one unit of
/// the benchmarked work and must not be optimized away (return something
/// and let the caller black-box it, or mutate state).
pub fn run(cfg: &BenchConfig, mut f: impl FnMut()) -> Stats {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    let start = Instant::now();
    for _ in 0..cfg.iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if start.elapsed() > cfg.max_total && samples.len() >= 3 {
            break;
        }
    }
    Stats::from_durations(&samples)
}

/// Prevent the optimizer from discarding a computed value.
/// (std::hint::black_box is stable since 1.66.)
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_collects_requested_iters() {
        let cfg = BenchConfig {
            warmup_iters: 2,
            iters: 10,
            max_total: Duration::from_secs(5),
        };
        let mut calls = 0usize;
        let stats = run(&cfg, || {
            calls += 1;
        });
        assert_eq!(calls, 12); // warmup + timed
        assert_eq!(stats.n, 10);
    }

    #[test]
    fn run_respects_time_cap() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            iters: 1_000_000,
            max_total: Duration::from_millis(50),
        };
        let stats = run(&cfg, || std::thread::sleep(Duration::from_millis(5)));
        assert!(stats.n >= 3 && stats.n < 1000, "n={}", stats.n);
    }

    #[test]
    fn timing_is_plausible() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            iters: 5,
            max_total: Duration::from_secs(5),
        };
        let stats = run(&cfg, || std::thread::sleep(Duration::from_millis(10)));
        assert!(stats.mean_ns() >= 9.0e6, "mean {}", stats.mean_ns());
    }
}
