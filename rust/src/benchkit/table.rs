//! Plain-text and CSV table rendering for benchmark reports.

/// A simple left-aligned text table with an optional CSV dump.
#[derive(Debug, Default, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width"
        );
        self.rows.push(cells);
    }

    /// True when no rows were added.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, (cell, w)) in cells.iter().zip(widths).enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{cell:<w$}"));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len().saturating_sub(1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (RFC-4180 quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| csv_escape(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter()
                    .map(|c| csv_escape(c))
                    .collect::<Vec<_>>()
                    .join(","),
            );
            out.push('\n');
        }
        out
    }
}

/// Quote a CSV field if needed.
pub fn csv_escape(s: &str) -> String {
    if s.contains([',', '"', '\n']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Format a nanosecond quantity with an adaptive unit (re-exported from
/// the histogram module for bench reports).
pub fn fmt_ns(ns: f64) -> String {
    crate::util::histogram::fmt_ns(ns.max(0.0) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name  2.5"));
        // header padded to column width
        assert!(s.contains("name       value"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_quoting() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("q\"q"), "\"q\"\"q\"");
        let mut t = Table::new("", &["h1", "h,2"]);
        t.row(vec!["v1".into(), "v\n2".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("h1,\"h,2\"\n"));
        assert!(csv.contains("v1,\"v\n2\""));
    }
}
