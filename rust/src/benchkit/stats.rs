//! Sample statistics for benchmark timings.

use std::time::Duration;

/// Statistics over one benchmark case's per-iteration durations.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Number of samples.
    pub n: usize,
    sorted_ns: Vec<u64>,
    sum_ns: u128,
}

impl Stats {
    /// Statistics over a set of per-iteration durations.
    pub fn from_durations(samples: &[Duration]) -> Stats {
        let mut sorted_ns: Vec<u64> = samples
            .iter()
            .map(|d| d.as_nanos().min(u64::MAX as u128) as u64)
            .collect();
        sorted_ns.sort_unstable();
        let sum_ns = sorted_ns.iter().map(|&x| x as u128).sum();
        Stats {
            n: sorted_ns.len(),
            sorted_ns,
            sum_ns,
        }
    }

    /// Mean duration in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.n as f64
    }

    /// Median duration in nanoseconds.
    pub fn median_ns(&self) -> f64 {
        self.quantile_ns(0.5)
    }

    /// Linear-interpolated quantile, q in [0, 1].
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let pos = q.clamp(0.0, 1.0) * (self.n - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.sorted_ns[lo] as f64 * (1.0 - frac) + self.sorted_ns[hi] as f64 * frac
    }

    /// Fastest iteration in nanoseconds.
    pub fn min_ns(&self) -> f64 {
        self.sorted_ns.first().map(|&x| x as f64).unwrap_or(0.0)
    }

    /// Slowest iteration in nanoseconds.
    pub fn max_ns(&self) -> f64 {
        self.sorted_ns.last().map(|&x| x as f64).unwrap_or(0.0)
    }

    /// Sample standard deviation in nanoseconds.
    pub fn stddev_ns(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let mean = self.mean_ns();
        let var: f64 = self
            .sorted_ns
            .iter()
            .map(|&x| (x as f64 - mean).powi(2))
            .sum::<f64>()
            / (self.n - 1) as f64;
        var.sqrt()
    }

    /// Flatten into a copyable summary row.
    pub fn summary(&self) -> Summary {
        Summary {
            n: self.n,
            mean_ns: self.mean_ns(),
            median_ns: self.median_ns(),
            p95_ns: self.quantile_ns(0.95),
            stddev_ns: self.stddev_ns(),
            min_ns: self.min_ns(),
            max_ns: self.max_ns(),
        }
    }
}

/// Flattened summary row (what tables and EXPERIMENTS.md record).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Number of samples.
    pub n: usize,
    /// Mean in nanoseconds.
    pub mean_ns: f64,
    /// Median in nanoseconds.
    pub median_ns: f64,
    /// 95th percentile in nanoseconds.
    pub p95_ns: f64,
    /// Sample standard deviation in nanoseconds.
    pub stddev_ns: f64,
    /// Fastest iteration in nanoseconds.
    pub min_ns: f64,
    /// Slowest iteration in nanoseconds.
    pub max_ns: f64,
}

impl Summary {
    /// Speedup of `baseline` over `self` (how many times faster self is).
    pub fn speedup_vs(&self, baseline: &Summary) -> f64 {
        if self.median_ns == 0.0 {
            return f64::INFINITY;
        }
        baseline.median_ns / self.median_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats_of(ns: &[u64]) -> Stats {
        Stats::from_durations(&ns.iter().map(|&x| Duration::from_nanos(x)).collect::<Vec<_>>())
    }

    #[test]
    fn mean_median_of_known_set() {
        let s = stats_of(&[10, 20, 30, 40, 50]);
        assert_eq!(s.mean_ns(), 30.0);
        assert_eq!(s.median_ns(), 30.0);
        assert_eq!(s.min_ns(), 10.0);
        assert_eq!(s.max_ns(), 50.0);
    }

    #[test]
    fn median_interpolates_even_n() {
        let s = stats_of(&[10, 20, 30, 40]);
        assert_eq!(s.median_ns(), 25.0);
    }

    #[test]
    fn quantile_bounds() {
        let s = stats_of(&[5, 1, 9, 3, 7]); // unsorted input
        assert_eq!(s.quantile_ns(0.0), 1.0);
        assert_eq!(s.quantile_ns(1.0), 9.0);
    }

    #[test]
    fn stddev_of_constant_is_zero() {
        let s = stats_of(&[42, 42, 42]);
        assert_eq!(s.stddev_ns(), 0.0);
    }

    #[test]
    fn speedup_ratio() {
        let fast = stats_of(&[100, 100, 100]).summary();
        let slow = stats_of(&[400, 400, 400]).summary();
        assert_eq!(fast.speedup_vs(&slow), 4.0);
        assert_eq!(slow.speedup_vs(&fast), 0.25);
    }

    #[test]
    fn empty_is_safe() {
        let s = stats_of(&[]);
        assert_eq!(s.mean_ns(), 0.0);
        assert_eq!(s.median_ns(), 0.0);
        assert_eq!(s.stddev_ns(), 0.0);
    }
}
