//! Composite pipelines: chains of serving ops executed stage by stage,
//! keeping intermediate tensors host-side between artifact executions.
//!
//! The paper's PFB use case is the canonical pipeline: `pfb_fir -> dft`
//! (Fig. 3 right column built from the left column plus a Fourier stage).
//! The fused `pfb` artifact exists too; the `ablation` bench compares the
//! fused graph against this two-stage chain to quantify fusion benefit
//! (DESIGN.md §7/L2).

use super::request::{ImplPref, OpKind, OpRequest, Precision};
use super::service::Coordinator;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// One pipeline stage: an op plus routing preferences.
#[derive(Debug, Clone)]
pub struct Stage {
    pub op: OpKind,
    pub impl_pref: ImplPref,
    pub precision: Precision,
}

impl Stage {
    pub fn new(op: OpKind) -> Stage {
        Stage {
            op,
            impl_pref: ImplPref::Auto,
            precision: Precision::F32,
        }
    }
}

/// A linear pipeline over serving ops.
///
/// Stage outputs feed the next stage's inputs positionally; multi-output
/// stages (dft, pfb) feed multi-input stages (idft) naturally.
#[derive(Debug, Clone, Default)]
pub struct Pipeline {
    pub stages: Vec<Stage>,
}

impl Pipeline {
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    pub fn then(mut self, stage: Stage) -> Pipeline {
        self.stages.push(stage);
        self
    }

    /// The paper's PFB as a two-stage chain (FIR bank, then DFT across
    /// branches).  Input: (B, L) signal; output: (re, im) spectra.
    pub fn pfb_two_stage() -> Pipeline {
        Pipeline::new()
            .then(Stage::new(OpKind::PfbFir))
            .then(Stage::new(OpKind::Dft))
    }

    /// Execute the pipeline through a coordinator.
    pub fn run(&self, coord: &Coordinator, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        if self.stages.is_empty() {
            bail!("empty pipeline");
        }
        let mut current = inputs;
        for (i, stage) in self.stages.iter().enumerate() {
            // glue: pfb_fir produces (B, P, Ns); a following dft consumes
            // (rows, P) — flatten spectra-major
            if i > 0 && stage.op == OpKind::Dft && current.len() == 1 && current[0].rank() == 3
            {
                let t = &current[0];
                let (b, p, ns) = (t.shape()[0], t.shape()[1], t.shape()[2]);
                let rows = t.permute3([0, 2, 1])?.into_reshape(&[b * ns, p])?;
                current = vec![rows];
            }
            let req = OpRequest {
                op: stage.op,
                impl_pref: stage.impl_pref,
                precision: stage.precision,
                inputs: current,
            };
            let resp = coord.execute(req)?;
            current = resp.outputs;
        }
        Ok(current)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_stages() {
        let p = Pipeline::pfb_two_stage();
        assert_eq!(p.stages.len(), 2);
        assert_eq!(p.stages[0].op, OpKind::PfbFir);
        assert_eq!(p.stages[1].op, OpKind::Dft);
    }

    #[test]
    fn empty_pipeline_is_invalid() {
        // constructing is fine; running requires a coordinator, so only the
        // static shape is checked here (run() is covered in integration
        // tests with a live engine)
        let p = Pipeline::new();
        assert!(p.stages.is_empty());
    }
}
