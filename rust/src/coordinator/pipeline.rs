//! Composite pipelines: chains of serving ops executed stage by stage,
//! keeping intermediate tensors host-side between artifact executions.
//!
//! The paper's PFB use case is the canonical pipeline: `pfb_fir -> dft`
//! (Fig. 3 right column built from the left column plus a Fourier stage).
//! The fused `pfb` artifact exists too; the `ablation` bench compares the
//! fused graph against this two-stage chain to quantify fusion benefit
//! (DESIGN.md §7/L2).
//!
//! Concurrency invariant: [`Pipeline::run_many`] submits every stage-i
//! request before awaiting any, so co-arriving same-shape stages coalesce
//! in the coordinator's batchers.  Batched requests complete directly
//! from the drain-side scatter (no thread-pool worker is parked per
//! request), so the number of concurrently in-flight pipeline items is
//! bounded only by the coordinator's in-flight-batched limit — not by its
//! worker-pool size.

use super::request::{ImplPref, OpKind, OpRequest, Precision};
use super::service::Coordinator;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// One pipeline stage: an op plus routing preferences.
#[derive(Debug, Clone)]
pub struct Stage {
    /// The op this stage executes.
    pub op: OpKind,
    /// Implementation preference forwarded to the router.
    pub impl_pref: ImplPref,
    /// Compute precision forwarded to the router.
    pub precision: Precision,
}

impl Stage {
    /// Stage with default routing (`Auto`, f32).
    pub fn new(op: OpKind) -> Stage {
        Stage {
            op,
            impl_pref: ImplPref::Auto,
            precision: Precision::F32,
        }
    }
}

/// A linear pipeline over serving ops.
///
/// Stage outputs feed the next stage's inputs positionally; multi-output
/// stages (dft, pfb) feed multi-input stages (idft) naturally.
#[derive(Debug, Clone, Default)]
pub struct Pipeline {
    /// Stages in execution order.
    pub stages: Vec<Stage>,
}

impl Pipeline {
    /// Empty pipeline (stages are appended with [`Pipeline::then`]).
    pub fn new() -> Pipeline {
        Pipeline::default()
    }

    /// Append a stage.
    pub fn then(mut self, stage: Stage) -> Pipeline {
        self.stages.push(stage);
        self
    }

    /// The paper's PFB as a two-stage chain (FIR bank, then DFT across
    /// branches).  Input: (B, L) signal; output: (re, im) spectra.
    pub fn pfb_two_stage() -> Pipeline {
        Pipeline::new()
            .then(Stage::new(OpKind::PfbFir))
            .then(Stage::new(OpKind::Dft))
    }

    /// Execute the pipeline through a coordinator: the degenerate
    /// single-item case of [`Pipeline::run_many`].
    pub fn run(&self, coord: &Coordinator, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let mut out = self.run_many(coord, vec![inputs])?;
        Ok(out.pop().expect("one item in, one item out"))
    }

    /// Execute the pipeline for many independent items concurrently.
    ///
    /// All stage-i requests are submitted before any is awaited, so
    /// co-arriving same-shape stages coalesce in the coordinator's
    /// batchers — fallback stages in the shape-bucketed batcher, artifact
    /// stages in the artifact batcher.  Because batched replies are
    /// completed from the drain-side scatter rather than relayed through
    /// parked workers, submitting more items than the coordinator has
    /// worker threads is safe and expected.  Outputs come back in item
    /// order; the first failing item aborts the pipeline with its error.
    pub fn run_many(
        &self,
        coord: &Coordinator,
        items: Vec<Vec<Tensor>>,
    ) -> Result<Vec<Vec<Tensor>>> {
        if self.stages.is_empty() {
            bail!("empty pipeline");
        }
        let mut current = items;
        for (i, stage) in self.stages.iter().enumerate() {
            // glue: pfb_fir produces (B, P, Ns); a following dft consumes
            // (rows, P) — flatten spectra-major
            if i > 0 && stage.op == OpKind::Dft {
                for item in current.iter_mut() {
                    if item.len() == 1 && item[0].rank() == 3 {
                        let t = &item[0];
                        let (b, p, ns) = (t.shape()[0], t.shape()[1], t.shape()[2]);
                        let rows = t.permute3([0, 2, 1])?.into_reshape(&[b * ns, p])?;
                        *item = vec![rows];
                    }
                }
            }
            let slots: Vec<_> = current
                .drain(..)
                .map(|inputs| {
                    coord.submit(OpRequest {
                        op: stage.op,
                        impl_pref: stage.impl_pref,
                        precision: stage.precision,
                        inputs,
                        deadline: None,
                    })
                })
                .collect();
            current = slots
                .into_iter()
                .map(|s| s.wait().map(|resp| resp.outputs))
                .collect::<Result<Vec<_>>>()?;
        }
        Ok(current)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::CoordinatorConfig;
    use crate::runtime::Registry;
    use crate::tensor::Tensor;

    fn empty_coordinator(batching: bool) -> Coordinator {
        let registry = Registry::from_manifest_text(
            std::path::PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap();
        Coordinator::new(
            registry,
            CoordinatorConfig {
                batching,
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn run_many_matches_run_per_item() {
        // concurrent multi-item execution (stages coalescing in the
        // shape-bucketed batcher) must return exactly what per-item runs
        // return — batching is a throughput choice, not a numeric one
        let coord = empty_coordinator(true);
        let p = Pipeline::pfb_two_stage();
        let l = 32 * 40; // router default pfb: 32 branches, 8 taps
        let items: Vec<Vec<Tensor>> = (0..3)
            .map(|i| vec![Tensor::randn(&[1, l], i)])
            .collect();
        let many = p.run_many(&coord, items.clone()).unwrap();
        assert_eq!(many.len(), items.len());
        for (item, got) in items.into_iter().zip(many) {
            let want = p.run(&coord, item).unwrap();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a, b, "run_many diverged from per-item run");
            }
        }
    }

    #[test]
    fn run_many_handles_more_items_than_workers() {
        // the lifted in-flight cap at the pipeline layer: far more
        // concurrent items than the 2-worker pool could ever park relay
        // closures for — all must complete through drain-side scatter
        let coord = empty_coordinator(true);
        let p = Pipeline::pfb_two_stage();
        let l = 32 * 40;
        let items: Vec<Vec<Tensor>> = (0..12)
            .map(|i| vec![Tensor::randn(&[1, l], 100 + i)])
            .collect();
        let many = p.run_many(&coord, items).unwrap();
        assert_eq!(many.len(), 12);
        let m = coord.metrics();
        use std::sync::atomic::Ordering;
        assert_eq!(
            m.inflight_batched_requests.load(Ordering::Relaxed),
            0,
            "in-flight gauge must settle once the pipeline drains"
        );
        assert_eq!(
            m.drain_completions.load(Ordering::Relaxed),
            m.batched_fallback_requests.load(Ordering::Relaxed),
            "batched stage replies must come from the drain scatter"
        );
    }

    #[test]
    fn builder_chains_stages() {
        let p = Pipeline::pfb_two_stage();
        assert_eq!(p.stages.len(), 2);
        assert_eq!(p.stages[0].op, OpKind::PfbFir);
        assert_eq!(p.stages[1].op, OpKind::Dft);
    }

    #[test]
    fn empty_pipeline_is_invalid() {
        // constructing is fine; running requires a coordinator, so only the
        // static shape is checked here (run() is covered in integration
        // tests with a live engine)
        let p = Pipeline::new();
        assert!(p.stages.is_empty());
    }
}
