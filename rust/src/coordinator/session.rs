//! Streaming sessions: long-lived per-stream state for chunked signals.
//!
//! A session lifts the overlap-carry idiom of `examples/fir_streaming.rs`
//! into the coordinator: the client pushes an unbounded signal in chunks,
//! the session prepends the carried tail (the last `overlap` samples of
//! everything seen so far) to each chunk, runs the combined signal
//! through the normal serving path — so every chunk rides the planned /
//! batched engine like any other request — and keeps the new tail for the
//! next push.
//!
//! **Overlap-carry invariant:** for a FIR of `T` taps, `overlap = T - 1`.
//! Output element `i` of a valid convolution is a fixed-order dot product
//! of samples `i..i+T` and depends on nothing else, so running the filter
//! over `[carry | chunk]` produces exactly the continuation of the
//! one-shot run — and because the repo's kernels fix the per-element
//! reduction order regardless of signal length or batch (the standing
//! interpreter-oracle contract), the concatenated chunked outputs equal
//! the one-shot output **bit-for-bit**, not just approximately.  The
//! protocol tests pin this.
//!
//! Failed pushes leave the session untouched (carry and counters update
//! only after a successful execution), so a client may retry a chunk
//! after a transient error — a shed deadline, an overloaded gate —
//! without corrupting the stream.

use super::request::OpKind;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Limits on streaming-session admission.
#[derive(Debug, Clone, Copy)]
pub struct SessionConfig {
    /// Most sessions open at once across all connections; `session_open`
    /// fails fast at the cap instead of growing per-stream state
    /// unboundedly.
    pub max_sessions: usize,
    /// Most samples a single push may carry (beyond it the push is
    /// refused before any tensor is built).
    pub max_chunk_samples: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        Self {
            max_sessions: 256,
            max_chunk_samples: 1 << 22,
        }
    }
}

/// Lifetime totals of one closed session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionSummary {
    /// Chunks pushed.
    pub chunks: u64,
    /// Input samples consumed.
    pub samples_in: u64,
    /// Output samples produced.
    pub samples_out: u64,
}

/// The output of one successful push.
#[derive(Debug, Clone)]
pub struct SessionChunk {
    /// Zero-based index of the pushed chunk within its session.
    pub index: u64,
    /// Output samples (empty while the session is still accumulating its
    /// first `overlap` samples).
    pub samples: Vec<f32>,
}

/// Per-stream state: the op, the carried tail, and lifetime counters.
#[derive(Debug)]
pub(crate) struct StreamSession {
    /// The op this session streams.
    pub(crate) op: OpKind,
    /// Samples carried between pushes (at most `overlap`).
    pub(crate) carry: Vec<f32>,
    /// Tail length the op requires (`taps - 1` for FIR).
    pub(crate) overlap: usize,
    /// Chunks pushed so far.
    pub(crate) chunks: u64,
    /// Input samples consumed so far.
    pub(crate) samples_in: u64,
    /// Output samples produced so far.
    pub(crate) samples_out: u64,
}

/// Registry of open sessions.  The map lock is held only for
/// lookup/insert/remove; each session has its own mutex, held across the
/// push's execution so pushes into one session serialize (the carry makes
/// them order-dependent) while different sessions push concurrently.
#[derive(Debug)]
pub struct SessionManager {
    sessions: Mutex<HashMap<u64, Arc<Mutex<StreamSession>>>>,
    next_id: AtomicU64,
    config: SessionConfig,
}

impl SessionManager {
    /// Empty manager enforcing `config`'s caps.
    pub fn new(config: SessionConfig) -> SessionManager {
        SessionManager {
            sessions: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            config,
        }
    }

    /// The admission limits this manager enforces.
    pub fn config(&self) -> &SessionConfig {
        &self.config
    }

    /// Open a session for `op` with the given overlap; returns its id.
    /// Fails fast when [`SessionConfig::max_sessions`] are already open.
    pub(crate) fn open(&self, op: OpKind, overlap: usize) -> Result<u64> {
        let mut map = self.sessions.lock().unwrap();
        if map.len() >= self.config.max_sessions {
            bail!(
                "session limit reached ({} open); close one or retry later",
                self.config.max_sessions
            );
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        map.insert(
            id,
            Arc::new(Mutex::new(StreamSession {
                op,
                carry: Vec::new(),
                overlap,
                chunks: 0,
                samples_in: 0,
                samples_out: 0,
            })),
        );
        Ok(id)
    }

    /// Look up an open session (the map lock is released before the
    /// caller locks the session itself).
    pub(crate) fn checkout(&self, id: u64) -> Result<Arc<Mutex<StreamSession>>> {
        self.sessions
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| anyhow!("unknown session {id}"))
    }

    /// Close a session and return its lifetime totals.
    pub(crate) fn close(&self, id: u64) -> Result<SessionSummary> {
        let sess = self
            .sessions
            .lock()
            .unwrap()
            .remove(&id)
            .ok_or_else(|| anyhow!("unknown session {id}"))?;
        let s = sess.lock().unwrap();
        Ok(SessionSummary {
            chunks: s.chunks,
            samples_in: s.samples_in,
            samples_out: s.samples_out,
        })
    }

    /// Drop every open session (coordinator shutdown); returns how many
    /// were dropped.
    pub fn clear(&self) -> usize {
        let mut map = self.sessions.lock().unwrap();
        let n = map.len();
        map.clear();
        n
    }

    /// Number of sessions currently open.
    pub fn active(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_checkout_close_lifecycle() {
        let m = SessionManager::new(SessionConfig::default());
        let id = m.open(OpKind::Fir, 63).unwrap();
        assert_eq!(m.active(), 1);
        let sess = m.checkout(id).unwrap();
        {
            let mut s = sess.lock().unwrap();
            s.chunks = 3;
            s.samples_in = 100;
            s.samples_out = 37;
        }
        let summary = m.close(id).unwrap();
        assert_eq!(
            summary,
            SessionSummary {
                chunks: 3,
                samples_in: 100,
                samples_out: 37
            }
        );
        assert_eq!(m.active(), 0);
        assert!(m.checkout(id).is_err(), "closed session is gone");
        assert!(m.close(id).is_err(), "double close is an error");
    }

    #[test]
    fn session_cap_fails_fast_and_ids_are_unique() {
        let m = SessionManager::new(SessionConfig {
            max_sessions: 2,
            ..Default::default()
        });
        let a = m.open(OpKind::Fir, 63).unwrap();
        let b = m.open(OpKind::Fir, 63).unwrap();
        assert_ne!(a, b);
        assert!(m.open(OpKind::Fir, 63).is_err(), "cap must refuse");
        m.close(a).unwrap();
        assert!(m.open(OpKind::Fir, 63).is_ok(), "slot freed by close");
    }

    #[test]
    fn clear_drops_everything() {
        let m = SessionManager::new(SessionConfig::default());
        for _ in 0..3 {
            m.open(OpKind::Fir, 63).unwrap();
        }
        assert_eq!(m.clear(), 3);
        assert_eq!(m.active(), 0);
    }
}
