//! Request router: resolves an [`OpRequest`] to an execution target —
//! a compiled PJRT artifact when one matches the request signature, or a
//! pure-rust fallback plan.
//!
//! Fallback execution is two-tiered: the serving path runs on the planned
//! executor ([`Planned`], compiled once per (op, shape signature) and
//! cached), while the naive [`Interpreter`] stays available as the
//! cross-check oracle for tests and `tina validate`.  Both caches share
//! the same [`PlanKey`] signature.
//!
//! # Per-bucket LRU accounting invariant
//!
//! The plan caches are LRU maps bounded by
//! [`RouterConfig::plan_cache_cap`], and the cap counts **per-bucket
//! entries**: the batch dimension participates in [`PlanKey`], so every
//! `(op, per-item shape, bucket size B)` combination the shape-bucketed
//! batcher compiles occupies — and is evicted as — its own entry.
//! Evictions are accumulated in a counter the coordinator drains into
//! [`Metrics::plan_cache_evictions`](super::metrics::Metrics); callers
//! sizing the cap must multiply their distinct (op, shape) signatures by
//! the bucket fan-out (|{1, 2, 4, 8}| by default).

use super::request::{ImplPref, OpKind, OpRequest, Precision};
use crate::dsp::PfbConfig;
use crate::runtime::Registry;
use crate::tina::{lower, CompileOptions, ExecPlan, Interpreter, Planned};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Bound on tracked quarantine entries: adversarial shape churn must not
/// grow the map without limit (the entry closest to parole is dropped).
const QUARANTINE_CAP: usize = 256;

/// Bound on the per-key arm-latency table (same churn argument).
const LATENCY_CAP: usize = 256;

/// EWMA weight of a fresh latency sample (the first sample seeds the
/// average directly).
const LATENCY_ALPHA: f64 = 0.2;

/// Fixed op parameters that are baked into artifacts as NN weights; the
/// interpreter fallback regenerates the same values (DESIGN.md §6).
/// Mirrors python/compile/model.py.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// FIR low-pass filter length (taps).
    pub fir_taps: usize,
    /// FIR cutoff as a fraction of Nyquist.
    pub fir_cutoff: f64,
    /// Sliding-window length of the `unfold` op.
    pub unfold_window: usize,
    /// Polyphase filter bank geometry (branches, taps per branch).
    pub pfb: PfbConfig,
    /// STFT FFT length.
    pub stft_nfft: usize,
    /// STFT hop between frames.
    pub stft_hop: usize,
    /// IIR feedforward taps (numerator `b`).
    pub iir_b: Vec<f32>,
    /// IIR feedback taps (denominator `a`, past-output coefficients).
    /// Kept contractive (‖a‖₁ < 1) so the fixed-depth unrolling below
    /// converges geometrically.
    pub iir_a: Vec<f32>,
    /// Unroll depth of the IIR recurrence (paper §3: iterative functions
    /// become fixed-depth layer stacks).
    pub iir_depth: usize,
    /// Beamformer per-channel integer delays (taps of the one-hot delay
    /// kernel); the channel count of a `Beamform` request must equal
    /// `beam_delays.len()`.
    pub beam_delays: Vec<usize>,
    /// Beamformer per-channel gains, same length as
    /// [`beam_delays`](Self::beam_delays).
    pub beam_gains: Vec<f32>,
    /// Upper bound on cached fallback plans per cache (interpreter oracle
    /// and planned executor each).  Shape-diverse traffic evicts the
    /// least-recently-used plan instead of growing without limit; plans
    /// hold baked constants (a DFT matrix is O(n^2) floats), so an
    /// unbounded map is a slow memory leak under adversarial shapes.
    ///
    /// The cap counts **per-bucket entries**: a shape-bucketed batch plan
    /// occupies one entry per (op, per-item shape, bucket size B) — the
    /// batch dim is part of [`PlanKey`] — and each such entry is evicted
    /// (and counted) individually.  Size the cap for the number of
    /// distinct (op, shape) signatures times the bucket fan-out
    /// (|{1, 2, 4, 8}| by default).
    pub plan_cache_cap: usize,
    /// Run the static plan verifier ([`crate::tina::ExecPlan::verify`])
    /// on every freshly compiled fallback plan in *release* builds.
    /// Debug/test builds always verify regardless of this flag.  The pass
    /// is metered: the coordinator drains `plans_verified` / `verify_ns`
    /// into its metrics (see [`Router::take_verify_counters`]).
    pub verify_plans: bool,
    /// Base quarantine backoff for a poisoned plan key.  A plan that
    /// panics during execution or fails release-mode verification is
    /// evicted and its `(op, shape, B)` key is quarantined for
    /// `quarantine_backoff × 2^(strikes−1)` (capped at
    /// [`quarantine_backoff_cap`](Self::quarantine_backoff_cap)); while
    /// quarantined, traffic for the key degrades to the interpreter
    /// oracle — bit-for-bit identical results, just slower.  After the
    /// backoff expires the key is paroled: the next request recompiles
    /// the plan, and a repeat offense doubles the backoff.
    pub quarantine_backoff: Duration,
    /// Ceiling on the exponential quarantine backoff — a persistently
    /// poisoned key retries compilation at most this often, it is never
    /// quarantined forever.
    pub quarantine_backoff_cap: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            fir_taps: 64,
            fir_cutoff: 0.25,
            unfold_window: 32,
            pfb: PfbConfig::new(32, 8),
            stft_nfft: 256,
            stft_hop: 128,
            iir_b: vec![0.25, 0.5, 0.25],
            iir_a: vec![0.3, 0.15],
            iir_depth: 4,
            beam_delays: vec![0, 1, 2, 3],
            beam_gains: vec![1.0, 0.8, -0.6, 0.4],
            plan_cache_cap: 64,
            verify_plans: false,
            quarantine_backoff: Duration::from_secs(1),
            quarantine_backoff_cap: Duration::from_secs(60),
        }
    }
}

/// Tiny LRU map for compiled plans: a `HashMap` plus monotone recency
/// ticks.  Eviction scans for the minimum tick — O(cap) on insert, and cap
/// is small (plans are heavyweight, the map never holds more than a few
/// dozen entries), so no linked-list bookkeeping is warranted.
struct LruMap<V> {
    cap: usize,
    tick: u64,
    map: HashMap<PlanKey, (V, u64)>,
}

impl<V: Clone> LruMap<V> {
    fn new(cap: usize) -> LruMap<V> {
        LruMap {
            cap: cap.max(1),
            tick: 0,
            map: HashMap::new(),
        }
    }

    /// Fetch and refresh recency.
    fn get(&mut self, k: &PlanKey) -> Option<V> {
        self.tick += 1;
        let t = self.tick;
        self.map.get_mut(k).map(|e| {
            e.1 = t;
            e.0.clone()
        })
    }

    /// Insert (refreshing recency); returns how many entries were evicted
    /// (0 or 1 — never the entry just inserted, whose tick is newest).
    fn insert(&mut self, k: PlanKey, v: V) -> u64 {
        self.tick += 1;
        self.map.insert(k, (v, self.tick));
        if self.map.len() <= self.cap {
            return 0;
        }
        let oldest = self
            .map
            .iter()
            .min_by_key(|(_, (_, t))| *t)
            .map(|(k, _)| k.clone());
        match oldest {
            Some(old) => {
                self.map.remove(&old);
                1
            }
            None => 0,
        }
    }

    /// Remove an entry (poisoned-plan eviction); true when it existed.
    fn remove(&mut self, k: &PlanKey) -> bool {
        self.map.remove(k).is_some()
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Quarantine record for a poisoned plan key: strike count drives the
/// exponential backoff; the entry survives past `until` so a repeat
/// offense after parole escalates instead of starting over.
struct QuarantineEntry {
    strikes: u32,
    until: Instant,
}

/// Where a request should execute.
#[derive(Debug, Clone, PartialEq)]
pub enum Target {
    /// Artifact name; `pad_batch` is the artifact's batch dimension when
    /// the request's own batch is smaller (the batcher's padding room).
    Artifact { name: String, pad_batch: usize },
    /// Interpreter plan key (op + shape signature).
    Interp { key: PlanKey },
}

/// Cache key for interpreter plans.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// The op the plan lowers.
    pub op: OpKind,
    /// Rank-prefixed input dims (see [`PlanKey::for_shapes`]).
    pub dims: Vec<usize>,
}

impl PlanKey {
    /// Signature for (op, input shapes): rank-prefixed dims per input.
    /// The leading batch dim participates, so every (op, shape, B) bucket
    /// of the shape-bucketed fallback batcher is its own cache entry.
    pub fn for_shapes(op: OpKind, shapes: &[Vec<usize>]) -> PlanKey {
        let dims: Vec<usize> = shapes
            .iter()
            .flat_map(|s| std::iter::once(s.len()).chain(s.iter().copied()))
            .collect();
        PlanKey { op, dims }
    }
}

/// The router: artifact lookup + LRU-bounded fallback plan caches
/// (planned executor for serving, interpreter for the oracle path).
pub struct Router {
    registry: Registry,
    config: RouterConfig,
    plans: Mutex<LruMap<std::sync::Arc<Interpreter>>>,
    exec_plans: Mutex<LruMap<std::sync::Arc<Planned>>>,
    /// Plans dropped from either cache since the last drain (the
    /// coordinator folds this into `Metrics::plan_cache_evictions`).
    evictions: AtomicU64,
    /// Window-fold rewrites applied by plans compiled since the last
    /// drain (the coordinator folds this into `Metrics::fused_steps`).
    fused_steps: AtomicU64,
    /// Materialize copies eliminated by plans compiled since the last
    /// drain (drained into `Metrics::fusion_eliminated_copies`).
    fusion_eliminated_copies: AtomicU64,
    /// Plans the static verifier checked since the last drain (drained
    /// into `Metrics::plans_verified`).
    plans_verified: AtomicU64,
    /// Nanoseconds the static verifier spent since the last drain
    /// (drained into `Metrics::verify_ns`).
    verify_ns: AtomicU64,
    /// Poisoned plan keys under exponential backoff (plus their strike
    /// history); bounded at [`QUARANTINE_CAP`].
    quarantine: Mutex<HashMap<PlanKey, QuarantineEntry>>,
    /// Quarantine events since the last drain (drained into
    /// `Metrics::quarantined_plans`).
    quarantined: AtomicU64,
    /// Whether the artifact arm is live — armed by default, then set
    /// from the engine's typed [`crate::runtime::Capability`] probe at
    /// coordinator construction (a type, not an error-message match).
    /// When false, `ImplPref::Auto` never routes to an artifact.
    artifact_arm: AtomicBool,
    /// Measured per-row latency EWMA per batch-normalized plan key:
    /// `[planned executor, artifact backend]` nanoseconds.  `Auto`
    /// consults this to pick the measured-faster arm; an unmeasured
    /// artifact arm is explored first.
    latency: Mutex<HashMap<PlanKey, [Option<f64>; 2]>>,
    /// Poisoned artifact names under the same exponential backoff as
    /// plan keys — a panicking artifact execution quarantines the
    /// *artifact*, and its traffic degrades to the interpreter oracle.
    artifact_quarantine: Mutex<HashMap<String, QuarantineEntry>>,
    /// `Auto` requests routed to the planned-executor arm since the last
    /// drain (drained into `Metrics::auto_routed_plan`).
    auto_routed_plan: AtomicU64,
    /// `Auto` requests routed to the artifact arm since the last drain
    /// (drained into `Metrics::auto_routed_artifact`).
    auto_routed_artifact: AtomicU64,
}

impl Router {
    /// Build a router over a loaded artifact registry.
    pub fn new(registry: Registry, config: RouterConfig) -> Router {
        let cap = config.plan_cache_cap;
        Router {
            registry,
            config,
            plans: Mutex::new(LruMap::new(cap)),
            exec_plans: Mutex::new(LruMap::new(cap)),
            evictions: AtomicU64::new(0),
            fused_steps: AtomicU64::new(0),
            fusion_eliminated_copies: AtomicU64::new(0),
            plans_verified: AtomicU64::new(0),
            verify_ns: AtomicU64::new(0),
            quarantine: Mutex::new(HashMap::new()),
            quarantined: AtomicU64::new(0),
            artifact_arm: AtomicBool::new(true),
            latency: Mutex::new(HashMap::new()),
            artifact_quarantine: Mutex::new(HashMap::new()),
            auto_routed_plan: AtomicU64::new(0),
            auto_routed_artifact: AtomicU64::new(0),
        }
    }

    /// The artifact registry routed over.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The fixed op parameters baked into fallback lowerings.
    pub fn config(&self) -> &RouterConfig {
        &self.config
    }

    /// Resolve a request to a target (no batching preference).
    ///
    /// Matching rule: an artifact fits when op, impl, dtype match and every
    /// input shape equals the request's — except that batchable ops may run
    /// on an artifact with a *larger* leading batch (the batcher pads).
    /// Preference order for `Auto`: exact-batch tina artifact, padded-batch
    /// tina artifact, interpreter.
    pub fn route(&self, req: &OpRequest) -> Result<Target> {
        self.route_with_batching(req, false)
    }

    /// Resolve a request; with `prefer_batched` set, batchable B=1 requests
    /// are steered to a multi-row artifact so the dynamic batcher can
    /// coalesce co-arriving requests (the serving configuration).
    pub fn route_with_batching(&self, req: &OpRequest, prefer_batched: bool) -> Result<Target> {
        req.validate()?;
        match req.impl_pref {
            ImplPref::Interp => Ok(Target::Interp {
                key: self.plan_key(req)?,
            }),
            ImplPref::Tina => self
                .find_artifact(req, "tina", prefer_batched)
                .ok_or_else(|| anyhow!(self.no_artifact_msg(req, "tina"))),
            ImplPref::JaxRef => self
                .find_artifact(req, "jaxref", prefer_batched)
                .ok_or_else(|| anyhow!(self.no_artifact_msg(req, "jaxref"))),
            ImplPref::Auto => {
                // the artifact arm is armed/disarmed by the engine's typed
                // capability probe — no artifact lookup when the backend
                // cannot execute
                if !self.artifact_arm.load(Ordering::Relaxed) {
                    return Ok(Target::Interp {
                        key: self.plan_key(req)?,
                    });
                }
                match self.find_artifact(req, "tina", prefer_batched) {
                    Some(Target::Artifact { name, pad_batch })
                        if !self.is_artifact_quarantined(&name)
                            && self.prefers_artifact(req) =>
                    {
                        self.auto_routed_artifact.fetch_add(1, Ordering::Relaxed);
                        Ok(Target::Artifact { name, pad_batch })
                    }
                    // an artifact exists but lost on measured latency (or
                    // is quarantined): Auto picks the plan arm
                    Some(_) => {
                        self.auto_routed_plan.fetch_add(1, Ordering::Relaxed);
                        Ok(Target::Interp {
                            key: self.plan_key(req)?,
                        })
                    }
                    None => Ok(Target::Interp {
                        key: self.plan_key(req)?,
                    }),
                }
            }
        }
    }

    fn no_artifact_msg(&self, req: &OpRequest, impl_: &str) -> String {
        format!(
            "no {impl_} artifact for op {} dtype {} with input shapes {:?}",
            req.op.as_str(),
            req.precision.as_str(),
            req.inputs.iter().map(|t| t.shape().to_vec()).collect::<Vec<_>>()
        )
    }

    fn find_artifact(&self, req: &OpRequest, impl_: &str, prefer_batched: bool) -> Option<Target> {
        let dtype = match req.precision {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        };
        let candidates = self.registry.find(req.op.as_str(), impl_, dtype);
        // serving mode: steer batchable single-row requests to a multi-row
        // artifact (the batcher pads/coalesces)
        if prefer_batched && req.op.batchable() && req.inputs.len() == 1 {
            let t = &req.inputs[0];
            if t.rank() == 2 && t.shape()[0] == 1 {
                let l = t.shape()[1];
                let mut best: Option<(&str, usize)> = None;
                for meta in &candidates {
                    if meta.inputs.len() != 1 || meta.inputs[0].shape.len() != 2 {
                        continue;
                    }
                    let (ab, al) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
                    if al == l && ab > 1 && best.map(|(_, bb)| ab < bb).unwrap_or(true) {
                        best = Some((meta.name.as_str(), ab));
                    }
                }
                if let Some((name, ab)) = best {
                    return Some(Target::Artifact {
                        name: name.to_string(),
                        pad_batch: ab,
                    });
                }
            }
        }
        // exact shape match first
        for meta in &candidates {
            if meta.inputs.len() == req.inputs.len()
                && meta
                    .inputs
                    .iter()
                    .zip(&req.inputs)
                    .all(|(spec, t)| spec.shape == t.shape())
            {
                return Some(Target::Artifact {
                    name: meta.name.clone(),
                    pad_batch: meta.batch(),
                });
            }
        }
        // padded-batch match for batchable single-input ops
        if req.op.batchable() && req.inputs.len() == 1 {
            let t = &req.inputs[0];
            if t.rank() == 2 {
                let (b, l) = (t.shape()[0], t.shape()[1]);
                let mut best: Option<(&str, usize)> = None;
                for meta in &candidates {
                    if meta.inputs.len() != 1 || meta.inputs[0].shape.len() != 2 {
                        continue;
                    }
                    let (ab, al) = (meta.inputs[0].shape[0], meta.inputs[0].shape[1]);
                    if al == l && ab >= b {
                        // smallest sufficient batch wins
                        if best.map(|(_, bb)| ab < bb).unwrap_or(true) {
                            best = Some((meta.name.as_str(), ab));
                        }
                    }
                }
                if let Some((name, ab)) = best {
                    return Some(Target::Artifact {
                        name: name.to_string(),
                        pad_batch: ab,
                    });
                }
            }
        }
        None
    }

    /// Shape signature for the interpreter plan cache.
    fn plan_key(&self, req: &OpRequest) -> Result<PlanKey> {
        Ok(PlanKey::for_shapes(req.op, &Self::shapes_of(req)))
    }

    fn shapes_of(req: &OpRequest) -> Vec<Vec<usize>> {
        req.inputs.iter().map(|t| t.shape().to_vec()).collect()
    }

    /// Get or build the interpreter for a plan key, using the request's
    /// input shapes (mirrors python/compile/tina_ops.py lowering).
    ///
    /// This is the *oracle* path: naive node-at-a-time execution kept for
    /// cross-checks.  Serving traffic goes through [`Router::planned`].
    pub fn interpreter(
        &self,
        key: &PlanKey,
        req: &OpRequest,
    ) -> Result<std::sync::Arc<Interpreter>> {
        if let Some(it) = self.plans.lock().unwrap().get(key) {
            return Ok(it);
        }
        let graph = self.build_graph(req)?;
        let it = std::sync::Arc::new(Interpreter::new(graph)?);
        let evicted = self
            .plans
            .lock()
            .unwrap()
            .insert(key.clone(), std::sync::Arc::clone(&it));
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        Ok(it)
    }

    /// Get or compile the planned executor for a plan key.  Returns the
    /// plan plus whether it was a cache hit (the coordinator feeds that
    /// into its plan-cache metrics).
    pub fn planned(
        &self,
        key: &PlanKey,
        req: &OpRequest,
    ) -> Result<(std::sync::Arc<Planned>, bool)> {
        self.planned_impl(key, req.op, &Self::shapes_of(req))
    }

    /// Get or compile the planned executor for (op, input shapes) with no
    /// request object — the entry point the shape-bucketed batch drain
    /// uses to fetch a plan at the coalesced bucket batch size B.  A
    /// single request is the degenerate B=1 case of the same lookup.
    pub fn planned_for_shapes(
        &self,
        op: OpKind,
        shapes: &[Vec<usize>],
    ) -> Result<(std::sync::Arc<Planned>, bool)> {
        let key = PlanKey::for_shapes(op, shapes);
        self.planned_impl(&key, op, shapes)
    }

    fn planned_impl(
        &self,
        key: &PlanKey,
        op: OpKind,
        shapes: &[Vec<usize>],
    ) -> Result<(std::sync::Arc<Planned>, bool)> {
        if let Some(p) = self.exec_plans.lock().unwrap().get(key) {
            return Ok((p, true));
        }
        // Compile outside the lock: plan compilation does real work
        // (constant baking, liveness analysis) and must not serialize
        // unrelated requests.  A racing compile of the same key is
        // harmless — last insert wins, both plans are identical.
        let graph = self.build_graph_for(op, shapes)?;
        // Compile without the inline verify gate, then (when enabled) run
        // the static verifier as a separate *metered* pass: debug/test
        // builds always verify, release builds opt in via
        // `RouterConfig::verify_plans`.
        let p = std::sync::Arc::new(Planned::new_with(
            &graph,
            CompileOptions {
                fusion: true,
                verify: false,
            },
        )?);
        if cfg!(debug_assertions) || self.config.verify_plans {
            let t0 = std::time::Instant::now();
            if let Err(e) = p.plan().verify() {
                // a plan the verifier rejects is poisoned by construction:
                // quarantine the key so traffic degrades to the oracle
                // instead of re-compiling (and re-failing) per request
                self.quarantine_key(key, "failed static verification");
                bail!(
                    "plan for op {} shapes {shapes:?} failed static verification: {e}",
                    op.as_str()
                );
            }
            self.plans_verified.fetch_add(1, Ordering::Relaxed);
            self.verify_ns
                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        self.fused_steps
            .fetch_add(p.plan().fused_steps() as u64, Ordering::Relaxed);
        self.fusion_eliminated_copies.fetch_add(
            p.plan().fusion_eliminated_copies() as u64,
            Ordering::Relaxed,
        );
        let evicted = self
            .exec_plans
            .lock()
            .unwrap()
            .insert(key.clone(), std::sync::Arc::clone(&p));
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        Ok((p, false))
    }

    /// Take (and reset) the eviction count accumulated since the last
    /// drain; the coordinator mirrors it into its metrics.
    pub fn take_plan_cache_evictions(&self) -> u64 {
        self.evictions.swap(0, Ordering::Relaxed)
    }

    /// Take (and reset) the fusion counters accumulated by plan compiles
    /// since the last drain, as `(fused_steps, fusion_eliminated_copies)`;
    /// the coordinator mirrors them into its metrics.
    pub fn take_fusion_counters(&self) -> (u64, u64) {
        (
            self.fused_steps.swap(0, Ordering::Relaxed),
            self.fusion_eliminated_copies.swap(0, Ordering::Relaxed),
        )
    }

    /// Take (and reset) the static-verification counters accumulated by
    /// plan compiles since the last drain, as `(plans_verified,
    /// verify_ns)`; the coordinator mirrors them into its metrics.
    pub fn take_verify_counters(&self) -> (u64, u64) {
        (
            self.plans_verified.swap(0, Ordering::Relaxed),
            self.verify_ns.swap(0, Ordering::Relaxed),
        )
    }

    /// Take (and reset) the quarantine-event count accumulated since the
    /// last drain (drained into `Metrics::quarantined_plans`); counts
    /// both plan-key and artifact quarantine events.
    pub fn take_quarantine_counters(&self) -> u64 {
        self.quarantined.swap(0, Ordering::Relaxed)
    }

    /// Take (and reset) the `Auto` arm-choice counters accumulated since
    /// the last drain, as `(auto_routed_plan, auto_routed_artifact)`;
    /// the coordinator mirrors them into its metrics.
    pub fn take_auto_routed(&self) -> (u64, u64) {
        (
            self.auto_routed_plan.swap(0, Ordering::Relaxed),
            self.auto_routed_artifact.swap(0, Ordering::Relaxed),
        )
    }

    /// Arm or disarm the artifact routing arm.  The coordinator calls
    /// this once at construction with the engine's typed
    /// [`crate::runtime::Capability::can_execute`] — replacing the old
    /// behavior of discovering a dead backend per request via stringly
    /// execute errors.
    pub fn set_artifact_arm(&self, live: bool) {
        self.artifact_arm.store(live, Ordering::Relaxed);
    }

    /// Whether the artifact arm is currently armed.
    pub fn artifact_arm_live(&self) -> bool {
        self.artifact_arm.load(Ordering::Relaxed)
    }

    /// Batch-normalized latency key: bucketed executions of the same
    /// (op, per-row shape) share one entry regardless of B, so per-row
    /// EWMAs stay comparable across bucket sizes.
    fn latency_key(op: OpKind, shapes: &[Vec<usize>]) -> PlanKey {
        let mut shapes = shapes.to_vec();
        if op.batchable() && shapes.len() == 1 && shapes[0].len() == 2 {
            shapes[0][0] = 1;
        }
        PlanKey::for_shapes(op, &shapes)
    }

    /// Record a measured per-row latency for the planned-executor arm.
    pub fn record_plan_latency(&self, op: OpKind, shapes: &[Vec<usize>], ns_per_row: f64) {
        self.record_latency(0, op, shapes, ns_per_row);
    }

    /// Record a measured per-row latency for the artifact arm.
    pub fn record_artifact_latency(&self, op: OpKind, shapes: &[Vec<usize>], ns_per_row: f64) {
        self.record_latency(1, op, shapes, ns_per_row);
    }

    fn record_latency(&self, arm: usize, op: OpKind, shapes: &[Vec<usize>], ns_per_row: f64) {
        if !ns_per_row.is_finite() || ns_per_row <= 0.0 {
            return;
        }
        let key = Self::latency_key(op, shapes);
        let mut table = self.latency.lock().unwrap();
        if !table.contains_key(&key) && table.len() >= LATENCY_CAP {
            // adversarial shape churn: drop an arbitrary entry rather
            // than growing without bound (the table self-heals as live
            // keys keep recording)
            if let Some(k) = table.keys().next().cloned() {
                table.remove(&k);
            }
        }
        let entry = table.entry(key).or_insert([None, None]);
        entry[arm] = Some(match entry[arm] {
            None => ns_per_row,
            Some(prev) => prev * (1.0 - LATENCY_ALPHA) + ns_per_row * LATENCY_ALPHA,
        });
    }

    /// Measured per-row EWMA latencies for (op, shapes), as
    /// `(planned_ns, artifact_ns)` (tests/introspection).
    pub fn arm_latency(&self, op: OpKind, shapes: &[Vec<usize>]) -> (Option<f64>, Option<f64>) {
        let key = Self::latency_key(op, shapes);
        let table = self.latency.lock().unwrap();
        match table.get(&key) {
            Some([p, a]) => (*p, *a),
            None => (None, None),
        }
    }

    /// `Auto` arm choice for a request with a matching artifact: the
    /// measured-faster arm wins; an unmeasured artifact arm is explored
    /// first (one execution seeds its EWMA).
    fn prefers_artifact(&self, req: &OpRequest) -> bool {
        let key = Self::latency_key(req.op, &Self::shapes_of(req));
        let table = self.latency.lock().unwrap();
        match table.get(&key) {
            Some([Some(plan_ns), Some(artifact_ns)]) => artifact_ns <= plan_ns,
            _ => true,
        }
    }

    /// Quarantine a poisoned *artifact* (panic or typed execution
    /// failure on the artifact arm): its traffic degrades to the
    /// interpreter oracle under the same exponential backoff as plan
    /// keys, and `Auto` stops choosing it until parole.
    pub fn quarantine_artifact(&self, name: &str, reason: &str) {
        let mut q = self.artifact_quarantine.lock().unwrap();
        if !q.contains_key(name) && q.len() >= QUARANTINE_CAP {
            let soonest = q.iter().min_by_key(|(_, e)| e.until).map(|(k, _)| k.clone());
            if let Some(k) = soonest {
                q.remove(&k);
            }
        }
        let now = Instant::now();
        let e = q.entry(name.to_string()).or_insert(QuarantineEntry {
            strikes: 0,
            until: now,
        });
        e.strikes = e.strikes.saturating_add(1);
        let backoff = self
            .config
            .quarantine_backoff
            .saturating_mul(1u32 << (e.strikes - 1).min(16))
            .min(self.config.quarantine_backoff_cap);
        e.until = now + backoff;
        drop(q);
        self.quarantined.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "tina: quarantined artifact '{name}' for {backoff:?} ({reason}); \
             serving via interpreter oracle"
        );
    }

    /// Whether an artifact is currently quarantined (backoff not yet
    /// expired).  Expired entries keep their strike history so a repeat
    /// offense escalates the next backoff.
    pub fn is_artifact_quarantined(&self, name: &str) -> bool {
        let q = self.artifact_quarantine.lock().unwrap();
        q.get(name).is_some_and(|e| e.until > Instant::now())
    }

    /// Lower (op, input shapes) and compile a standalone [`ExecPlan`] —
    /// the coordinator uses this to populate the virtual accelerator's
    /// program table from the artifact registry at startup (one load per
    /// manifest entry).  Not cached: each artifact is loaded once.
    pub fn compile_artifact_plan(&self, op: OpKind, shapes: &[Vec<usize>]) -> Result<ExecPlan> {
        let graph = self.build_graph_for(op, shapes)?;
        ExecPlan::compile(&graph)
    }

    /// Quarantine a poisoned plan key: evict its compiled plan so nothing
    /// serves from it again, and put the key under exponential backoff
    /// ([`RouterConfig::quarantine_backoff`], doubling per strike, capped
    /// at [`RouterConfig::quarantine_backoff_cap`]).  While quarantined,
    /// the coordinator degrades the key's traffic to the interpreter
    /// oracle.  Called when a plan panics during execution or fails
    /// release-mode verification.
    pub fn quarantine_key(&self, key: &PlanKey, reason: &str) {
        self.exec_plans.lock().unwrap().remove(key);
        let mut q = self.quarantine.lock().unwrap();
        if !q.contains_key(key) && q.len() >= QUARANTINE_CAP {
            // drop the entry expiring soonest: it is closest to parole, so
            // losing its strike history costs the least
            let soonest = q.iter().min_by_key(|(_, e)| e.until).map(|(k, _)| k.clone());
            if let Some(k) = soonest {
                q.remove(&k);
            }
        }
        let now = Instant::now();
        let e = q.entry(key.clone()).or_insert(QuarantineEntry {
            strikes: 0,
            until: now,
        });
        e.strikes = e.strikes.saturating_add(1);
        let backoff = self
            .config
            .quarantine_backoff
            .saturating_mul(1u32 << (e.strikes - 1).min(16))
            .min(self.config.quarantine_backoff_cap);
        e.until = now + backoff;
        drop(q);
        self.quarantined.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "tina: quarantined plan key {:?} for {:?} ({reason}); serving via interpreter oracle",
            key, backoff
        );
    }

    /// Whether a plan key is currently quarantined (backoff not yet
    /// expired).  Expired entries keep their strike history so a repeat
    /// offense escalates the next backoff.
    pub fn is_quarantined(&self, key: &PlanKey) -> bool {
        let q = self.quarantine.lock().unwrap();
        q.get(key).is_some_and(|e| e.until > Instant::now())
    }

    /// Get or build the interpreter oracle for (op, input shapes) with no
    /// request object — the degraded-mode entry point the batch drain uses
    /// while a bucketed plan key is quarantined.  Shares the oracle cache
    /// with [`Router::interpreter`].
    pub fn interpreter_for_shapes(
        &self,
        op: OpKind,
        shapes: &[Vec<usize>],
    ) -> Result<std::sync::Arc<Interpreter>> {
        let key = PlanKey::for_shapes(op, shapes);
        if let Some(it) = self.plans.lock().unwrap().get(&key) {
            return Ok(it);
        }
        let graph = self.build_graph_for(op, shapes)?;
        let it = std::sync::Arc::new(Interpreter::new(graph)?);
        let evicted = self
            .plans
            .lock()
            .unwrap()
            .insert(key, std::sync::Arc::clone(&it));
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        Ok(it)
    }

    fn build_graph(&self, req: &OpRequest) -> Result<crate::tina::Graph> {
        self.build_graph_for(req.op, &Self::shapes_of(req))
    }

    /// Lower (op, input shapes) to a TINA graph (mirrors
    /// python/compile/tina_ops.py).  Shape-driven so both a request's own
    /// shapes and a bucketed batch shape `(B, L)` compile the same way.
    fn build_graph_for(&self, op: OpKind, shapes: &[Vec<usize>]) -> Result<crate::tina::Graph> {
        if shapes.len() != op.expected_inputs() {
            bail!(
                "op {} wants {} inputs, got {}",
                op.as_str(),
                op.expected_inputs(),
                shapes.len()
            );
        }
        let shape = |i: usize| shapes[i].clone();
        let rank2 = |i: usize| -> Result<(usize, usize)> {
            let s = shape(i);
            if s.len() != 2 {
                bail!("op {} input {i} must be rank 2, got {:?}", op.as_str(), s);
            }
            Ok((s[0], s[1]))
        };
        Ok(match op {
            OpKind::EwMult => {
                let (h, w) = rank2(0)?;
                lower::ewmult(h, w)
            }
            OpKind::EwAdd => {
                let (h, w) = rank2(0)?;
                lower::ewadd(h, w)
            }
            OpKind::MatMul => {
                let (m, l) = rank2(0)?;
                let (l2, n) = rank2(1)?;
                if l != l2 {
                    bail!("matmul contraction mismatch {l} vs {l2}");
                }
                lower::matmul(m, l, n)
            }
            OpKind::Summation => {
                let s = shape(0);
                if s.len() != 1 {
                    bail!("summation input must be rank 1, got {:?}", s);
                }
                lower::summation(s[0])
            }
            OpKind::Dft => {
                let (b, n) = rank2(0)?;
                lower::dft(b, n)
            }
            OpKind::Idft => {
                let (b, n) = rank2(0)?;
                let (b2, n2) = rank2(1)?;
                if (b, n) != (b2, n2) {
                    bail!("idft re/im shape mismatch");
                }
                lower::idft(b, n)
            }
            OpKind::Fir => {
                let (b, l) = rank2(0)?;
                let taps =
                    crate::dsp::fir_lowpass(self.config.fir_taps, self.config.fir_cutoff)?;
                lower::fir(b, l, &taps)?
            }
            OpKind::Unfold => {
                let (b, l) = rank2(0)?;
                lower::unfold(b, l, self.config.unfold_window)?
            }
            OpKind::PfbFir => {
                let (b, l) = rank2(0)?;
                lower::pfb_fir(b, l, self.config.pfb)?
            }
            OpKind::Pfb => {
                let (b, l) = rank2(0)?;
                lower::pfb(b, l, self.config.pfb)?
            }
            OpKind::Stft => {
                let (b, l) = rank2(0)?;
                lower::stft(b, l, self.config.stft_nfft, self.config.stft_hop)?
            }
            OpKind::Iir => {
                let (b, l) = rank2(0)?;
                lower::iir(
                    b,
                    l,
                    &self.config.iir_b,
                    &self.config.iir_a,
                    self.config.iir_depth,
                )?
            }
            OpKind::Xcorr => {
                let (b, l) = rank2(0)?;
                let t = shape(1);
                if t.len() != 1 {
                    bail!("xcorr template must be rank 1, got {:?}", t);
                }
                lower::xcorr(b, l, t[0])?
            }
            OpKind::FxCorrelate => {
                let (b, l) = rank2(0)?;
                let (b2, l2) = rank2(1)?;
                if (b, l) != (b2, l2) {
                    bail!("fx_correlate antenna shape mismatch");
                }
                // bandpass calibration curve baked as the chain-folded gain
                let gains: Vec<f32> = crate::dsp::hamming(self.config.stft_nfft)
                    .iter()
                    .map(|&v| v as f32)
                    .collect();
                lower::fx_correlate(b, l, self.config.stft_nfft, self.config.stft_hop, &gains)?
            }
            OpKind::Spectrometer => {
                let (b, l) = rank2(0)?;
                lower::spectrometer(b, l, self.config.pfb)?
            }
            OpKind::Beamform => {
                let s = shape(0);
                if s.len() != 3 {
                    bail!("beamform input must be rank 3 (B, C, L), got {:?}", s);
                }
                if s[1] != self.config.beam_delays.len() {
                    bail!(
                        "beamform channel count {} != configured array size {}",
                        s[1],
                        self.config.beam_delays.len()
                    );
                }
                lower::beamform(
                    s[0],
                    s[1],
                    s[2],
                    &self.config.beam_delays,
                    &self.config.beam_gains,
                )?
            }
        })
    }

    /// Number of cached interpreter (oracle) plans.
    pub fn cached_plans(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// Number of cached planned-executor plans.
    pub fn cached_exec_plans(&self) -> usize {
        self.exec_plans.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::path::PathBuf;

    const MANIFEST: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "fir_tina_f32_B1_L1024", "op": "fir", "impl": "tina",
         "dtype": "f32", "params": {"l": 1024, "taps": 64, "batch": 1},
         "inputs": [{"shape": [1, 1024], "dtype": "float32"}],
         "outputs": [{"shape": [1, 961], "dtype": "float32"}],
         "file": "a.hlo.txt"},
        {"name": "fir_tina_f32_B8_L1024", "op": "fir", "impl": "tina",
         "dtype": "f32", "params": {"l": 1024, "taps": 64, "batch": 8},
         "inputs": [{"shape": [8, 1024], "dtype": "float32"}],
         "outputs": [{"shape": [8, 961], "dtype": "float32"}],
         "file": "b.hlo.txt"},
        {"name": "fir_jaxref_f32_B1_L1024", "op": "fir", "impl": "jaxref",
         "dtype": "f32", "params": {"l": 1024, "taps": 64, "batch": 1},
         "inputs": [{"shape": [1, 1024], "dtype": "float32"}],
         "outputs": [{"shape": [1, 961], "dtype": "float32"}],
         "file": "c.hlo.txt"}
      ]
    }"#;

    fn router() -> Router {
        let reg =
            Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap();
        Router::new(reg, RouterConfig::default())
    }

    #[test]
    fn exact_match_preferred() {
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 1024])]);
        match r.route(&req).unwrap() {
            Target::Artifact { name, pad_batch } => {
                assert_eq!(name, "fir_tina_f32_B1_L1024");
                assert_eq!(pad_batch, 1);
            }
            t => panic!("unexpected target {t:?}"),
        }
    }

    #[test]
    fn padded_batch_match() {
        let r = router();
        // batch 3 has no exact artifact; should pick the B8 one
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[3, 1024])]);
        match r.route(&req).unwrap() {
            Target::Artifact { name, pad_batch } => {
                assert_eq!(name, "fir_tina_f32_B8_L1024");
                assert_eq!(pad_batch, 8);
            }
            t => panic!("unexpected target {t:?}"),
        }
    }

    #[test]
    fn auto_falls_back_to_interp() {
        let r = router();
        // length 999 has no artifact
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 999])]);
        match r.route(&req).unwrap() {
            Target::Interp { key } => assert_eq!(key.op, OpKind::Fir),
            t => panic!("unexpected target {t:?}"),
        }
    }

    #[test]
    fn strict_tina_errors_when_missing() {
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 999])])
            .with_impl(ImplPref::Tina);
        assert!(r.route(&req).is_err());
    }

    #[test]
    fn jaxref_routed_when_asked() {
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 1024])])
            .with_impl(ImplPref::JaxRef);
        match r.route(&req).unwrap() {
            Target::Artifact { name, .. } => assert_eq!(name, "fir_jaxref_f32_B1_L1024"),
            t => panic!("unexpected target {t:?}"),
        }
    }

    #[test]
    fn interpreter_plans_cached() {
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 999])])
            .with_impl(ImplPref::Interp);
        let Target::Interp { key } = r.route(&req).unwrap() else {
            panic!()
        };
        assert_eq!(r.cached_plans(), 0);
        let _ = r.interpreter(&key, &req).unwrap();
        assert_eq!(r.cached_plans(), 1);
        let _ = r.interpreter(&key, &req).unwrap();
        assert_eq!(r.cached_plans(), 1);
    }

    #[test]
    fn exec_plans_cached_and_hit_reported() {
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 999])])
            .with_impl(ImplPref::Interp);
        let Target::Interp { key } = r.route(&req).unwrap() else {
            panic!()
        };
        assert_eq!(r.cached_exec_plans(), 0);
        let (_, hit) = r.planned(&key, &req).unwrap();
        assert!(!hit, "first compile must be a miss");
        assert_eq!(r.cached_exec_plans(), 1);
        let (_, hit) = r.planned(&key, &req).unwrap();
        assert!(hit, "second lookup must hit the cache");
        assert_eq!(r.cached_exec_plans(), 1);
        // the two caches are independent
        assert_eq!(r.cached_plans(), 0);
    }

    #[test]
    fn plan_caches_evict_lru_beyond_cap() {
        let reg =
            Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap();
        let r = Router::new(
            reg,
            RouterConfig {
                plan_cache_cap: 2,
                ..RouterConfig::default()
            },
        );
        // three distinct shape signatures: the first must fall out
        let keys: Vec<PlanKey> = [100usize, 101, 102]
            .iter()
            .map(|&l| {
                let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, l])])
                    .with_impl(ImplPref::Interp);
                let Target::Interp { key } = r.route(&req).unwrap() else {
                    panic!()
                };
                let _ = r.planned(&key, &req).unwrap();
                key
            })
            .collect();
        assert_eq!(r.cached_exec_plans(), 2, "cap must hold");
        assert_eq!(r.take_plan_cache_evictions(), 1, "one plan evicted");
        assert_eq!(r.take_plan_cache_evictions(), 0, "drain resets");
        // the evicted (oldest) key recompiles: a miss
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 100])])
            .with_impl(ImplPref::Interp);
        let (_, hit) = r.planned(&keys[0], &req).unwrap();
        assert!(!hit, "evicted plan must recompile");
    }

    #[test]
    fn bucketed_plans_count_against_cap_per_entry() {
        // every (op, shape, B) bucket is its own cache entry: three bucket
        // sizes of the same (op, L) overflow a cap of 2 and evictions are
        // counted per entry
        let reg =
            Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap();
        let r = Router::new(
            reg,
            RouterConfig {
                plan_cache_cap: 2,
                ..RouterConfig::default()
            },
        );
        for b in [1usize, 2, 4] {
            let (_, hit) = r.planned_for_shapes(OpKind::Fir, &[vec![b, 128]]).unwrap();
            assert!(!hit, "distinct bucket B={b} must compile its own plan");
        }
        assert_eq!(r.cached_exec_plans(), 2, "cap bounds bucketed entries");
        assert_eq!(r.take_plan_cache_evictions(), 1, "one bucket entry evicted");
        // the evicted bucket (B=1, the LRU entry) recompiles: a miss
        let (_, hit) = r.planned_for_shapes(OpKind::Fir, &[vec![1, 128]]).unwrap();
        assert!(!hit, "evicted bucket plan must recompile");
        // a surviving bucket still hits
        let (_, hit) = r.planned_for_shapes(OpKind::Fir, &[vec![4, 128]]).unwrap();
        assert!(hit, "surviving bucket plan must hit");
    }

    #[test]
    fn planned_for_shapes_shares_the_request_plan_cache() {
        // the bucketed entry point and the request entry point agree on
        // the key: a B=1 bucket lookup hits a plan compiled via a request
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 999])])
            .with_impl(ImplPref::Interp);
        let Target::Interp { key } = r.route(&req).unwrap() else {
            panic!()
        };
        let (_, hit) = r.planned(&key, &req).unwrap();
        assert!(!hit);
        let (_, hit) = r.planned_for_shapes(OpKind::Fir, &[vec![1, 999]]).unwrap();
        assert!(hit, "degenerate B=1 shape lookup must share the cache");
    }

    #[test]
    fn lru_get_refreshes_recency() {
        let reg =
            Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap();
        let r = Router::new(
            reg,
            RouterConfig {
                plan_cache_cap: 2,
                ..RouterConfig::default()
            },
        );
        let key_of = |l: usize| {
            let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, l])])
                .with_impl(ImplPref::Interp);
            let Target::Interp { key } = r.route(&req).unwrap() else {
                panic!()
            };
            (key, req)
        };
        let (k100, r100) = key_of(100);
        let (k101, r101) = key_of(101);
        let (k102, r102) = key_of(102);
        let _ = r.planned(&k100, &r100).unwrap();
        let _ = r.planned(&k101, &r101).unwrap();
        // touch 100 so 101 becomes the LRU entry, then overflow with 102
        let (_, hit) = r.planned(&k100, &r100).unwrap();
        assert!(hit);
        let _ = r.planned(&k102, &r102).unwrap();
        let (_, hit) = r.planned(&k100, &r100).unwrap();
        assert!(hit, "recently-touched plan must survive eviction");
        let (_, hit) = r.planned(&k101, &r101).unwrap();
        assert!(!hit, "LRU plan must have been evicted");
    }

    #[test]
    fn fusion_counters_accumulate_and_drain() {
        let r = router();
        assert_eq!(r.take_fusion_counters(), (0, 0));
        // default config: nfft 256, hop 128.  A batched B=2 STFT plan
        // folds its window (1 fused step) and eliminates the frame
        // regrouping copy (1)
        let (_, hit) = r
            .planned_for_shapes(OpKind::Stft, &[vec![2, 1024]])
            .unwrap();
        assert!(!hit);
        assert_eq!(r.take_fusion_counters(), (1, 1), "stft B=2 fold + copy");
        assert_eq!(r.take_fusion_counters(), (0, 0), "drain resets");
        // a cache hit compiles nothing, so nothing accumulates
        let (_, hit) = r
            .planned_for_shapes(OpKind::Stft, &[vec![2, 1024]])
            .unwrap();
        assert!(hit);
        assert_eq!(r.take_fusion_counters(), (0, 0));
        // FIR has no window: fold-free plans leave the counters alone
        let _ = r.planned_for_shapes(OpKind::Fir, &[vec![1, 256]]).unwrap();
        assert_eq!(r.take_fusion_counters(), (0, 0));
    }

    #[test]
    fn verify_counters_accumulate_and_drain() {
        let r = router();
        assert_eq!(r.take_verify_counters(), (0, 0));
        let (_, hit) = r.planned_for_shapes(OpKind::Fir, &[vec![1, 256]]).unwrap();
        assert!(!hit);
        let (n, ns) = r.take_verify_counters();
        assert_eq!(n, 1, "debug builds always verify fresh plans");
        assert!(ns > 0, "verification time must be metered");
        assert_eq!(r.take_verify_counters(), (0, 0), "drain resets");
        // a cache hit compiles (and verifies) nothing
        let (_, hit) = r.planned_for_shapes(OpKind::Fir, &[vec![1, 256]]).unwrap();
        assert!(hit);
        assert_eq!(r.take_verify_counters().0, 0);
    }

    #[test]
    fn quarantine_evicts_plan_and_expires_with_escalating_backoff() {
        let reg =
            Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap();
        let r = Router::new(
            reg,
            RouterConfig {
                quarantine_backoff: Duration::from_millis(30),
                quarantine_backoff_cap: Duration::from_secs(60),
                ..RouterConfig::default()
            },
        );
        let key = PlanKey::for_shapes(OpKind::Fir, &[vec![1, 128]]);
        let (_, hit) = r.planned_for_shapes(OpKind::Fir, &[vec![1, 128]]).unwrap();
        assert!(!hit);
        assert_eq!(r.cached_exec_plans(), 1);
        assert!(!r.is_quarantined(&key));

        r.quarantine_key(&key, "test poison");
        assert!(r.is_quarantined(&key));
        assert_eq!(r.cached_exec_plans(), 0, "poisoned plan must be evicted");
        assert_eq!(r.take_quarantine_counters(), 1);
        assert_eq!(r.take_quarantine_counters(), 0, "drain resets");

        // parole: the backoff expires, the key serves (and recompiles) again
        std::thread::sleep(Duration::from_millis(40));
        assert!(!r.is_quarantined(&key), "backoff must expire");
        let (_, hit) = r.planned_for_shapes(OpKind::Fir, &[vec![1, 128]]).unwrap();
        assert!(!hit, "paroled key recompiles");

        // repeat offense: strike history survived parole, backoff doubles
        // (60ms), so the key is still quarantined after the base 30ms
        r.quarantine_key(&key, "test poison again");
        std::thread::sleep(Duration::from_millis(40));
        assert!(
            r.is_quarantined(&key),
            "second strike must escalate the backoff past the base"
        );
        assert_eq!(r.take_quarantine_counters(), 1);
    }

    #[test]
    fn quarantine_backoff_is_capped() {
        let reg =
            Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap();
        let r = Router::new(
            reg,
            RouterConfig {
                quarantine_backoff: Duration::from_millis(10),
                quarantine_backoff_cap: Duration::from_millis(20),
                ..RouterConfig::default()
            },
        );
        let key = PlanKey::for_shapes(OpKind::Fir, &[vec![1, 64]]);
        // many strikes: the backoff must stay at the cap, so the key still
        // paroles quickly (never quarantined forever)
        for _ in 0..40 {
            r.quarantine_key(&key, "repeat offender");
        }
        assert!(r.is_quarantined(&key));
        std::thread::sleep(Duration::from_millis(30));
        assert!(!r.is_quarantined(&key), "capped backoff must still expire");
    }

    #[test]
    fn quarantine_map_stays_bounded() {
        let r = router();
        for l in 0..QUARANTINE_CAP + 10 {
            let key = PlanKey::for_shapes(OpKind::Fir, &[vec![1, 1000 + l]]);
            r.quarantine_key(&key, "churn");
        }
        let q = r.quarantine.lock().unwrap();
        assert!(q.len() <= QUARANTINE_CAP, "map must stay at the cap");
    }

    #[test]
    fn interpreter_for_shapes_shares_the_oracle_cache() {
        let r = router();
        let x = Tensor::randn(&[1, 999], 11);
        let req = OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Interp);
        let Target::Interp { key } = r.route(&req).unwrap() else {
            panic!()
        };
        let via_req = r.interpreter(&key, &req).unwrap();
        assert_eq!(r.cached_plans(), 1);
        let via_shapes = r.interpreter_for_shapes(OpKind::Fir, &[vec![1, 999]]).unwrap();
        assert_eq!(r.cached_plans(), 1, "shape lookup must share the cache");
        // both handles run the same oracle bit-for-bit
        let a = via_req.run(std::slice::from_ref(&x)).unwrap();
        let b = via_shapes.run(std::slice::from_ref(&x)).unwrap();
        assert_eq!(a[0].data(), b[0].data());
    }

    #[test]
    fn auto_respects_the_artifact_arm_switch() {
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 1024])]);
        assert!(r.artifact_arm_live(), "armed by default");
        assert!(matches!(r.route(&req).unwrap(), Target::Artifact { .. }));
        r.set_artifact_arm(false);
        assert!(
            matches!(r.route(&req).unwrap(), Target::Interp { .. }),
            "disarmed backend must never receive Auto traffic"
        );
        r.set_artifact_arm(true);
        assert!(matches!(r.route(&req).unwrap(), Target::Artifact { .. }));
    }

    #[test]
    fn auto_explores_unmeasured_artifact_then_follows_measured_latency() {
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 1024])]);
        // no measurements: explore the artifact arm first
        assert!(matches!(r.route(&req).unwrap(), Target::Artifact { .. }));
        assert_eq!(r.take_auto_routed(), (0, 1));
        // artifact measured slower than the plan: Auto flips to the plan
        r.record_plan_latency(OpKind::Fir, &[vec![1, 1024]], 100.0);
        r.record_artifact_latency(OpKind::Fir, &[vec![1, 1024]], 500.0);
        assert!(matches!(r.route(&req).unwrap(), Target::Interp { .. }));
        assert_eq!(r.take_auto_routed(), (1, 0));
        // strict prefs bypass the latency table entirely
        let strict = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 1024])])
            .with_impl(ImplPref::Tina);
        assert!(matches!(r.route(&strict).unwrap(), Target::Artifact { .. }));
        assert_eq!(r.take_auto_routed(), (0, 0), "strict prefs are not Auto");
    }

    #[test]
    fn latency_table_normalizes_bucket_batch_and_ewmas() {
        let r = router();
        // a B=8 bucketed measurement and a B=1 request share one entry
        r.record_artifact_latency(OpKind::Fir, &[vec![8, 1024]], 300.0);
        let (p, a) = r.arm_latency(OpKind::Fir, &[vec![1, 1024]]);
        assert_eq!(p, None);
        assert_eq!(a, Some(300.0), "first sample seeds the EWMA");
        r.record_artifact_latency(OpKind::Fir, &[vec![1, 1024]], 400.0);
        let (_, a) = r.arm_latency(OpKind::Fir, &[vec![8, 1024]]);
        assert_eq!(a, Some(300.0 * 0.8 + 400.0 * 0.2), "EWMA blend");
    }

    #[test]
    fn quarantined_artifact_degrades_auto_to_plan_arm() {
        let reg =
            Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap();
        let r = Router::new(
            reg,
            RouterConfig {
                quarantine_backoff: Duration::from_millis(30),
                ..RouterConfig::default()
            },
        );
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 1024])]);
        assert!(!r.is_artifact_quarantined("fir_tina_f32_B1_L1024"));
        r.quarantine_artifact("fir_tina_f32_B1_L1024", "test poison");
        assert!(r.is_artifact_quarantined("fir_tina_f32_B1_L1024"));
        assert_eq!(r.take_quarantine_counters(), 1);
        assert!(
            matches!(r.route(&req).unwrap(), Target::Interp { .. }),
            "Auto must not choose a quarantined artifact"
        );
        assert_eq!(r.take_auto_routed(), (1, 0));
        // parole after the backoff expires
        std::thread::sleep(Duration::from_millis(40));
        assert!(!r.is_artifact_quarantined("fir_tina_f32_B1_L1024"));
        assert!(matches!(r.route(&req).unwrap(), Target::Artifact { .. }));
    }

    #[test]
    fn compile_artifact_plan_lowers_registry_shapes() {
        let r = router();
        let plan = r
            .compile_artifact_plan(OpKind::Fir, &[vec![8, 1024]])
            .unwrap();
        assert_eq!(plan.input_shapes(), &[vec![8, 1024]]);
        let err = r.compile_artifact_plan(OpKind::Fir, &[vec![1, 2], vec![3]]);
        assert!(err.is_err(), "arity mismatch must fail the lowering");
    }

    #[test]
    fn planned_matches_interpreter_through_router() {
        let r = router();
        let x = Tensor::randn(&[1, 999], 7);
        let req = OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Interp);
        let Target::Interp { key } = r.route(&req).unwrap() else {
            panic!()
        };
        let it = r.interpreter(&key, &req).unwrap();
        let (p, _) = r.planned(&key, &req).unwrap();
        let want = it.run(std::slice::from_ref(&x)).unwrap();
        let got = p.run(std::slice::from_ref(&x)).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert!(a.allclose(b, 1e-5, 1e-6));
        }
    }
}

#[cfg(test)]
mod batching_route_tests {
    use super::*;
    use crate::tensor::Tensor;
    use std::path::PathBuf;

    const MANIFEST: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "fir_tina_f32_B1_L1024", "op": "fir", "impl": "tina",
         "dtype": "f32", "params": {"batch": 1},
         "inputs": [{"shape": [1, 1024], "dtype": "float32"}],
         "outputs": [{"shape": [1, 961], "dtype": "float32"}],
         "file": "a.hlo.txt"},
        {"name": "fir_tina_f32_B8_L1024", "op": "fir", "impl": "tina",
         "dtype": "f32", "params": {"batch": 8},
         "inputs": [{"shape": [8, 1024], "dtype": "float32"}],
         "outputs": [{"shape": [8, 961], "dtype": "float32"}],
         "file": "b.hlo.txt"}
      ]
    }"#;

    fn router() -> Router {
        let reg =
            Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap();
        Router::new(reg, RouterConfig::default())
    }

    #[test]
    fn serving_mode_prefers_multi_row_artifact() {
        let r = router();
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 1024])]);
        match r.route_with_batching(&req, true).unwrap() {
            Target::Artifact { name, pad_batch } => {
                assert_eq!(name, "fir_tina_f32_B8_L1024");
                assert_eq!(pad_batch, 8);
            }
            t => panic!("unexpected {t:?}"),
        }
        // without the preference, the exact B=1 artifact wins
        match r.route(&req).unwrap() {
            Target::Artifact { name, pad_batch } => {
                assert_eq!(name, "fir_tina_f32_B1_L1024");
                assert_eq!(pad_batch, 1);
            }
            t => panic!("unexpected {t:?}"),
        }
    }

    #[test]
    fn non_batchable_ops_unaffected() {
        let r = router();
        // matmul is not batchable; with no artifact it goes to interp even
        // in serving mode
        let req = OpRequest::new(
            OpKind::MatMul,
            vec![Tensor::zeros(&[4, 4]), Tensor::zeros(&[4, 4])],
        );
        assert!(matches!(
            r.route_with_batching(&req, true).unwrap(),
            Target::Interp { .. }
        ));
    }
}
