//! Length-prefixed binary framing for the TCP serving front-end.
//!
//! The JSON line protocol prints every f32 in decimal — at serving scale
//! serialization dwarfs kernel time, and decimal round-trips are not
//! bit-exact.  This module owns the binary alternative: every frame is
//!
//! ```text
//! [0xB7, 0x54]  magic    (2 bytes; 0xB7 is not a valid JSON first byte,
//!                         so the server auto-detects the mode from the
//!                         first byte of a connection)
//! [0x01]        version  (1 byte)
//! [type]        frame type (1 byte, see [`FrameType`])
//! [len]         payload length (u32 LE, capped by the reader)
//! [payload]     `len` bytes
//! ```
//!
//! Sample payloads are raw little-endian f32 bytes — never decimal text —
//! and decoding borrows straight from the payload slice ([`Cur`]): the
//! only copy is `chunks_exact(4)` → `f32::from_le_bytes` into the
//! destination `Vec<f32>`, with no intermediate JSON values.  Non-finite
//! values (NaN, ±inf) round-trip bit-exactly, which JSON cannot do.
//!
//! Framing errors are typed ([`FrameError`]) so the server can keep the
//! connection alive when the frame boundary is intact (a malformed
//! payload) and close it when synchronization is lost (bad magic, bad
//! version, oversized length).

use super::request::{ImplPref, OpKind, Precision};
use crate::coordinator::request::OpResponse;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::fmt;
use std::io::Read;
use std::time::Duration;

/// Frame magic: the first byte 0xB7 is invalid as the start of any JSON
/// document, which is what lets the server sniff the protocol from the
/// first byte of a connection.
pub const MAGIC: [u8; 2] = [0xB7, 0x54];

/// Protocol version this module speaks.
pub const VERSION: u8 = 1;

/// Bytes in a frame header (magic + version + type + u32 length).
pub const HEADER_LEN: usize = 8;

/// Default cap on a single frame's payload (64 MiB) — the same bound the
/// JSON compat mode puts on a line.
pub const DEFAULT_MAX_FRAME: usize = 64 << 20;

/// Highest tensor rank the wire format carries.
const MAX_RANK: u8 = 4;

/// Frame types of the binary protocol.  Client→server: `Request`,
/// `SessionOpen`, `SessionPush`, `SessionClose`, `Stats`.  Server→client:
/// the rest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameType {
    /// One op request (client→server).
    Request,
    /// Successful op reply (server→client).
    Response,
    /// Error reply; `id` 0 when the request id could not be recovered.
    Error,
    /// Open a streaming session (client→server).
    SessionOpen,
    /// Session granted: carries the session id and overlap (server→client).
    SessionOpened,
    /// Push one chunk of samples into a session (client→server).
    SessionPush,
    /// Output samples for one pushed chunk (server→client).
    SessionData,
    /// Close a session (client→server).
    SessionClose,
    /// Session summary after close (server→client).
    SessionClosed,
    /// Request the metrics report (client→server).
    Stats,
    /// Metrics report text (server→client).
    StatsReply,
}

impl FrameType {
    /// Wire byte of this frame type.
    pub fn as_u8(self) -> u8 {
        match self {
            FrameType::Request => 1,
            FrameType::Response => 2,
            FrameType::Error => 3,
            FrameType::SessionOpen => 4,
            FrameType::SessionOpened => 5,
            FrameType::SessionPush => 6,
            FrameType::SessionData => 7,
            FrameType::SessionClose => 8,
            FrameType::SessionClosed => 9,
            FrameType::Stats => 10,
            FrameType::StatsReply => 11,
        }
    }

    /// Inverse of [`FrameType::as_u8`].
    pub fn from_u8(b: u8) -> Option<FrameType> {
        Some(match b {
            1 => FrameType::Request,
            2 => FrameType::Response,
            3 => FrameType::Error,
            4 => FrameType::SessionOpen,
            5 => FrameType::SessionOpened,
            6 => FrameType::SessionPush,
            7 => FrameType::SessionData,
            8 => FrameType::SessionClose,
            9 => FrameType::SessionClosed,
            10 => FrameType::Stats,
            11 => FrameType::StatsReply,
            _ => return None,
        })
    }
}

/// Typed framing/decoding failure.  The server maps these onto its two
/// recovery modes: payload-level errors (`Malformed`) keep the connection
/// (the frame boundary is intact), stream-level errors close it.
#[derive(Debug)]
pub enum FrameError {
    /// Underlying socket/reader error.
    Io(std::io::Error),
    /// The two magic bytes did not match [`MAGIC`].
    BadMagic,
    /// Unsupported protocol version.
    BadVersion(u8),
    /// Unknown frame-type byte.
    UnknownType(u8),
    /// Declared payload length exceeds the reader's cap.
    Oversized(usize),
    /// The stream ended inside a frame.
    Truncated,
    /// The payload did not decode as its frame type.
    Malformed(String),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "io error: {e}"),
            FrameError::BadMagic => write!(f, "bad frame magic"),
            FrameError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (want {VERSION})")
            }
            FrameError::UnknownType(t) => write!(f, "unknown frame type {t}"),
            FrameError::Oversized(n) => write!(f, "oversized frame: {n} bytes"),
            FrameError::Truncated => write!(f, "truncated frame"),
            FrameError::Malformed(m) => write!(f, "malformed payload: {m}"),
        }
    }
}

impl std::error::Error for FrameError {}

fn malformed(msg: impl Into<String>) -> FrameError {
    FrameError::Malformed(msg.into())
}

fn read_exact_or_truncated<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), FrameError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            FrameError::Truncated
        } else {
            FrameError::Io(e)
        }
    })
}

/// Read one frame header + payload from `r` into the reusable `payload`
/// buffer.  Returns `Ok(None)` on a clean EOF at a frame boundary,
/// `Ok(Some(frame_type))` with `payload` filled otherwise.  A stream
/// ending mid-frame is [`FrameError::Truncated`]; a declared length above
/// `max_frame` is [`FrameError::Oversized`] (the payload is not read).
pub fn read_frame<R: Read>(
    r: &mut R,
    payload: &mut Vec<u8>,
    max_frame: usize,
) -> Result<Option<FrameType>, FrameError> {
    // first byte by hand: zero bytes here is a clean close, not an error
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    let mut rest = [0u8; HEADER_LEN - 1];
    read_exact_or_truncated(r, &mut rest)?;
    if first[0] != MAGIC[0] || rest[0] != MAGIC[1] {
        return Err(FrameError::BadMagic);
    }
    if rest[1] != VERSION {
        return Err(FrameError::BadVersion(rest[1]));
    }
    let ft = FrameType::from_u8(rest[2]).ok_or(FrameError::UnknownType(rest[2]))?;
    let len = u32::from_le_bytes([rest[3], rest[4], rest[5], rest[6]]) as usize;
    if len > max_frame {
        return Err(FrameError::Oversized(len));
    }
    payload.clear();
    payload.resize(len, 0);
    read_exact_or_truncated(r, payload)?;
    Ok(Some(ft))
}

// ---------------------------------------------------------------------------
// payload cursor (borrowed-slice reads; the single copy is into the
// destination Vec<f32>)
// ---------------------------------------------------------------------------

struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| malformed("length overflow"))?;
        if end > self.b.len() {
            return Err(malformed("payload too short"));
        }
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f64(&mut self) -> Result<f64, FrameError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// Decode `n` little-endian f32s — the hot ingest path: one pass over
    /// the borrowed payload slice into the destination vector.
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, FrameError> {
        let nbytes = n.checked_mul(4).ok_or_else(|| malformed("f32 count overflow"))?;
        let bytes = self.take(nbytes)?;
        let mut v = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(v)
    }

    fn string(&mut self, n: usize) -> Result<String, FrameError> {
        let bytes = self.take(n)?;
        std::str::from_utf8(bytes)
            .map(str::to_owned)
            .map_err(|_| malformed("invalid utf-8 string"))
    }

    /// Decode one tensor: rank u8, dims u32 each, then raw f32 data.
    fn tensor(&mut self) -> Result<Tensor, FrameError> {
        let rank = self.u8()?;
        if rank == 0 || rank > MAX_RANK {
            return Err(malformed(format!("tensor rank {rank} out of 1..={MAX_RANK}")));
        }
        let mut shape = Vec::with_capacity(rank as usize);
        let mut numel = 1usize;
        for _ in 0..rank {
            let d = self.u32()? as usize;
            numel = numel
                .checked_mul(d)
                .ok_or_else(|| malformed("tensor element count overflow"))?;
            shape.push(d);
        }
        let data = self.f32s(numel)?;
        Tensor::new(&shape, data).map_err(|e| malformed(format!("bad tensor: {e}")))
    }

    /// Every decoder must consume the payload exactly.
    fn finish(&self) -> Result<(), FrameError> {
        if self.pos != self.b.len() {
            return Err(malformed(format!(
                "{} trailing bytes after payload",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// encode helpers
// ---------------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_short_str(out: &mut Vec<u8>, s: &str) {
    debug_assert!(s.len() <= u8::MAX as usize, "short string too long");
    out.push(s.len() as u8);
    out.extend_from_slice(s.as_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    out.push(t.rank() as u8);
    for &d in t.shape() {
        put_u32(out, d as u32);
    }
    put_f32s(out, t.data());
}

/// Prepend the frame header to a finished payload body.
fn finish_frame(ft: FrameType, body: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(ft.as_u8());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

// ---------------------------------------------------------------------------
// typed frames
// ---------------------------------------------------------------------------

/// A decoded op request (the binary twin of the JSON request object).
#[derive(Debug)]
pub struct WireRequest {
    /// Client-chosen correlation id, echoed in the reply.
    pub id: u64,
    /// The op to execute.
    pub op: OpKind,
    /// Implementation preference.
    pub impl_pref: ImplPref,
    /// Compute precision.
    pub precision: Precision,
    /// Optional deadline budget in milliseconds (fractional allowed).
    pub deadline_ms: Option<f64>,
    /// Input tensors, decoded straight from the raw LE payload.
    pub inputs: Vec<Tensor>,
}

/// Frames a client sends.
#[derive(Debug)]
pub enum ClientFrame {
    /// One op request.
    Request(WireRequest),
    /// Open a streaming session.
    SessionOpen {
        /// Correlation id.
        id: u64,
        /// The op the session streams (currently `fir` only).
        op: OpKind,
    },
    /// Push one chunk of samples into an open session.
    SessionPush {
        /// Correlation id.
        id: u64,
        /// Session id from [`ServerFrame::SessionOpened`].
        session: u64,
        /// Optional per-chunk deadline budget (ms).
        deadline_ms: Option<f64>,
        /// The chunk's samples.
        samples: Vec<f32>,
    },
    /// Close a session.
    SessionClose {
        /// Correlation id.
        id: u64,
        /// Session id to close.
        session: u64,
    },
    /// Request the metrics report.
    Stats {
        /// Correlation id.
        id: u64,
    },
}

/// Frames the server sends (decoded by clients and tests).
#[derive(Debug)]
pub enum ServerFrame {
    /// Successful op reply.
    Response {
        /// Echo of the request id.
        id: u64,
        /// Whether the request rode a coalesced batch.
        batched: bool,
        /// Submit-to-completion latency in microseconds.
        latency_us: f64,
        /// Artifact name or `interp:<op>`.
        served_by: String,
        /// Output tensors.
        outputs: Vec<Tensor>,
    },
    /// Error reply (id 0 when the request id was unrecoverable).
    Error {
        /// Echo of the request id, or 0.
        id: u64,
        /// Human-readable error.
        message: String,
    },
    /// Session granted.
    SessionOpened {
        /// Echo of the request id.
        id: u64,
        /// Server-assigned session id.
        session: u64,
        /// Overlap (carried tail length) the session maintains.
        overlap: u64,
    },
    /// Output samples for one pushed chunk (empty while the session is
    /// still accumulating its first `overlap` samples).
    SessionData {
        /// Echo of the request id.
        id: u64,
        /// Session id.
        session: u64,
        /// Zero-based index of the pushed chunk.
        chunk_index: u64,
        /// Output samples.
        samples: Vec<f32>,
    },
    /// Session summary after close.
    SessionClosed {
        /// Echo of the request id.
        id: u64,
        /// Session id.
        session: u64,
        /// Chunks pushed over the session's lifetime.
        chunks: u64,
        /// Input samples consumed.
        samples_in: u64,
        /// Output samples produced.
        samples_out: u64,
    },
    /// Metrics report text.
    StatsReply {
        /// Echo of the request id.
        id: u64,
        /// The multi-line metrics report.
        report: String,
    },
}

/// Best-effort request-id recovery from a payload whose full decode
/// failed: every payload starts with the u64 id, so the error reply can
/// still be correlated when at least 8 bytes arrived.
pub fn peek_id(payload: &[u8]) -> u64 {
    if payload.len() >= 8 {
        u64::from_le_bytes([
            payload[0], payload[1], payload[2], payload[3], payload[4], payload[5], payload[6],
            payload[7],
        ])
    } else {
        0
    }
}

/// Convert a client-supplied millisecond budget into a `Duration` without
/// truncating fractional values: `0.9` becomes 900 µs, not a zero-length
/// deadline that sheds instantly.  Rejects NaN, negatives and overflow.
pub fn deadline_from_ms(ms: f64) -> Result<Duration> {
    if !ms.is_finite() || ms < 0.0 {
        bail!("bad 'deadline_ms': expected a non-negative finite number, got {ms}");
    }
    Duration::try_from_secs_f64(ms / 1000.0)
        .map_err(|e| anyhow::anyhow!("bad 'deadline_ms' {ms}: {e}"))
}

// ---------------------------------------------------------------------------
// decoders
// ---------------------------------------------------------------------------

fn decode_op(cur: &mut Cur<'_>) -> Result<OpKind, FrameError> {
    let n = cur.u8()? as usize;
    let s = cur.string(n)?;
    OpKind::parse(&s).map_err(|e| malformed(e.to_string()))
}

fn decode_deadline(cur: &mut Cur<'_>) -> Result<Option<f64>, FrameError> {
    match cur.u8()? {
        0 => Ok(None),
        1 => Ok(Some(cur.f64()?)),
        b => Err(malformed(format!("bad deadline flag {b}"))),
    }
}

/// Decode a client frame from its type and payload.
pub fn decode_client_frame(ft: FrameType, payload: &[u8]) -> Result<ClientFrame, FrameError> {
    let mut cur = Cur::new(payload);
    let frame = match ft {
        FrameType::Request => {
            let id = cur.u64()?;
            let op = decode_op(&mut cur)?;
            let n = cur.u8()? as usize;
            let impl_pref =
                ImplPref::parse(&cur.string(n)?).map_err(|e| malformed(e.to_string()))?;
            let n = cur.u8()? as usize;
            let precision =
                Precision::parse(&cur.string(n)?).map_err(|e| malformed(e.to_string()))?;
            let deadline_ms = decode_deadline(&mut cur)?;
            let n_inputs = cur.u16()? as usize;
            let mut inputs = Vec::with_capacity(n_inputs.min(16));
            for _ in 0..n_inputs {
                inputs.push(cur.tensor()?);
            }
            ClientFrame::Request(WireRequest {
                id,
                op,
                impl_pref,
                precision,
                deadline_ms,
                inputs,
            })
        }
        FrameType::SessionOpen => {
            let id = cur.u64()?;
            let op = decode_op(&mut cur)?;
            ClientFrame::SessionOpen { id, op }
        }
        FrameType::SessionPush => {
            let id = cur.u64()?;
            let session = cur.u64()?;
            let deadline_ms = decode_deadline(&mut cur)?;
            let n = cur.u32()? as usize;
            let samples = cur.f32s(n)?;
            ClientFrame::SessionPush {
                id,
                session,
                deadline_ms,
                samples,
            }
        }
        FrameType::SessionClose => {
            let id = cur.u64()?;
            let session = cur.u64()?;
            ClientFrame::SessionClose { id, session }
        }
        FrameType::Stats => {
            let id = cur.u64()?;
            ClientFrame::Stats { id }
        }
        other => {
            return Err(malformed(format!(
                "frame type {:?} is not a client frame",
                other
            )))
        }
    };
    cur.finish()?;
    Ok(frame)
}

/// Decode a server frame from its type and payload.
pub fn decode_server_frame(ft: FrameType, payload: &[u8]) -> Result<ServerFrame, FrameError> {
    let mut cur = Cur::new(payload);
    let frame = match ft {
        FrameType::Response => {
            let id = cur.u64()?;
            let batched = cur.u8()? != 0;
            let latency_us = cur.f64()?;
            let n = cur.u16()? as usize;
            let served_by = cur.string(n)?;
            let n_outputs = cur.u16()? as usize;
            let mut outputs = Vec::with_capacity(n_outputs.min(16));
            for _ in 0..n_outputs {
                outputs.push(cur.tensor()?);
            }
            ServerFrame::Response {
                id,
                batched,
                latency_us,
                served_by,
                outputs,
            }
        }
        FrameType::Error => {
            let id = cur.u64()?;
            let n = cur.u32()? as usize;
            let message = cur.string(n)?;
            ServerFrame::Error { id, message }
        }
        FrameType::SessionOpened => {
            let id = cur.u64()?;
            let session = cur.u64()?;
            let overlap = cur.u64()?;
            ServerFrame::SessionOpened {
                id,
                session,
                overlap,
            }
        }
        FrameType::SessionData => {
            let id = cur.u64()?;
            let session = cur.u64()?;
            let chunk_index = cur.u64()?;
            let n = cur.u32()? as usize;
            let samples = cur.f32s(n)?;
            ServerFrame::SessionData {
                id,
                session,
                chunk_index,
                samples,
            }
        }
        FrameType::SessionClosed => {
            let id = cur.u64()?;
            let session = cur.u64()?;
            let chunks = cur.u64()?;
            let samples_in = cur.u64()?;
            let samples_out = cur.u64()?;
            ServerFrame::SessionClosed {
                id,
                session,
                chunks,
                samples_in,
                samples_out,
            }
        }
        FrameType::StatsReply => {
            let id = cur.u64()?;
            let n = cur.u32()? as usize;
            let report = cur.string(n)?;
            ServerFrame::StatsReply { id, report }
        }
        other => {
            return Err(malformed(format!(
                "frame type {:?} is not a server frame",
                other
            )))
        }
    };
    cur.finish()?;
    Ok(frame)
}

// ---------------------------------------------------------------------------
// encoders
// ---------------------------------------------------------------------------

/// Encode an op request frame.
pub fn encode_request(
    id: u64,
    op: OpKind,
    impl_pref: ImplPref,
    precision: Precision,
    deadline_ms: Option<f64>,
    inputs: &[Tensor],
) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_short_str(&mut body, op.as_str());
    put_short_str(&mut body, impl_pref.as_str());
    put_short_str(&mut body, precision.as_str());
    match deadline_ms {
        Some(ms) => {
            body.push(1);
            put_f64(&mut body, ms);
        }
        None => body.push(0),
    }
    put_u16(&mut body, inputs.len() as u16);
    for t in inputs {
        put_tensor(&mut body, t);
    }
    finish_frame(FrameType::Request, body)
}

/// Encode a successful op reply.
pub fn encode_response(id: u64, resp: &OpResponse, latency_us: f64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    body.push(resp.batched as u8);
    put_f64(&mut body, latency_us);
    let sb = resp.served_by.as_bytes();
    let n = sb.len().min(u16::MAX as usize);
    put_u16(&mut body, n as u16);
    body.extend_from_slice(&sb[..n]);
    put_u16(&mut body, resp.outputs.len() as u16);
    for t in &resp.outputs {
        put_tensor(&mut body, t);
    }
    finish_frame(FrameType::Response, body)
}

/// Encode an error reply.
pub fn encode_error(id: u64, message: &str) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u32(&mut body, message.len() as u32);
    body.extend_from_slice(message.as_bytes());
    finish_frame(FrameType::Error, body)
}

/// Encode a session-open request.
pub fn encode_session_open(id: u64, op: OpKind) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_short_str(&mut body, op.as_str());
    finish_frame(FrameType::SessionOpen, body)
}

/// Encode a session-granted reply.
pub fn encode_session_opened(id: u64, session: u64, overlap: u64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, session);
    put_u64(&mut body, overlap);
    finish_frame(FrameType::SessionOpened, body)
}

/// Encode a session chunk push.
pub fn encode_session_push(
    id: u64,
    session: u64,
    deadline_ms: Option<f64>,
    samples: &[f32],
) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, session);
    match deadline_ms {
        Some(ms) => {
            body.push(1);
            put_f64(&mut body, ms);
        }
        None => body.push(0),
    }
    put_u32(&mut body, samples.len() as u32);
    put_f32s(&mut body, samples);
    finish_frame(FrameType::SessionPush, body)
}

/// Encode the output samples of one pushed chunk.
pub fn encode_session_data(id: u64, session: u64, chunk_index: u64, samples: &[f32]) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, session);
    put_u64(&mut body, chunk_index);
    put_u32(&mut body, samples.len() as u32);
    put_f32s(&mut body, samples);
    finish_frame(FrameType::SessionData, body)
}

/// Encode a session-close request.
pub fn encode_session_close(id: u64, session: u64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, session);
    finish_frame(FrameType::SessionClose, body)
}

/// Encode a session summary reply.
pub fn encode_session_closed(
    id: u64,
    session: u64,
    chunks: u64,
    samples_in: u64,
    samples_out: u64,
) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u64(&mut body, session);
    put_u64(&mut body, chunks);
    put_u64(&mut body, samples_in);
    put_u64(&mut body, samples_out);
    finish_frame(FrameType::SessionClosed, body)
}

/// Encode a stats request.
pub fn encode_stats(id: u64) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    finish_frame(FrameType::Stats, body)
}

/// Encode a stats reply.
pub fn encode_stats_reply(id: u64, report: &str) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, id);
    put_u32(&mut body, report.len() as u32);
    body.extend_from_slice(report.as_bytes());
    finish_frame(FrameType::StatsReply, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip_client(bytes: &[u8]) -> ClientFrame {
        let mut r = Cursor::new(bytes);
        let mut payload = Vec::new();
        let ft = read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME)
            .unwrap()
            .unwrap();
        decode_client_frame(ft, &payload).unwrap()
    }

    fn roundtrip_server(bytes: &[u8]) -> ServerFrame {
        let mut r = Cursor::new(bytes);
        let mut payload = Vec::new();
        let ft = read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME)
            .unwrap()
            .unwrap();
        decode_server_frame(ft, &payload).unwrap()
    }

    #[test]
    fn request_roundtrips_bit_exactly() {
        let t = Tensor::new(
            &[2, 3],
            vec![1.5, -0.0, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 9e15],
        )
        .unwrap();
        let bytes = encode_request(
            7,
            OpKind::Fir,
            ImplPref::Interp,
            Precision::Bf16,
            Some(0.9),
            std::slice::from_ref(&t),
        );
        let ClientFrame::Request(req) = roundtrip_client(&bytes) else {
            panic!("expected request frame");
        };
        assert_eq!(req.id, 7);
        assert_eq!(req.op, OpKind::Fir);
        assert_eq!(req.impl_pref, ImplPref::Interp);
        assert_eq!(req.precision, Precision::Bf16);
        assert_eq!(req.deadline_ms, Some(0.9));
        assert_eq!(req.inputs.len(), 1);
        assert_eq!(req.inputs[0].shape(), &[2, 3]);
        // bit-exact, including NaN and signed zero — JSON cannot do this
        for (a, b) in req.inputs[0].data().iter().zip(t.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn response_and_error_roundtrip() {
        let resp = OpResponse {
            outputs: vec![Tensor::new(&[1, 2], vec![f32::MAX, f32::MIN]).unwrap()],
            served_by: "interp:fir".into(),
            batched: true,
        };
        let bytes = encode_response(42, &resp, 812.5);
        let ServerFrame::Response {
            id,
            batched,
            latency_us,
            served_by,
            outputs,
        } = roundtrip_server(&bytes)
        else {
            panic!("expected response frame");
        };
        assert_eq!(id, 42);
        assert!(batched);
        assert_eq!(latency_us, 812.5);
        assert_eq!(served_by, "interp:fir");
        assert_eq!(outputs[0].data(), resp.outputs[0].data());

        let ServerFrame::Error { id, message } = roundtrip_server(&encode_error(3, "boom")) else {
            panic!("expected error frame");
        };
        assert_eq!((id, message.as_str()), (3, "boom"));
    }

    #[test]
    fn session_frames_roundtrip() {
        let open = roundtrip_client(&encode_session_open(1, OpKind::Fir));
        let ClientFrame::SessionOpen { id, op } = open else {
            panic!("expected session open");
        };
        assert_eq!((id, op), (1, OpKind::Fir));

        let samples = vec![0.25f32, -1.0, f32::NAN];
        let ClientFrame::SessionPush {
            id,
            session,
            deadline_ms,
            samples: got,
        } = roundtrip_client(&encode_session_push(2, 9, None, &samples))
        else {
            panic!("expected session push");
        };
        assert_eq!((id, session, deadline_ms), (2, 9, None));
        for (a, b) in got.iter().zip(&samples) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        let ServerFrame::SessionData {
            chunk_index,
            samples: out,
            ..
        } = roundtrip_server(&encode_session_data(2, 9, 4, &samples))
        else {
            panic!("expected session data");
        };
        assert_eq!(chunk_index, 4);
        assert_eq!(out.len(), 3);

        let ServerFrame::SessionClosed {
            chunks,
            samples_in,
            samples_out,
            ..
        } = roundtrip_server(&encode_session_closed(3, 9, 5, 1000, 937))
        else {
            panic!("expected session closed");
        };
        assert_eq!((chunks, samples_in, samples_out), (5, 1000, 937));
    }

    #[test]
    fn stats_frames_roundtrip() {
        let ClientFrame::Stats { id } = roundtrip_client(&encode_stats(11)) else {
            panic!("expected stats");
        };
        assert_eq!(id, 11);
        let ServerFrame::StatsReply { id, report } =
            roundtrip_server(&encode_stats_reply(11, "requests=0"))
        else {
            panic!("expected stats reply");
        };
        assert_eq!((id, report.as_str()), (11, "requests=0"));
    }

    #[test]
    fn clean_eof_is_none_and_mid_frame_eof_is_truncated() {
        let mut payload = Vec::new();
        let mut empty = Cursor::new(&[][..]);
        assert!(read_frame(&mut empty, &mut payload, DEFAULT_MAX_FRAME)
            .unwrap()
            .is_none());
        let bytes = encode_stats(1);
        for cut in 1..bytes.len() {
            let mut r = Cursor::new(&bytes[..cut]);
            match read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME) {
                Err(FrameError::Truncated) => {}
                other => panic!("cut at {cut}: expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn bad_magic_version_type_and_oversized_are_typed_errors() {
        let mut payload = Vec::new();
        let good = encode_stats(1);

        let mut bad = good.clone();
        bad[0] = b'{';
        let mut r = Cursor::new(&bad[..]);
        assert!(matches!(
            read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME),
            Err(FrameError::BadMagic)
        ));

        let mut bad = good.clone();
        bad[2] = 99;
        let mut r = Cursor::new(&bad[..]);
        assert!(matches!(
            read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME),
            Err(FrameError::BadVersion(99))
        ));

        let mut bad = good.clone();
        bad[3] = 200;
        let mut r = Cursor::new(&bad[..]);
        assert!(matches!(
            read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME),
            Err(FrameError::UnknownType(200))
        ));

        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut r = Cursor::new(&bad[..]);
        assert!(matches!(
            read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME),
            Err(FrameError::Oversized(_))
        ));
    }

    #[test]
    fn trailing_bytes_and_bad_fields_are_malformed() {
        // trailing bytes after a fully decoded payload
        let mut bytes = encode_stats(1);
        let extra = 3u32;
        let n = bytes.len();
        bytes[4..8].copy_from_slice(&(8 + extra).to_le_bytes());
        bytes.resize(n + extra as usize, 0xEE);
        let mut r = Cursor::new(&bytes[..]);
        let mut payload = Vec::new();
        let ft = read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME)
            .unwrap()
            .unwrap();
        assert!(matches!(
            decode_client_frame(ft, &payload),
            Err(FrameError::Malformed(_))
        ));
        // a rank-9 tensor is malformed, not a panic
        let t = Tensor::new(&[1, 4], vec![0.0; 4]).unwrap();
        let mut req = encode_request(1, OpKind::Fir, ImplPref::Auto, Precision::F32, None, &[t]);
        let rank_pos = HEADER_LEN + 8 + 4 + 5 + 4 + 1 + 2;
        assert_eq!(req[rank_pos], 2, "encoded rank sits where the decoder reads it");
        req[rank_pos] = 9;
        let mut r = Cursor::new(&req[..]);
        let ft = read_frame(&mut r, &mut payload, DEFAULT_MAX_FRAME)
            .unwrap()
            .unwrap();
        assert!(matches!(
            decode_client_frame(ft, &payload),
            Err(FrameError::Malformed(_))
        ));
    }

    #[test]
    fn peek_id_recovers_the_leading_id() {
        let bytes = encode_error(77, "x");
        assert_eq!(peek_id(&bytes[HEADER_LEN..]), 77);
        assert_eq!(peek_id(&[1, 2, 3]), 0, "short payloads fall back to 0");
    }

    #[test]
    fn deadline_from_ms_keeps_fractional_budgets() {
        assert_eq!(deadline_from_ms(0.9).unwrap(), Duration::from_micros(900));
        assert_eq!(deadline_from_ms(0.0).unwrap(), Duration::ZERO);
        assert_eq!(deadline_from_ms(1500.0).unwrap(), Duration::from_millis(1500));
        assert!(deadline_from_ms(f64::NAN).is_err());
        assert!(deadline_from_ms(-1.0).is_err());
        assert!(deadline_from_ms(f64::INFINITY).is_err());
        assert!(deadline_from_ms(1e300).is_err(), "overflow must not panic");
    }
}
