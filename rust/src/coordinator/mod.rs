//! L3 coordinator: the serving layer wrapped around the TINA artifacts.
//!
//! The paper's contribution is the function->NN-layer mapping (L1/L2);
//! per the architecture rules the rust layer turns it into a deployable
//! runtime: request routing across compiled artifacts, dynamic batching,
//! a worker pool with bounded-queue backpressure, composite pipelines
//! (the PFB use case), metrics, and a TCP server speaking a length-
//! prefixed binary frame protocol ([`wire`]) with pipelined requests and
//! streaming sessions ([`session`]), plus the original JSON line protocol
//! as a per-connection auto-detected debug/compat mode ([`server`]).
//!
//! # Batching model
//!
//! Two kinds of traffic coalesce in the [`Batcher`]:
//!
//! * **Artifact batches** pad along a compiled artifact's *fixed* leading
//!   batch dimension (the PJRT ABI is frozen at compile time).
//! * **Fallback batches** are *shape-bucketed*: batchable single-row
//!   requests group per `(op, signal length)`, and a formed batch pads up
//!   to the next power-of-two bucket `B ∈ {1, 2, 4, 8, ...}` (capped at
//!   [`BatcherConfig::max_bucket`]).  The planned executor compiles one
//!   plan per (op, shape, B) — cached and LRU-bounded per entry by
//!   [`RouterConfig::plan_cache_cap`] — runs the bucket in one execution,
//!   and scatters per-request outputs row by row from its terminal views.
//!   Padding rows are zero-filled on the way in and never gathered on the
//!   way out, so they cannot leak into replies; a lone request is just
//!   the degenerate B=1 bucket of the same path.
//!
//! # Completion-driven request lifecycle
//!
//! Replies to batched requests are completed *directly from the exec-pool
//! worker that ran the batch* — the request's response slot, op label,
//! submit timestamp `t0`, and optional client deadline travel through the
//! batcher inside a [`batcher::Completion`], and the drain-side scatter
//! finishes each response in place.  No thread-pool worker is ever parked
//! on a relay wait, so in-flight batched concurrency is bounded only by
//! the [`batcher::InflightGate`]
//! ([`CoordinatorConfig::max_inflight_batched`], bounded waiting at
//! enqueue per [`CoordinatorConfig::admission_timeout`]), not by the pool
//! size.  On top of the freed drain loop, the batcher sizes fallback
//! buckets *adaptively*: a per-key EWMA of observed arrival rates picks
//! the effective bucket cap and flush deadline, clipper-style, with the
//! static [`BatcherConfig`] values as ceilings.
//!
//! # Fault containment
//!
//! Batches execute on a bounded, panic-isolating exec pool
//! (`util::threadpool::ExecPool`), never on detached per-batch threads.
//! A panicking kernel fails only its own batch's waiters; a poisoned
//! fallback plan key is quarantined with capped exponential backoff while
//! its traffic degrades to the bit-identical interpreter oracle; rows
//! whose client deadline expired are shed before execution; and a
//! saturated admission gate refuses work fast instead of queueing it
//! unboundedly.  See `service` module docs ("Failure domains") for the
//! full ladder, and `testing::faults` for the deterministic
//! fault-injection harness the chaos suite drives these paths with.
//!
//! [`Metrics`] surfaces the model: `batched_fallback_requests`,
//! `fallback_batches_executed`, `fallback_padded_rows`,
//! `batch_fill_ratio()`, per-bucket plan-cache hit/miss stats, the
//! `inflight_batched_requests` gauge, `drain_completions` (== batched
//! fallback requests when every bucket executes successfully — the
//! no-worker-relay invariant the e2e tests pin), and the
//! `adaptive_bucket_*` gauges.
//!
//! See the repo-root `ARCHITECTURE.md` for the full lifecycle walk-through
//! (submit → bucket → plan-cache → compile `(B, L)` → execute →
//! drain-thread scatter → completion).

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod request;
pub mod router;
pub mod server;
pub mod service;
pub mod session;
pub mod wire;

pub use batcher::{
    BatchKey, Batcher, BatcherConfig, BucketDecision, Completion, InflightGate, InflightPermit,
};
pub use metrics::Metrics;
pub use pipeline::{Pipeline, Stage};
pub use request::{ImplPref, OpKind, OpRequest, OpResponse, Precision};
pub use router::{PlanKey, Router, RouterConfig, Target};
pub use server::ServerConfig;
pub use service::{Coordinator, CoordinatorConfig};
pub use session::{SessionChunk, SessionConfig, SessionManager, SessionSummary};
pub use wire::{ClientFrame, FrameError, FrameType, ServerFrame, WireRequest};
