//! L3 coordinator: the serving layer wrapped around the TINA artifacts.
//!
//! The paper's contribution is the function->NN-layer mapping (L1/L2);
//! per the architecture rules the rust layer turns it into a deployable
//! runtime: request routing across compiled artifacts, dynamic batching
//! along the artifacts' leading batch dimension, a worker pool with
//! bounded-queue backpressure, composite pipelines (the PFB use case),
//! metrics, and a TCP JSON-line server.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod request;
pub mod router;
pub mod server;
pub mod service;

pub use batcher::{BatchKey, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use pipeline::{Pipeline, Stage};
pub use request::{ImplPref, OpKind, OpRequest, OpResponse, Precision};
pub use router::{Router, RouterConfig, Target};
pub use service::{Coordinator, CoordinatorConfig};
