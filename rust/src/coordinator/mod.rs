//! L3 coordinator: the serving layer wrapped around the TINA artifacts.
//!
//! The paper's contribution is the function->NN-layer mapping (L1/L2);
//! per the architecture rules the rust layer turns it into a deployable
//! runtime: request routing across compiled artifacts, dynamic batching,
//! a worker pool with bounded-queue backpressure, composite pipelines
//! (the PFB use case), metrics, and a TCP JSON-line server.
//!
//! # Batching model
//!
//! Two kinds of traffic coalesce in the [`Batcher`]:
//!
//! * **Artifact batches** pad along a compiled artifact's *fixed* leading
//!   batch dimension (the PJRT ABI is frozen at compile time).
//! * **Fallback batches** are *shape-bucketed*: batchable single-row
//!   requests group per `(op, signal length)`, and a formed batch pads up
//!   to the next power-of-two bucket `B ∈ {1, 2, 4, 8, ...}` (capped at
//!   [`BatcherConfig::max_bucket`]).  The planned executor compiles one
//!   plan per (op, shape, B) — cached and LRU-bounded per entry by
//!   [`RouterConfig::plan_cache_cap`] — runs the bucket in one execution,
//!   and scatters per-request outputs row by row from its terminal views.
//!   Padding rows are zero-filled on the way in and never gathered on the
//!   way out, so they cannot leak into replies; a lone request is just
//!   the degenerate B=1 bucket of the same path.
//!
//! [`Metrics`] surfaces the model: `batched_fallback_requests`,
//! `fallback_batches_executed`, `fallback_padded_rows`,
//! `batch_fill_ratio()`, and per-bucket plan-cache hit/miss stats.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod request;
pub mod router;
pub mod server;
pub mod service;

pub use batcher::{BatchKey, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use pipeline::{Pipeline, Stage};
pub use request::{ImplPref, OpKind, OpRequest, OpResponse, Precision};
pub use router::{Router, RouterConfig, Target};
pub use service::{Coordinator, CoordinatorConfig};
