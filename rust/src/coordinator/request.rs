//! Request/response types of the TINA serving surface.
//!
//! These are pure data: an [`OpRequest`] names an op, an implementation
//! preference, a precision, and input tensors; an [`OpResponse`] carries
//! output tensors plus provenance (`served_by`, `batched`).  The stable
//! contract consumers rely on: `served_by` is the artifact name for the
//! PJRT path and `"interp:<op>"` for the fallback path, regardless of
//! which engine (interpreter or planned executor) actually ran it.

use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::time::{Duration, Instant};

/// The signal-processing operations TINA serves (paper Table 1 + §5.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    /// Elementwise multiply (paper Table 1).
    EwMult,
    /// Elementwise add (paper Table 1).
    EwAdd,
    /// Matrix multiply (paper Table 1).
    MatMul,
    /// Reduce-sum of a vector (paper Table 1).
    Summation,
    /// Discrete Fourier transform, (re, im) outputs.
    Dft,
    /// Inverse DFT from a (re, im) pair.
    Idft,
    /// FIR low-pass filter over a (B, L) signal.
    Fir,
    /// Sliding-window unfold (im2col-style framing).
    Unfold,
    /// Polyphase filter bank, FIR stage only.
    PfbFir,
    /// Fused polyphase filter bank (FIR bank + DFT across branches).
    Pfb,
    /// Extension op (paper future work): short-time Fourier transform.
    Stft,
    /// IIR filter via fixed-depth unrolled iteration (paper §3's
    /// iterative-function case).
    Iir,
    /// Cross-correlation of a signal against a runtime template.
    Xcorr,
    /// Two-antenna FX correlator: per-antenna STFT, gain-calibrated
    /// conjugate multiply, frame accumulation.
    FxCorrelate,
    /// End-to-end spectrometer: PFB → |·|² → time integration as one
    /// fused graph.
    Spectrometer,
    /// Delay-and-sum beamformer over sensor channels.
    Beamform,
}

impl OpKind {
    /// Manifest `op` string.
    pub fn as_str(&self) -> &'static str {
        match self {
            OpKind::EwMult => "ewmult",
            OpKind::EwAdd => "ewadd",
            OpKind::MatMul => "matmul",
            OpKind::Summation => "summation",
            OpKind::Dft => "dft",
            OpKind::Idft => "idft",
            OpKind::Fir => "fir",
            OpKind::Unfold => "unfold",
            OpKind::PfbFir => "pfb_fir",
            OpKind::Pfb => "pfb",
            OpKind::Stft => "stft",
            OpKind::Iir => "iir",
            OpKind::Xcorr => "xcorr",
            OpKind::FxCorrelate => "fx_correlate",
            OpKind::Spectrometer => "spectrometer",
            OpKind::Beamform => "beamform",
        }
    }

    /// Inverse of [`OpKind::as_str`].
    pub fn parse(s: &str) -> Result<OpKind> {
        Ok(match s {
            "ewmult" => OpKind::EwMult,
            "ewadd" => OpKind::EwAdd,
            "matmul" => OpKind::MatMul,
            "summation" => OpKind::Summation,
            "dft" => OpKind::Dft,
            "idft" => OpKind::Idft,
            "fir" => OpKind::Fir,
            "unfold" => OpKind::Unfold,
            "pfb_fir" => OpKind::PfbFir,
            "pfb" => OpKind::Pfb,
            "stft" => OpKind::Stft,
            "iir" => OpKind::Iir,
            "xcorr" => OpKind::Xcorr,
            "fx_correlate" => OpKind::FxCorrelate,
            "spectrometer" => OpKind::Spectrometer,
            "beamform" => OpKind::Beamform,
            _ => bail!("unknown op '{s}'"),
        })
    }

    /// All ops, for sweeps.
    pub fn all() -> &'static [OpKind] {
        &[
            OpKind::EwMult,
            OpKind::EwAdd,
            OpKind::MatMul,
            OpKind::Summation,
            OpKind::Dft,
            OpKind::Idft,
            OpKind::Fir,
            OpKind::Unfold,
            OpKind::PfbFir,
            OpKind::Pfb,
            OpKind::Stft,
            OpKind::Iir,
            OpKind::Xcorr,
            OpKind::FxCorrelate,
            OpKind::Spectrometer,
            OpKind::Beamform,
        ]
    }

    /// Ops whose requests carry a (B, L) signal and can be coalesced along
    /// the batch axis by the dynamic batcher.
    pub fn batchable(&self) -> bool {
        matches!(
            self,
            OpKind::Fir
                | OpKind::PfbFir
                | OpKind::Pfb
                | OpKind::Stft
                | OpKind::Iir
                | OpKind::Spectrometer
        )
    }

    /// Input-tensor arity the op's lowering expects.
    pub fn expected_inputs(&self) -> usize {
        match self {
            OpKind::EwMult
            | OpKind::EwAdd
            | OpKind::MatMul
            | OpKind::Idft
            | OpKind::Xcorr
            | OpKind::FxCorrelate => 2,
            _ => 1,
        }
    }
}

/// Which implementation the client wants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ImplPref {
    /// TINA NN-layer artifact, fall back to the rust interpreter.
    #[default]
    Auto,
    /// TINA NN-layer artifact only (error if absent).
    Tina,
    /// Direct-jnp comparator artifact.
    JaxRef,
    /// Pure-rust TINA interpreter (no PJRT).
    Interp,
}

impl ImplPref {
    /// Inverse of [`ImplPref::as_str`].
    pub fn parse(s: &str) -> Result<ImplPref> {
        Ok(match s {
            "auto" => ImplPref::Auto,
            "tina" => ImplPref::Tina,
            "jaxref" => ImplPref::JaxRef,
            "interp" => ImplPref::Interp,
            _ => bail!("unknown impl '{s}'"),
        })
    }

    /// Stable string form (protocol/CLI spelling).
    pub fn as_str(&self) -> &'static str {
        match self {
            ImplPref::Auto => "auto",
            ImplPref::Tina => "tina",
            ImplPref::JaxRef => "jaxref",
            ImplPref::Interp => "interp",
        }
    }
}

/// Compute precision of the TINA variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// IEEE single precision (the default).
    #[default]
    F32,
    /// bfloat16 (accelerator-native reduced precision).
    Bf16,
}

impl Precision {
    /// Stable string form (protocol/CLI spelling).
    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }

    /// Inverse of [`Precision::as_str`].
    pub fn parse(s: &str) -> Result<Precision> {
        Ok(match s {
            "f32" => Precision::F32,
            "bf16" => Precision::Bf16,
            _ => bail!("unknown dtype '{s}'"),
        })
    }
}

/// One serving request.
#[derive(Debug, Clone)]
pub struct OpRequest {
    /// The op to execute.
    pub op: OpKind,
    /// Which implementation the client wants.
    pub impl_pref: ImplPref,
    /// Compute precision of the TINA variant.
    pub precision: Precision,
    /// Input tensors (arity per [`OpKind::expected_inputs`]).
    pub inputs: Vec<Tensor>,
    /// Optional client deadline: a request whose deadline has passed is
    /// shed (failed fast with a shed error) instead of executed — at
    /// admission if already expired, or in the drain loop if it expires
    /// while queued.  `None` (the default) never sheds.
    pub deadline: Option<Instant>,
}

impl OpRequest {
    /// Request with default routing (`Auto`, f32) and no deadline.
    pub fn new(op: OpKind, inputs: Vec<Tensor>) -> OpRequest {
        OpRequest {
            op,
            impl_pref: ImplPref::Auto,
            precision: Precision::F32,
            inputs,
            deadline: None,
        }
    }

    /// Set the implementation preference (builder style).
    pub fn with_impl(mut self, p: ImplPref) -> Self {
        self.impl_pref = p;
        self
    }

    /// Set the compute precision (builder style).
    pub fn with_precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    /// Set a relative deadline: the request is shed if it has not begun
    /// executing within `budget` of this call (builder style).
    pub fn with_deadline(self, budget: Duration) -> Self {
        self.with_deadline_at(Instant::now() + budget)
    }

    /// Set an absolute deadline (builder style).
    pub fn with_deadline_at(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Basic arity/rank validation before routing.
    pub fn validate(&self) -> Result<()> {
        if self.inputs.len() != self.op.expected_inputs() {
            bail!(
                "op {} wants {} inputs, got {}",
                self.op.as_str(),
                self.op.expected_inputs(),
                self.inputs.len()
            );
        }
        for (i, t) in self.inputs.iter().enumerate() {
            if t.is_empty() {
                bail!("input {i} is empty");
            }
        }
        Ok(())
    }
}

/// Response: output tensors plus how the request was served.
#[derive(Debug, Clone)]
pub struct OpResponse {
    /// Output tensors in the op's declared order.
    pub outputs: Vec<Tensor>,
    /// Artifact name, or "interp:<op>" for the fallback path.
    pub served_by: String,
    /// Whether the request rode a coalesced batch.
    pub batched: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_roundtrip() {
        for op in OpKind::all() {
            assert_eq!(OpKind::parse(op.as_str()).unwrap(), *op);
        }
        assert!(OpKind::parse("nope").is_err());
    }

    #[test]
    fn batchable_set() {
        assert!(OpKind::Fir.batchable());
        assert!(OpKind::Pfb.batchable());
        assert!(OpKind::Iir.batchable());
        assert!(OpKind::Spectrometer.batchable());
        assert!(!OpKind::MatMul.batchable());
        // two-signal / runtime-template ops can't ride the row batcher
        assert!(!OpKind::Xcorr.batchable());
        assert!(!OpKind::FxCorrelate.batchable());
        assert!(!OpKind::Beamform.batchable());
    }

    #[test]
    fn new_op_arities() {
        assert_eq!(OpKind::Xcorr.expected_inputs(), 2);
        assert_eq!(OpKind::FxCorrelate.expected_inputs(), 2);
        assert_eq!(OpKind::Iir.expected_inputs(), 1);
        assert_eq!(OpKind::Spectrometer.expected_inputs(), 1);
        assert_eq!(OpKind::Beamform.expected_inputs(), 1);
    }

    #[test]
    fn request_validation() {
        let ok = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 64])]);
        assert!(ok.validate().is_ok());
        let bad = OpRequest::new(OpKind::MatMul, vec![Tensor::zeros(&[2, 2])]);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn pref_parsing() {
        assert_eq!(ImplPref::parse("tina").unwrap(), ImplPref::Tina);
        assert_eq!(Precision::parse("bf16").unwrap(), Precision::Bf16);
        assert!(ImplPref::parse("x").is_err());
        assert!(Precision::parse("f64").is_err());
    }
}
