//! The coordinator service: ties router, batcher, worker pool, engine
//! handle and metrics into the serving object examples/benches/server use.
//!
//! Request path (all rust, no python):
//!
//! ```text
//!  submit(OpRequest)
//!    └─ route ──────────── artifact, batchable,  B==1 ─▶ batcher ─▶ engine
//!        ├──────────────── artifact, exact shape ──────▶ worker  ─▶ engine
//!        └──────────────── no artifact (Auto/Interp) ──▶ worker  ─▶ interpreter
//! ```

use super::batcher::{scatter_results, BatchKey, Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{OpRequest, OpResponse};
use super::router::{Router, RouterConfig, Target};
use crate::runtime::{EngineHandle, Registry};
use crate::tensor::Tensor;
use crate::util::threadpool::{OneShot, ThreadPool};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    pub router: RouterConfig,
    pub batcher: BatcherConfig,
    /// Worker threads handling non-batched requests.
    pub workers: usize,
    /// Bound on the worker queue (backpressure).
    pub queue_capacity: usize,
    /// Enable the dynamic batcher (ablation knob).
    pub batching: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            router: RouterConfig::default(),
            batcher: BatcherConfig::default(),
            workers: crate::util::threadpool::default_threads(),
            queue_capacity: 256,
            batching: true,
        }
    }
}

/// The serving coordinator.  Cheap to share via Arc; all methods take &self.
pub struct Coordinator {
    router: Arc<Router>,
    engine: EngineHandle,
    pool: ThreadPool,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    config: CoordinatorConfig,
    stop: Arc<AtomicBool>,
    drain_thread: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Coordinator {
    /// Build from an artifact directory.
    pub fn from_dir(dir: impl AsRef<std::path::Path>, config: CoordinatorConfig) -> Result<Self> {
        let registry = Registry::load(dir)?;
        Self::new(registry, config)
    }

    pub fn new(registry: Registry, config: CoordinatorConfig) -> Result<Self> {
        let engine = EngineHandle::spawn(registry.clone())?;
        let router = Arc::new(Router::new(registry, config.router.clone()));
        let batcher = Arc::new(Batcher::new(config.batcher));
        let metrics = Arc::new(Metrics::new());
        let pool = ThreadPool::new(config.workers, config.queue_capacity);
        let stop = Arc::new(AtomicBool::new(false));

        let coord = Coordinator {
            router,
            engine,
            pool,
            batcher,
            metrics,
            config,
            stop,
            drain_thread: std::sync::Mutex::new(None),
        };
        if coord.config.batching {
            coord.start_drain_loop();
        }
        Ok(coord)
    }

    fn start_drain_loop(&self) {
        let batcher = Arc::clone(&self.batcher);
        let engine = self.engine.clone();
        let metrics = Arc::clone(&self.metrics);
        let stop = Arc::clone(&self.stop);
        let handle = std::thread::Builder::new()
            .name("tina-batch-drain".into())
            .spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    if let Some(batch) = batcher.next_batch(Duration::from_millis(20)) {
                        let padding = batch.key.batch - batch.rows.len();
                        metrics.record_batch(batch.rows.len(), padding);
                        let result =
                            engine.execute(&batch.key.artifact, vec![batch.input.clone()]);
                        scatter_results(batch, result);
                    }
                }
            })
            .expect("spawn drain loop");
        *self.drain_thread.lock().unwrap() = Some(handle);
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    /// Warm the executable cache for every artifact of an op (or all).
    pub fn warmup(&self, op_filter: Option<&str>) -> Result<usize> {
        let mut n = 0;
        for meta in self.router.registry().entries() {
            if let Some(f) = op_filter {
                if meta.op != f {
                    continue;
                }
            }
            self.engine.prepare(&meta.name)?;
            n += 1;
        }
        Ok(n)
    }

    /// Submit asynchronously; the returned slot completes with the response.
    pub fn submit(&self, req: OpRequest) -> OneShot<Result<OpResponse>> {
        let slot: OneShot<Result<OpResponse>> = OneShot::new();
        self.metrics.record_request();
        // surface plan-cache evictions from *any* router path (including
        // direct oracle/interpreter use between requests), not just the
        // fallback compile below
        self.metrics
            .record_plan_cache_evictions(self.router.take_plan_cache_evictions());
        let t0 = Instant::now();

        let target = match self.router.route_with_batching(&req, self.config.batching) {
            Ok(t) => t,
            Err(e) => {
                self.metrics
                    .record_completion(req.op.as_str(), t0.elapsed(), false);
                slot.set(Err(e));
                return slot;
            }
        };

        match target {
            Target::Artifact { name, pad_batch } => {
                let batchable = self.config.batching
                    && req.op.batchable()
                    && req.inputs.len() == 1
                    && req.inputs[0].rank() == 2
                    && req.inputs[0].shape()[0] == 1
                    && pad_batch > 1;
                if batchable {
                    // ride the dynamic batcher
                    let key = BatchKey {
                        artifact: name.clone(),
                        batch: pad_batch,
                    };
                    let inner: OneShot<Result<Vec<Tensor>>> = OneShot::new();
                    self.batcher
                        .enqueue(key, req.inputs[0].clone(), inner.clone());
                    let metrics = Arc::clone(&self.metrics);
                    let op = req.op.as_str();
                    let out_slot = slot.clone();
                    self.pool.submit(move || {
                        let result = inner.wait().map(|outputs| OpResponse {
                            outputs,
                            served_by: name,
                            batched: true,
                        });
                        metrics.record_completion(op, t0.elapsed(), result.is_ok());
                        out_slot.set(result);
                    });
                } else {
                    let engine = self.engine.clone();
                    let metrics = Arc::clone(&self.metrics);
                    let op = req.op.as_str();
                    let out_slot = slot.clone();
                    let inputs = req.inputs;
                    self.pool.submit(move || {
                        let result = engine.execute(&name, inputs).map(|outputs| OpResponse {
                            outputs,
                            served_by: name,
                            batched: false,
                        });
                        metrics.record_completion(op, t0.elapsed(), result.is_ok());
                        out_slot.set(result);
                    });
                }
            }
            Target::Interp { key } => {
                // Fallback path: compile (or fetch) the exec plan and run
                // on the planned engine; the naive interpreter remains the
                // test oracle only.  `served_by` keeps the "interp:" prefix
                // as the stable fallback marker of the serving API.
                self.metrics.record_interp_fallback();
                let planned = match self.router.planned(&key, &req) {
                    Ok((p, hit)) => {
                        self.metrics.record_plan_cache(hit);
                        self.metrics
                            .record_plan_cache_evictions(self.router.take_plan_cache_evictions());
                        p
                    }
                    Err(e) => {
                        self.metrics
                            .record_completion(req.op.as_str(), t0.elapsed(), false);
                        slot.set(Err(e));
                        return slot;
                    }
                };
                let metrics = Arc::clone(&self.metrics);
                let op = req.op.as_str();
                let out_slot = slot.clone();
                let inputs = req.inputs;
                self.pool.submit(move || {
                    let result = planned.run(&inputs).map(|outputs| OpResponse {
                        outputs,
                        served_by: format!("interp:{op}"),
                        batched: false,
                    });
                    metrics.record_completion(op, t0.elapsed(), result.is_ok());
                    out_slot.set(result);
                });
            }
        }
        slot
    }

    /// Submit and wait.
    pub fn execute(&self, req: OpRequest) -> Result<OpResponse> {
        self.submit(req).wait()
    }

    /// Stop the batch drain loop (called on drop too).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.drain_thread.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Errors surfaced when building a coordinator without artifacts: kept as a
/// helper so binaries print a actionable message.
pub fn missing_artifacts_hint(dir: &std::path::Path) -> String {
    format!(
        "artifact directory '{}' not found or missing manifest.json — run `make artifacts` first",
        dir.display()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{ImplPref, OpKind};
    use std::path::PathBuf;

    /// Registry with no artifacts: everything routes to the interpreter.
    fn empty_coordinator(batching: bool) -> Coordinator {
        let registry = Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap();
        Coordinator::new(
            registry,
            CoordinatorConfig {
                batching,
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn interp_fallback_serves_requests() {
        let c = empty_coordinator(false);
        let a = Tensor::randn(&[4, 4], 1);
        let b = Tensor::randn(&[4, 4], 2);
        let resp = c
            .execute(OpRequest::new(OpKind::EwMult, vec![a.clone(), b.clone()]))
            .unwrap();
        assert_eq!(resp.served_by, "interp:ewmult");
        let want = crate::baselines::naive::ewmult(&a, &b).unwrap();
        assert!(resp.outputs[0].allclose(&want, 1e-6, 1e-6));
        assert_eq!(c.metrics().interp_fallbacks.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn repeat_fallback_requests_hit_plan_cache() {
        let c = empty_coordinator(false);
        for seed in 0..3u64 {
            let x = Tensor::randn(&[1, 256], seed);
            c.execute(OpRequest::new(OpKind::Fir, vec![x])).unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 1, "one compile");
        assert_eq!(m.plan_cache_hits.load(Ordering::Relaxed), 2, "repeats hit");
        assert_eq!(c.router().cached_exec_plans(), 1);
        // a different shape signature compiles its own plan
        let y = Tensor::randn(&[1, 300], 9);
        c.execute(OpRequest::new(OpKind::Fir, vec![y])).unwrap();
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(c.router().cached_exec_plans(), 2);
    }

    #[test]
    fn planned_fallback_matches_oracle_interpreter() {
        let c = empty_coordinator(false);
        let x = Tensor::randn(&[2, 400], 5);
        let resp = c
            .execute(OpRequest::new(OpKind::Stft, vec![x.clone()]))
            .unwrap();
        assert_eq!(resp.served_by, "interp:stft");
        // oracle: the naive interpreter over the router's own graph
        let req = OpRequest::new(OpKind::Stft, vec![x.clone()]).with_impl(ImplPref::Interp);
        let crate::coordinator::Target::Interp { key } = c.router().route(&req).unwrap() else {
            panic!("expected interp target");
        };
        let want = c
            .router()
            .interpreter(&key, &req)
            .unwrap()
            .run(std::slice::from_ref(&x))
            .unwrap();
        for (a, b) in resp.outputs.iter().zip(&want) {
            assert!(a.allclose(b, 1e-5, 1e-5), "planned engine diverged from oracle");
        }
    }

    #[test]
    fn shape_diverse_traffic_is_bounded_by_the_plan_cache_cap() {
        let registry = Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap();
        let c = Coordinator::new(
            registry,
            CoordinatorConfig {
                batching: false,
                workers: 2,
                router: crate::coordinator::RouterConfig {
                    plan_cache_cap: 2,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        for l in [128usize, 160, 192, 224] {
            let x = Tensor::randn(&[1, l], l as u64);
            c.execute(OpRequest::new(OpKind::Fir, vec![x])).unwrap();
        }
        assert_eq!(c.router().cached_exec_plans(), 2, "cap must bound the cache");
        assert_eq!(
            c.metrics().plan_cache_evictions.load(Ordering::Relaxed),
            2,
            "evictions must be surfaced in metrics"
        );
    }

    #[test]
    fn strict_tina_fails_without_artifacts() {
        let c = empty_coordinator(false);
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 128])])
            .with_impl(ImplPref::Tina);
        assert!(c.execute(req).is_err());
        assert_eq!(c.metrics().failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_submissions_complete() {
        let c = Arc::new(empty_coordinator(false));
        let slots: Vec<_> = (0..16)
            .map(|i| {
                let x = Tensor::randn(&[8, 8], i);
                let y = Tensor::randn(&[8, 8], 100 + i);
                c.submit(OpRequest::new(OpKind::EwAdd, vec![x, y]))
            })
            .collect();
        for s in slots {
            assert!(s.wait().is_ok());
        }
        assert_eq!(c.metrics().completed.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn shutdown_idempotent() {
        let c = empty_coordinator(true);
        c.shutdown();
        c.shutdown();
    }
}
