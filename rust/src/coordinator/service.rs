//! The coordinator service: ties router, batcher, worker pool, exec pool,
//! engine handle and metrics into the serving object examples/benches/server
//! use.
//!
//! Request path (all rust, no python):
//!
//! ```text
//!  submit(OpRequest)
//!    └─ route ──── artifact, batchable,  B==1 ─▶ batcher ─▶ engine
//!        ├──────── artifact, exact shape ──────▶ worker  ─▶ engine
//!        ├──────── fallback, batchable, B==1 ──▶ batcher ─▶ planned engine
//!        └──────── fallback, anything else ────▶ worker  ─▶ planned engine
//! ```
//!
//! With batching enabled, *all* fallback traffic runs on the planned
//! engine at a coalesced batch size: batchable single-row requests are
//! shape-bucketed by the batcher (grouped per (op, L), padded to the next
//! power-of-two bucket, executed once, scattered back per row), and every
//! other fallback request is simply the degenerate case of the same path
//! at its own batch size.
//!
//! # Completion-driven batched lifecycle
//!
//! Batched requests never touch the worker pool.  `submit` takes an
//! in-flight slot from the [`InflightGate`] — waiting at most
//! [`CoordinatorConfig::admission_timeout`]; a gate saturated past that
//! fails the request fast with an "overloaded, retry later" error instead
//! of queueing unbounded work — wraps the response slot + op + `t0` +
//! optional client deadline into a
//! [`Completion`](super::batcher::Completion), and enqueues it with the
//! row.  The drain loop forms batches and hands each one to the bounded
//! **exec pool** ([`ExecPool`], sized by
//! [`CoordinatorConfig::exec_pool_size`]), which completes every row's
//! response directly from the scatter — for both the artifact engine path
//! and the bucketed planned path.  Consequences the tests pin down:
//!
//! * in-flight batched requests are capped by the gate, not by the
//!   worker-pool size (`drain_completions == batched_fallback_requests`
//!   proves no request relayed through a parked worker);
//! * the drain loop itself never executes a batch, so a cold plan
//!   compile or a slow bucket cannot head-of-line-block other keys
//!   (beyond the bounded exec-pool queue, which backpressures the drain
//!   loop when all exec workers are busy);
//! * latency histograms measure from submit (`t0` rides the `Pending`).
//!
//! # Failure domains
//!
//! Execution faults are contained to the smallest unit that observed
//! them; nothing a single poisoned request or kernel does can take the
//! serving object down.  The ladder, from narrowest to widest:
//!
//! 1. **One row** — a row whose client deadline expired is shed (failed
//!    fast, [`Metrics::shed_expired_rows`]) before the batch pays for its
//!    execution; at admission, an already-expired request never routes.
//! 2. **One batch** — a panic inside plan/engine execution is caught
//!    (`catch_unwind`) by the exec worker: every waiter of that batch
//!    gets an error (never a hang), [`Metrics::exec_panics`] increments,
//!    and the pool thread survives to run the next batch.
//! 3. **One plan key / one artifact** — a fallback plan that panicked
//!    (or failed release-mode verification) is evicted and its
//!    `(op, shape, B)` key quarantined with capped exponential backoff
//!    ([`RouterConfig::quarantine_backoff`]); an artifact whose batch
//!    panicked is quarantined by name with the same backoff, and
//!    `ImplPref::Auto` stops routing to it.  While quarantined, traffic
//!    for either degrades to the interpreter oracle — bit-for-bit the
//!    same results, slower — counted by [`Metrics::degraded_requests`].
//! 4. **The service** — admission is deadline-aware: a saturated
//!    in-flight gate refuses new batched work after
//!    [`CoordinatorConfig::admission_timeout`]
//!    ([`Metrics::admission_timeouts`]) instead of queueing unboundedly,
//!    and [`Coordinator::shutdown`] drains the exec pool within
//!    [`CoordinatorConfig::drain_deadline`], detaching stragglers rather
//!    than hanging.

use super::batcher::{
    scatter_indexed_results, scatter_indexed_row_results, BatchKey, Batcher, BatcherConfig,
    Completion, FormedBatch, InflightGate, InflightPermit, Pending,
};
use super::metrics::Metrics;
use super::request::{OpKind, OpRequest, OpResponse};
use super::router::{PlanKey, Router, RouterConfig, Target};
use super::session::{SessionChunk, SessionConfig, SessionManager, SessionSummary};
use crate::runtime::{EngineHandle, Registry};
use crate::tensor::Tensor;
use crate::util::threadpool::{ExecPool, OneShot, ThreadPool};
use anyhow::{anyhow, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Drain the router's accumulated counters — plan-cache evictions,
/// fusion-pass stats, verifier stats, quarantine events, and
/// auto-routing decisions — into the metrics sink.  Every serving path
/// that may have compiled (or evicted, or quarantined, or routed) a plan
/// calls this one helper, so a counter added to the router is surfaced
/// on all arms at once.
fn sync_router_counters(metrics: &Metrics, router: &Router) {
    metrics.record_plan_cache_evictions(router.take_plan_cache_evictions());
    let (fused, copies) = router.take_fusion_counters();
    metrics.record_plan_fusion(fused, copies);
    let (verified, ns) = router.take_verify_counters();
    metrics.record_plan_verification(verified, ns);
    metrics.record_quarantined_plans(router.take_quarantine_counters());
    let (to_plan, to_artifact) = router.take_auto_routed();
    metrics.record_auto_routed(to_plan, to_artifact);
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Routing parameters and fallback plan-cache bound.
    pub router: RouterConfig,
    /// Batching ceilings (adaptive sizing never exceeds them).
    pub batcher: BatcherConfig,
    /// Worker threads handling non-batched requests.
    pub workers: usize,
    /// Bound on the worker queue (backpressure).
    pub queue_capacity: usize,
    /// Bound on in-flight *batched* requests: `submit` waits at enqueue
    /// (at most [`CoordinatorConfig::admission_timeout`]) once this many
    /// batched requests are admitted but not yet completed.
    pub max_inflight_batched: usize,
    /// Enable the dynamic batcher (ablation knob).
    pub batching: bool,
    /// Worker threads in the bounded batch **exec pool**.  Formed batches
    /// execute here — never on detached per-batch threads — so the number
    /// of concurrent batch executions (and the OS threads backing them)
    /// is fixed at construction.  Each worker wraps execution in
    /// `catch_unwind`: a panicking kernel fails only its own batch's
    /// waiters and the worker survives.  Clamped to ≥ 1.
    pub exec_pool_size: usize,
    /// Longest a batched `submit` waits for an in-flight slot — and the
    /// drain loop for an exec-pool queue slot — before failing fast with
    /// an "overloaded, retry later" error ([`Metrics::admission_timeouts`]).
    /// Deadline-aware admission: bounded waiting instead of unbounded
    /// queue growth when the service is saturated.
    pub admission_timeout: Duration,
    /// Longest [`Coordinator::shutdown`] waits for in-flight exec-pool
    /// batches to finish.  Batches still running past the deadline are
    /// detached (their waiters were already settled or will settle when
    /// the straggler completes/unwinds); shutdown itself never hangs.
    pub drain_deadline: Duration,
    /// Streaming-session admission limits (open-session cap and the
    /// per-push sample bound).
    pub sessions: SessionConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            router: RouterConfig::default(),
            batcher: BatcherConfig::default(),
            workers: crate::util::threadpool::default_threads(),
            queue_capacity: 256,
            max_inflight_batched: 1024,
            batching: true,
            exec_pool_size: 4,
            admission_timeout: Duration::from_secs(5),
            drain_deadline: Duration::from_secs(5),
            sessions: SessionConfig::default(),
        }
    }
}

/// The serving coordinator.  Cheap to share via Arc; all methods take &self.
pub struct Coordinator {
    router: Arc<Router>,
    engine: EngineHandle,
    pool: ThreadPool,
    exec_pool: Arc<ExecPool>,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    inflight: Arc<InflightGate>,
    sessions: SessionManager,
    config: CoordinatorConfig,
    stop: Arc<AtomicBool>,
    drain_thread: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Coordinator {
    /// Build from an artifact directory.
    pub fn from_dir(dir: impl AsRef<std::path::Path>, config: CoordinatorConfig) -> Result<Self> {
        let registry = Registry::load(dir)?;
        Self::new(registry, config)
    }

    /// Build from a loaded registry.
    pub fn new(registry: Registry, config: CoordinatorConfig) -> Result<Self> {
        let router = Arc::new(Router::new(registry, config.router.clone()));
        #[cfg(not(feature = "vaccel"))]
        let engine = EngineHandle::spawn(router.registry().clone())?;
        #[cfg(feature = "vaccel")]
        let engine = Self::spawn_vaccel(&router);
        // Arm or disarm the router's artifact arm from the backend's
        // typed capability probe — `ImplPref::Auto` never routes to a
        // backend that reported it cannot execute (no execute-time
        // "runtime unavailable" string matching anywhere on this path).
        router.set_artifact_arm(engine.capability().can_execute);
        let batcher = Arc::new(Batcher::new(config.batcher));
        let metrics = Arc::new(Metrics::new());
        let inflight = InflightGate::new(config.max_inflight_batched, Arc::clone(&metrics));
        let pool = ThreadPool::new(config.workers, config.queue_capacity);
        let exec_pool = Arc::new(ExecPool::new(
            config.exec_pool_size,
            config.exec_pool_size.saturating_mul(4).max(4),
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let sessions = SessionManager::new(config.sessions);

        let coord = Coordinator {
            router,
            engine,
            pool,
            exec_pool,
            batcher,
            metrics,
            inflight,
            sessions,
            config,
            stop,
            drain_thread: std::sync::Mutex::new(None),
        };
        if coord.config.batching {
            coord.start_drain_loop();
        }
        Ok(coord)
    }

    /// Build the virtual accelerator backend: specialize a linear program
    /// for every manifest artifact whose `(op, input shapes)` lowers
    /// through the router's graph builder — the same lowering the
    /// fallback plans compile, so the loaded programs dispatch identical
    /// kernels and results stay bit-for-bit oracle-equal (bf16 manifest
    /// entries are computed in f32, exactly like the fallback path).
    /// Entries that fail to lower or load are skipped: the artifact arm
    /// simply reports them as unknown and traffic falls back.
    #[cfg(feature = "vaccel")]
    fn spawn_vaccel(router: &Router) -> EngineHandle {
        let engine = Arc::new(crate::runtime::VaccelEngine::with_defaults());
        for meta in router.registry().entries() {
            let loaded = OpKind::parse(&meta.op)
                .and_then(|op| {
                    let shapes: Vec<Vec<usize>> =
                        meta.inputs.iter().map(|s| s.shape.clone()).collect();
                    router.compile_artifact_plan(op, &shapes)
                })
                .and_then(|plan| engine.load(&meta.name, &plan).map_err(Into::into));
            if let Err(e) = loaded {
                eprintln!("tina: vaccel skipped artifact '{}': {e:#}", meta.name);
            }
        }
        EngineHandle::vaccel(engine)
    }

    fn start_drain_loop(&self) {
        let batcher = Arc::clone(&self.batcher);
        let engine = self.engine.clone();
        let router = Arc::clone(&self.router);
        let metrics = Arc::clone(&self.metrics);
        let exec_pool = Arc::clone(&self.exec_pool);
        let stop = Arc::clone(&self.stop);
        let submit_wait = self.config.admission_timeout;
        // the static ceiling: an adaptive cap below it counts as a shrink
        let bucket_ceiling = self.batcher.config().max_bucket;
        let handle = std::thread::Builder::new()
            .name("tina-batch-drain".into())
            .spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let Some(batch) = batcher.next_batch(Duration::from_millis(20)) else {
                        continue;
                    };
                    if let Some(d) = batch.adaptive {
                        metrics.record_adaptive_bucket(d.cap, d.wait, d.cap < bucket_ceiling);
                    }
                    // Execution — including a cold plan compile on a cache
                    // miss, and the response completions — runs on the
                    // bounded exec pool for BOTH arms: the drain loop
                    // keeps draining while exec workers are free (no
                    // head-of-line blocking of co-queued batches behind a
                    // compile or a long bucket), the number of concurrent
                    // batch executions is fixed, and a refused submit
                    // (queue saturated past `submit_wait`, or pool closed
                    // by shutdown) drops the closure — failing every
                    // carried Completion — instead of wedging serving.
                    let submitted = match batch.key.clone() {
                        BatchKey::Artifact { name, batch: cap } => {
                            let engine = engine.clone();
                            let router = Arc::clone(&router);
                            let metrics = Arc::clone(&metrics);
                            let FormedBatch { input, rows, .. } = batch;
                            exec_pool.submit_timeout(
                                move || {
                                    exec_artifact_batch(
                                        &engine, &router, &metrics, &name, cap, &input, rows,
                                    )
                                },
                                submit_wait,
                            )
                        }
                        BatchKey::Fallback { op, len } => {
                            let router = Arc::clone(&router);
                            let metrics = Arc::clone(&metrics);
                            let FormedBatch { input, rows, .. } = batch;
                            exec_pool.submit_timeout(
                                move || {
                                    exec_fallback_batch(&router, &metrics, op, len, &input, rows)
                                },
                                submit_wait,
                            )
                        }
                    };
                    if !submitted {
                        eprintln!(
                            "tina: exec pool refused a batch (saturated past {submit_wait:?}, \
                             or closed); its rows fail"
                        );
                    }
                }
            })
            .expect("spawn drain loop");
        *self.drain_thread.lock().unwrap() = Some(handle);
    }

    /// The coordinator's metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The request router (artifact lookup + fallback plan caches).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The execution-backend handle (PJRT engine thread, or the virtual
    /// accelerator under `--features vaccel`).
    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    /// Warm the executable cache for every artifact of an op (or all).
    pub fn warmup(&self, op_filter: Option<&str>) -> Result<usize> {
        let mut n = 0;
        for meta in self.router.registry().entries() {
            if let Some(f) = op_filter {
                if meta.op != f {
                    continue;
                }
            }
            self.engine.prepare(&meta.name)?;
            n += 1;
        }
        Ok(n)
    }

    /// Completion context for a request settling through this coordinator
    /// — the single `OpResponse` assembly point for every serving path.
    /// `permit` is `Some` exactly for requests admitted through the
    /// in-flight gate (batched paths).
    fn completion(
        &self,
        slot: &OneShot<Result<OpResponse>>,
        op: &'static str,
        served_by: String,
        t0: Instant,
        permit: Option<InflightPermit>,
        deadline: Option<Instant>,
    ) -> Completion {
        Completion::new(
            Arc::clone(&self.metrics),
            slot.clone(),
            op,
            served_by,
            t0,
            permit,
            deadline,
        )
    }

    /// Fail a batched request whose admission wait timed out (the
    /// in-flight gate stayed saturated past
    /// [`CoordinatorConfig::admission_timeout`]).
    fn refuse_overloaded(
        &self,
        slot: OneShot<Result<OpResponse>>,
        op: &'static str,
        t0: Instant,
        deadline: Option<Instant>,
    ) -> OneShot<Result<OpResponse>> {
        self.metrics.record_admission_timeout();
        self.completion(&slot, op, String::new(), t0, None, deadline)
            .fail(anyhow!(
                "overloaded: {} batched requests in flight held the admission gate for {:?}; \
                 retry later",
                self.config.max_inflight_batched,
                self.config.admission_timeout
            ));
        slot
    }

    /// Submit asynchronously; the returned slot completes with the response.
    ///
    /// Batched requests may wait here briefly when the in-flight limit is
    /// reached (backpressure at enqueue), but never longer than
    /// [`CoordinatorConfig::admission_timeout`] — a saturated gate fails
    /// the request fast instead.  A request whose
    /// [`OpRequest::deadline`] already passed is shed immediately.
    pub fn submit(&self, req: OpRequest) -> OneShot<Result<OpResponse>> {
        let slot: OneShot<Result<OpResponse>> = OneShot::new();
        self.metrics.record_request();
        // surface plan-cache evictions and fusion counters from *any*
        // router path (including direct oracle/interpreter use between
        // requests), not just the fallback compile below
        sync_router_counters(&self.metrics, &self.router);
        let t0 = Instant::now();
        let op = req.op.as_str();
        let deadline = req.deadline;

        // deadline-aware admission: don't route (let alone execute) work
        // whose client already gave up
        if deadline.is_some_and(|d| Instant::now() >= d) {
            self.metrics.record_shed_expired_rows(1);
            self.completion(&slot, op, String::new(), t0, None, deadline)
                .fail(anyhow!("deadline already expired at admission (request shed)"));
            return slot;
        }

        let target = match self.router.route_with_batching(&req, self.config.batching) {
            Ok(t) => t,
            Err(e) => {
                self.completion(&slot, op, String::new(), t0, None, deadline)
                    .fail(e);
                return slot;
            }
        };

        match target {
            Target::Artifact { name, pad_batch } => {
                // degradation ladder, artifact arm: a quarantined artifact
                // serves from the interpreter oracle (bit-for-bit, slower)
                // while it backs off — for every pref.  Auto already
                // avoids quarantined artifacts at routing; this covers
                // strict prefs and races with an in-flight quarantine.
                if self.router.is_artifact_quarantined(&name) {
                    self.metrics.record_degraded_requests(1);
                    let shapes: Vec<Vec<usize>> =
                        req.inputs.iter().map(|t| t.shape().to_vec()).collect();
                    let key = PlanKey::for_shapes(req.op, &shapes);
                    let interp = match self.router.interpreter(&key, &req) {
                        Ok(it) => it,
                        Err(e) => {
                            self.completion(&slot, op, String::new(), t0, None, deadline)
                                .fail(e);
                            return slot;
                        }
                    };
                    let completion =
                        self.completion(&slot, op, format!("interp:{op}"), t0, None, deadline);
                    let inputs = req.inputs;
                    self.pool.submit(move || {
                        completion.complete(interp.run(&inputs));
                    });
                    return slot;
                }
                let batchable = self.config.batching
                    && req.op.batchable()
                    && req.inputs.len() == 1
                    && req.inputs[0].rank() == 2
                    && req.inputs[0].shape()[0] == 1
                    && pad_batch > 1;
                if batchable {
                    // ride the dynamic batcher; the exec-pool execution
                    // completes the response directly
                    let Some(permit) = self.inflight.acquire_timeout(self.config.admission_timeout)
                    else {
                        return self.refuse_overloaded(slot, op, t0, deadline);
                    };
                    let key = BatchKey::Artifact {
                        name: name.clone(),
                        batch: pad_batch,
                    };
                    let completion = self.completion(&slot, op, name, t0, Some(permit), deadline);
                    self.batcher.enqueue(key, req.inputs[0].clone(), completion);
                } else {
                    let engine = self.engine.clone();
                    let router = Arc::clone(&self.router);
                    let metrics = Arc::clone(&self.metrics);
                    let op_kind = req.op;
                    let completion =
                        self.completion(&slot, op, name.clone(), t0, None, deadline);
                    let inputs = req.inputs;
                    let shapes: Vec<Vec<usize>> =
                        inputs.iter().map(|t| t.shape().to_vec()).collect();
                    let exec_rows = inputs
                        .first()
                        .and_then(|t| t.shape().first().copied())
                        .unwrap_or(1)
                        .max(1);
                    self.pool.submit(move || {
                        let t_run = Instant::now();
                        let result = engine.execute(&name, inputs);
                        if result.is_ok() {
                            if engine.backend_name() == "vaccel" {
                                metrics.record_vaccel_batch();
                            }
                            // feed the artifact arm of the Auto latency
                            // table: per-row ns over the executed rows
                            router.record_artifact_latency(
                                op_kind,
                                &shapes,
                                t_run.elapsed().as_nanos() as f64 / exec_rows as f64,
                            );
                        }
                        completion.complete(result);
                    });
                }
            }
            Target::Interp { key } => {
                // Fallback path: runs on the planned engine; the naive
                // interpreter remains the test oracle only.  `served_by`
                // keeps the "interp:" prefix as the stable fallback marker
                // of the serving API.
                self.metrics.record_interp_fallback();
                // Serving mode: batchable single-row requests ride the
                // shape-bucketed batcher, coalescing with co-arriving
                // same-(op, L) traffic into one planned execution at the
                // bucket batch size.  Everything else below is the
                // degenerate case of the same path at the request's own
                // batch size.
                let bucketable = self.config.batching
                    && req.op.batchable()
                    && req.inputs.len() == 1
                    && req.inputs[0].rank() == 2
                    && req.inputs[0].shape()[0] == 1;
                if bucketable {
                    let Some(permit) = self.inflight.acquire_timeout(self.config.admission_timeout)
                    else {
                        return self.refuse_overloaded(slot, op, t0, deadline);
                    };
                    let len = req.inputs[0].shape()[1];
                    let bkey = BatchKey::Fallback { op: req.op, len };
                    let input = req.inputs.into_iter().next().expect("checked arity");
                    let completion =
                        self.completion(&slot, op, format!("interp:{op}"), t0, Some(permit), deadline);
                    self.batcher.enqueue(bkey, input, completion);
                    return slot;
                }
                // degradation ladder: a quarantined key serves from the
                // interpreter oracle (bit-for-bit, slower) while it backs
                // off, instead of recompiling a plan known to be poisoned
                if self.router.is_quarantined(&key) {
                    self.metrics.record_degraded_requests(1);
                    let interp = match self.router.interpreter(&key, &req) {
                        Ok(it) => it,
                        Err(e) => {
                            self.completion(&slot, op, String::new(), t0, None, deadline)
                                .fail(e);
                            return slot;
                        }
                    };
                    let completion =
                        self.completion(&slot, op, format!("interp:{op}"), t0, None, deadline);
                    let inputs = req.inputs;
                    self.pool.submit(move || {
                        completion.complete(interp.run(&inputs));
                    });
                    return slot;
                }
                let planned = match self.router.planned(&key, &req) {
                    Ok((p, hit)) => {
                        self.metrics.record_plan_cache(hit);
                        sync_router_counters(&self.metrics, &self.router);
                        p
                    }
                    Err(e) => {
                        self.completion(&slot, op, String::new(), t0, None, deadline)
                            .fail(e);
                        return slot;
                    }
                };
                let completion =
                    self.completion(&slot, op, format!("interp:{op}"), t0, None, deadline);
                let op_kind = req.op;
                let inputs = req.inputs;
                let shapes: Vec<Vec<usize>> =
                    inputs.iter().map(|t| t.shape().to_vec()).collect();
                let exec_rows = inputs
                    .first()
                    .and_then(|t| t.shape().first().copied())
                    .unwrap_or(1)
                    .max(1);
                let router = Arc::clone(&self.router);
                let metrics = Arc::clone(&self.metrics);
                self.pool.submit(move || {
                    // same containment as the batched arms: a panicking
                    // kernel fails this request and quarantines its key,
                    // never the worker or the service
                    let run = catch_unwind(AssertUnwindSafe(|| {
                        crate::testing::faults::fire("exec.direct")?;
                        let t_run = Instant::now();
                        let out = planned.run(&inputs);
                        if out.is_ok() {
                            // feed the plan arm of the Auto latency table
                            router.record_plan_latency(
                                op_kind,
                                &shapes,
                                t_run.elapsed().as_nanos() as f64 / exec_rows as f64,
                            );
                        }
                        out
                    }));
                    match run {
                        Ok(result) => completion.complete(result),
                        Err(_) => {
                            metrics.record_exec_panic();
                            router.quarantine_key(&key, "panicked during direct execution");
                            sync_router_counters(&metrics, &router);
                            completion.fail(anyhow!(
                                "op {op} execution panicked (contained); plan quarantined"
                            ));
                        }
                    }
                });
            }
        }
        slot
    }

    /// Submit and wait.
    pub fn execute(&self, req: OpRequest) -> Result<OpResponse> {
        self.submit(req).wait()
    }

    /// Overlap (carried tail length) a streaming session of `op` needs:
    /// `taps - 1` for FIR.  Ops without a streaming decomposition are
    /// refused at open, never at push.
    fn streaming_overlap(&self, op: OpKind) -> Result<usize> {
        match op {
            OpKind::Fir => Ok(self.config.router.fir_taps.saturating_sub(1)),
            other => Err(anyhow!(
                "streaming sessions support 'fir' only (got '{}')",
                other.as_str()
            )),
        }
    }

    /// The streaming-session registry (open-session count for tests and
    /// operators).
    pub fn sessions(&self) -> &SessionManager {
        &self.sessions
    }

    /// Open a streaming session for `op`; returns `(session id, overlap)`.
    /// Fails fast at the [`SessionConfig::max_sessions`] cap.
    pub fn session_open(&self, op: OpKind) -> Result<(u64, usize)> {
        let overlap = self.streaming_overlap(op)?;
        let id = self.sessions.open(op, overlap)?;
        self.metrics.record_session_opened();
        Ok((id, overlap))
    }

    /// Push one chunk of samples into a session.  The combined
    /// `[carry | chunk]` signal rides the normal serving path (planned /
    /// batched engine, deadline shedding, admission gate); on success the
    /// session keeps the new tail and the output samples continue the
    /// one-shot run bit-for-bit.  On *any* failure the session state is
    /// untouched, so the same chunk can be retried.
    pub fn session_push(
        &self,
        session: u64,
        chunk: &[f32],
        deadline: Option<Duration>,
    ) -> Result<SessionChunk> {
        if chunk.is_empty() {
            anyhow::bail!("empty chunk");
        }
        if chunk.len() > self.config.sessions.max_chunk_samples {
            anyhow::bail!(
                "chunk of {} samples exceeds the per-push limit of {}",
                chunk.len(),
                self.config.sessions.max_chunk_samples
            );
        }
        let sess = self.sessions.checkout(session)?;
        // the session mutex is held across execution: pushes into one
        // session serialize (the carry makes them order-dependent);
        // different sessions push concurrently
        let mut s = sess.lock().unwrap();
        let mut combined = Vec::with_capacity(s.carry.len() + chunk.len());
        combined.extend_from_slice(&s.carry);
        combined.extend_from_slice(chunk);
        let index = s.chunks;
        if combined.len() <= s.overlap {
            // not enough signal for a single output yet: carry everything
            s.carry = combined;
            s.chunks += 1;
            s.samples_in += chunk.len() as u64;
            self.metrics.record_session_chunk(0);
            return Ok(SessionChunk {
                index,
                samples: Vec::new(),
            });
        }
        let input = Tensor::new(&[1, combined.len()], combined.clone())?;
        let mut req = OpRequest::new(s.op, vec![input]);
        if let Some(d) = deadline {
            req = req.with_deadline(d);
        }
        let resp = self.execute(req)?;
        let out = resp
            .outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("op {} returned no output", s.op.as_str()))?;
        let samples = out.data().to_vec();
        // commit only after success (retry-safe)
        s.carry = combined[combined.len() - s.overlap..].to_vec();
        s.chunks += 1;
        s.samples_in += chunk.len() as u64;
        s.samples_out += samples.len() as u64;
        self.metrics.record_session_chunk(samples.len() as u64);
        Ok(SessionChunk { index, samples })
    }

    /// Close a streaming session and return its lifetime totals.
    pub fn session_close(&self, session: u64) -> Result<SessionSummary> {
        let summary = self.sessions.close(session)?;
        self.metrics.record_session_closed();
        Ok(summary)
    }

    /// Stop the batch drain loop and drain the exec pool (called on drop
    /// too).  Shutdown order is the reverse of the data flow so no stage
    /// feeds a stopped successor:
    ///
    /// 1. close the exec pool to new submits (a drain loop blocked in
    ///    `submit_timeout` wakes and fails that batch's rows),
    /// 2. stop + join the drain thread,
    /// 3. [`ExecPool::shutdown_join`] bounded by
    ///    [`CoordinatorConfig::drain_deadline`]: queued batches are
    ///    dropped (their rows fail via `Completion`), in-flight batches
    ///    get the deadline to finish, stragglers are detached,
    /// 4. fail rows still queued in the batcher, closing it so late
    ///    batched submits fail fast at enqueue.
    ///
    /// Waiters blocked on response slots therefore always settle — with
    /// results when their batch finished in time, with errors otherwise —
    /// and shutdown returns within roughly the drain deadline even with
    /// faults (panics, slow kernels) in flight.  Direct (non-batched)
    /// requests keep running on the worker pool until the coordinator
    /// drops.
    pub fn shutdown(&self) {
        self.exec_pool.close();
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.drain_thread.lock().unwrap().take() {
            let _ = h.join();
        }
        if !self.exec_pool.shutdown_join(self.config.drain_deadline) {
            eprintln!(
                "tina: exec pool did not drain within {:?}; stragglers detached",
                self.config.drain_deadline
            );
        }
        self.batcher
            .fail_pending("coordinator shut down before the batch executed");
        let dropped = self.sessions.clear();
        if dropped > 0 {
            eprintln!("tina: dropped {dropped} open streaming session(s) at shutdown");
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Shed the rows of a formed batch whose client deadline already expired:
/// each is failed fast ([`Metrics::shed_expired_rows`]) before the batch
/// pays for execution.  Survivors keep their original batch-slot index so
/// the scatter can still address the stacked outputs.
fn shed_expired(rows: Vec<Pending>, metrics: &Metrics) -> Vec<(usize, Pending)> {
    let mut live = Vec::with_capacity(rows.len());
    let mut shed = 0u64;
    for (i, row) in rows.into_iter().enumerate() {
        if row.completion.deadline_expired() {
            shed += 1;
            row.completion
                .fail(anyhow!("deadline expired before batch execution (row shed)"));
        } else {
            live.push((i, row));
        }
    }
    metrics.record_shed_expired_rows(shed);
    live
}

/// Execute one artifact batch on an exec-pool worker: shed expired rows,
/// serve from the interpreter oracle while the artifact is quarantined,
/// otherwise run the engine under `catch_unwind` and scatter per-row
/// outputs.  Success feeds the artifact arm of the router's Auto latency
/// table (per-row EWMA) and — on the vaccel backend — the
/// [`Metrics::vaccel_batches`] counter; a panic fails only this batch's
/// waiters ([`Metrics::exec_panics`]) and quarantines the artifact name
/// with the same capped exponential backoff plan keys get.
fn exec_artifact_batch(
    engine: &EngineHandle,
    router: &Arc<Router>,
    metrics: &Metrics,
    name: &str,
    cap: usize,
    input: &Tensor,
    rows: Vec<Pending>,
) {
    let live = shed_expired(rows, metrics);
    if live.is_empty() {
        return;
    }
    let op = router
        .registry()
        .get(name)
        .and_then(|meta| OpKind::parse(&meta.op).ok());
    let shapes = [input.shape().to_vec()];
    if let Some(op) = op {
        if router.is_artifact_quarantined(name) {
            // degradation ladder, artifact arm: the interpreter oracle
            // serves the whole batch bit-for-bit while the artifact
            // backs off (the Auto route stops picking it, but rows
            // already coalesced under its key still settle here)
            metrics.record_degraded_requests(live.len() as u64);
            let result = router
                .interpreter_for_shapes(op, &shapes)
                .and_then(|it| it.run(std::slice::from_ref(input)));
            sync_router_counters(metrics, router);
            scatter_indexed_results(live, result);
            return;
        }
    }
    let t_exec = Instant::now();
    let result = catch_unwind(AssertUnwindSafe(|| {
        crate::testing::faults::fire("exec.batch.artifact")?;
        engine.execute(name, vec![input.clone()])
    }));
    match result {
        Ok(result) => {
            // success-only: a failed execute must not inflate the
            // coalescing stats or the fill ratio
            if result.is_ok() {
                metrics.record_batch(live.len(), cap - live.len());
                if engine.backend_name() == "vaccel" {
                    metrics.record_vaccel_batch();
                }
                if let Some(op) = op {
                    router.record_artifact_latency(
                        op,
                        &shapes,
                        t_exec.elapsed().as_nanos() as f64 / cap.max(1) as f64,
                    );
                }
            }
            scatter_indexed_results(live, result);
        }
        Err(_) => {
            metrics.record_exec_panic();
            router.quarantine_artifact(name, "panicked during batched execution");
            sync_router_counters(metrics, router);
            for (_, row) in live {
                row.completion.fail(anyhow!(
                    "artifact '{name}' batch panicked during execution (contained; \
                     artifact quarantined)"
                ));
            }
        }
    }
}

/// Execute one bucketed fallback batch on an exec-pool worker: shed
/// expired rows, serve from the interpreter oracle if the `(op, shape, B)`
/// key is quarantined, otherwise run the planned executor under
/// `catch_unwind` — a panic quarantines the key and fails only this
/// batch's waiters.  Within the batch the kernels fan rows across scoped
/// threads (`util::threadpool::parallel_for`).
fn exec_fallback_batch(
    router: &Arc<Router>,
    metrics: &Metrics,
    op: OpKind,
    len: usize,
    input: &Tensor,
    rows: Vec<Pending>,
) {
    let live = shed_expired(rows, metrics);
    if live.is_empty() {
        return;
    }
    let bucket = input.shape()[0];
    // rows above the last survivor (shed, or padding) are never gathered
    let gather_n = live.last().map(|(i, _)| i + 1).expect("live is non-empty");
    let shapes = [vec![bucket, len]];
    let key = PlanKey::for_shapes(op, &shapes);
    if router.is_quarantined(&key) {
        // degradation ladder: the interpreter oracle runs the same graph
        // node-at-a-time — bit-for-bit the planned result, slower — while
        // the quarantined key backs off
        metrics.record_degraded_requests(live.len() as u64);
        let result = router
            .interpreter_for_shapes(op, &shapes)
            .and_then(|it| it.run(std::slice::from_ref(input)));
        sync_router_counters(metrics, router);
        scatter_indexed_results(live, result);
        return;
    }
    let exec = catch_unwind(AssertUnwindSafe(|| {
        crate::testing::faults::fire("exec.batch.fallback")?;
        router.planned_for_shapes(op, &shapes).and_then(|(plan, hit)| {
            metrics.record_plan_cache_bucketed(bucket, hit);
            sync_router_counters(metrics, router);
            let t_run = Instant::now();
            let out = plan.run_rows(std::slice::from_ref(input), gather_n);
            if out.is_ok() {
                // compile-on-miss is excluded: the Auto latency table
                // compares steady-state execution of the two arms
                router.record_plan_latency(
                    op,
                    &shapes,
                    t_run.elapsed().as_nanos() as f64 / bucket.max(1) as f64,
                );
            }
            out
        })
    }));
    match exec {
        Ok(result) => {
            // only successfully executed buckets count — a failed
            // lookup/run must not inflate the coalescing stats or the
            // fill ratio
            if result.is_ok() {
                metrics.record_fallback_batch(live.len(), bucket - live.len());
            }
            scatter_indexed_row_results(live, result);
        }
        Err(_) => {
            metrics.record_exec_panic();
            router.quarantine_key(&key, "panicked during batched execution");
            sync_router_counters(metrics, router);
            for (_, row) in live {
                row.completion.fail(anyhow!(
                    "op {} bucket B={bucket} panicked during execution (contained); \
                     plan quarantined",
                    op.as_str()
                ));
            }
        }
    }
}

/// Errors surfaced when building a coordinator without artifacts: kept as a
/// helper so binaries print a actionable message.
pub fn missing_artifacts_hint(dir: &std::path::Path) -> String {
    format!(
        "artifact directory '{}' not found or missing manifest.json — run `make artifacts` first",
        dir.display()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{ImplPref, OpKind};
    use crate::tensor::Tensor;
    use std::path::PathBuf;

    /// Registry with no artifacts: everything routes to the interpreter.
    fn empty_registry() -> Registry {
        Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap()
    }

    fn empty_coordinator(batching: bool) -> Coordinator {
        Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching,
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn interp_fallback_serves_requests() {
        let c = empty_coordinator(false);
        let a = Tensor::randn(&[4, 4], 1);
        let b = Tensor::randn(&[4, 4], 2);
        let resp = c
            .execute(OpRequest::new(OpKind::EwMult, vec![a.clone(), b.clone()]))
            .unwrap();
        assert_eq!(resp.served_by, "interp:ewmult");
        let want = crate::baselines::naive::ewmult(&a, &b).unwrap();
        assert!(resp.outputs[0].allclose(&want, 1e-6, 1e-6));
        assert_eq!(c.metrics().interp_fallbacks.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn repeat_fallback_requests_hit_plan_cache() {
        let c = empty_coordinator(false);
        for seed in 0..3u64 {
            let x = Tensor::randn(&[1, 256], seed);
            c.execute(OpRequest::new(OpKind::Fir, vec![x])).unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 1, "one compile");
        assert_eq!(m.plan_cache_hits.load(Ordering::Relaxed), 2, "repeats hit");
        assert_eq!(c.router().cached_exec_plans(), 1);
        // a different shape signature compiles its own plan
        let y = Tensor::randn(&[1, 300], 9);
        c.execute(OpRequest::new(OpKind::Fir, vec![y])).unwrap();
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(c.router().cached_exec_plans(), 2);
    }

    #[test]
    fn planned_fallback_matches_oracle_interpreter() {
        let c = empty_coordinator(false);
        let x = Tensor::randn(&[2, 400], 5);
        let resp = c
            .execute(OpRequest::new(OpKind::Stft, vec![x.clone()]))
            .unwrap();
        assert_eq!(resp.served_by, "interp:stft");
        // oracle: the naive interpreter over the router's own graph
        let req = OpRequest::new(OpKind::Stft, vec![x.clone()]).with_impl(ImplPref::Interp);
        let crate::coordinator::Target::Interp { key } = c.router().route(&req).unwrap() else {
            panic!("expected interp target");
        };
        let want = c
            .router()
            .interpreter(&key, &req)
            .unwrap()
            .run(std::slice::from_ref(&x))
            .unwrap();
        for (a, b) in resp.outputs.iter().zip(&want) {
            assert!(a.allclose(b, 1e-5, 1e-5), "planned engine diverged from oracle");
        }
    }

    #[test]
    fn shape_diverse_traffic_is_bounded_by_the_plan_cache_cap() {
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: false,
                workers: 2,
                router: crate::coordinator::RouterConfig {
                    plan_cache_cap: 2,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        for l in [128usize, 160, 192, 224] {
            let x = Tensor::randn(&[1, l], l as u64);
            c.execute(OpRequest::new(OpKind::Fir, vec![x])).unwrap();
        }
        assert_eq!(c.router().cached_exec_plans(), 2, "cap must bound the cache");
        assert_eq!(
            c.metrics().plan_cache_evictions.load(Ordering::Relaxed),
            2,
            "evictions must be surfaced in metrics"
        );
    }

    #[test]
    fn batched_fallback_matches_solo_bitwise() {
        // batching on: batchable B=1 fallback requests ride the
        // shape-bucketed batcher and must return exactly what the solo
        // (batching off) path returns for the same inputs
        let batched = empty_coordinator(true);
        let solo = empty_coordinator(false);
        let l = 300;
        let xs: Vec<Tensor> = (0..5).map(|i| Tensor::randn(&[1, l], i)).collect();
        let slots: Vec<_> = xs
            .iter()
            .map(|x| batched.submit(OpRequest::new(OpKind::Fir, vec![x.clone()])))
            .collect();
        for (x, s) in xs.iter().zip(slots) {
            let resp = s.wait().unwrap();
            assert_eq!(resp.served_by, "interp:fir");
            assert!(resp.batched, "fallback request must ride the batcher");
            let want = solo
                .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]))
                .unwrap();
            assert_eq!(resp.outputs.len(), want.outputs.len());
            for (a, b) in resp.outputs.iter().zip(&want.outputs) {
                assert_eq!(a, b, "bucketed row diverged from the solo run");
            }
        }
        let m = batched.metrics();
        assert_eq!(
            m.batched_fallback_requests.load(Ordering::Relaxed),
            5,
            "every request must be counted as coalesced fallback traffic"
        );
        let batches = m.fallback_batches_executed.load(Ordering::Relaxed);
        assert!(batches >= 1, "at least one bucket must have executed");
        // completion-driven serving: every batched reply was finished by
        // an exec-pool execution, none by a parked worker relay
        assert_eq!(
            m.drain_completions.load(Ordering::Relaxed),
            5,
            "all batched replies must complete from the drain scatter"
        );
        assert_eq!(
            m.inflight_batched_requests.load(Ordering::Relaxed),
            0,
            "in-flight gauge must settle to zero"
        );
        // per-bucket plan-cache stats cover exactly the executed buckets
        let lookups: u64 = m
            .plan_cache_bucket_stats()
            .iter()
            .map(|&(_, h, mi)| h + mi)
            .sum();
        assert_eq!(lookups, batches, "one bucketed plan lookup per batch");
        let fill = m.batch_fill_ratio();
        assert!(fill > 0.0 && fill <= 1.0, "fill ratio out of range: {fill}");
        // fault-free traffic must leave every containment counter at zero
        assert_eq!(m.exec_panics.load(Ordering::Relaxed), 0);
        assert_eq!(m.quarantined_plans.load(Ordering::Relaxed), 0);
        assert_eq!(m.degraded_requests.load(Ordering::Relaxed), 0);
        assert_eq!(m.shed_expired_rows.load(Ordering::Relaxed), 0);
        assert_eq!(m.admission_timeouts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn batched_requests_do_not_consume_pool_workers() {
        // the lifted-cap property at unit scale: a single-worker pool with
        // a single-slot queue serves many concurrent batched requests,
        // which the old parked-relay design could not (each in-flight
        // batched request occupied a worker)
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: true,
                workers: 1,
                queue_capacity: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let n = 16usize;
        let slots: Vec<_> = (0..n)
            .map(|i| {
                let x = Tensor::randn(&[1, 256], i as u64);
                c.submit(OpRequest::new(OpKind::Fir, vec![x]))
            })
            .collect();
        for s in slots {
            let resp = s.wait().unwrap();
            assert!(resp.batched);
        }
        let m = c.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), n as u64);
        assert_eq!(
            m.drain_completions.load(Ordering::Relaxed),
            m.batched_fallback_requests.load(Ordering::Relaxed),
            "every batched reply must come from the drain scatter"
        );
        assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn inflight_limit_backpressures_but_stays_live() {
        // a tiny in-flight limit forces submit() to wait at enqueue;
        // the drain loop must keep freeing slots so every request still
        // completes (liveness of the backpressure path)
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: true,
                workers: 2,
                max_inflight_batched: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let n = 8usize;
        let mut slots = Vec::new();
        for i in 0..n {
            let x = Tensor::randn(&[1, 128], i as u64);
            // sequential submits: the 3rd+ wait until the exec pool
            // completes earlier rows, then proceed
            slots.push(c.submit(OpRequest::new(OpKind::Fir, vec![x])));
        }
        for s in slots {
            assert!(s.wait().is_ok());
        }
        let m = c.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), n as u64);
        assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
        assert_eq!(m.admission_timeouts.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn expired_deadline_is_shed_at_admission() {
        let c = empty_coordinator(true);
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, 128], 1)])
            .with_deadline_at(Instant::now() - Duration::from_millis(5));
        let err = c.submit(req).wait().unwrap_err();
        assert!(err.to_string().contains("shed"), "got: {err}");
        let m = c.metrics();
        assert_eq!(m.shed_expired_rows.load(Ordering::Relaxed), 1);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        // a generous deadline never sheds
        let ok = c.execute(
            OpRequest::new(OpKind::Fir, vec![Tensor::randn(&[1, 128], 2)])
                .with_deadline(Duration::from_secs(60)),
        );
        assert!(ok.is_ok());
        assert_eq!(m.shed_expired_rows.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn admission_times_out_when_gate_stays_saturated() {
        // one in-flight slot, held by a row parked in a never-flushing
        // batcher: the second batched submit must fail fast with an
        // overload error instead of waiting forever
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: true,
                workers: 2,
                max_inflight_batched: 1,
                admission_timeout: Duration::from_millis(50),
                batcher: BatcherConfig {
                    max_wait: Duration::from_secs(60),
                    max_bucket: 8,
                },
                ..Default::default()
            },
        )
        .unwrap();
        let parked = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 128], 1)],
        ));
        let err = c
            .submit(OpRequest::new(
                OpKind::Fir,
                vec![Tensor::randn(&[1, 128], 2)],
            ))
            .wait()
            .unwrap_err();
        assert!(err.to_string().contains("overloaded"), "got: {err}");
        assert_eq!(c.metrics().admission_timeouts.load(Ordering::Relaxed), 1);
        c.shutdown();
        assert!(parked.wait().is_err(), "parked row fails at shutdown");
        assert_eq!(
            c.metrics().inflight_batched_requests.load(Ordering::Relaxed),
            0
        );
    }

    #[test]
    fn quarantined_direct_key_degrades_to_interpreter() {
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: false,
                workers: 2,
                router: RouterConfig {
                    quarantine_backoff: Duration::from_millis(40),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        let x = Tensor::randn(&[2, 64], 3);
        let req = OpRequest::new(OpKind::Dft, vec![x.clone()]);
        let Target::Interp { key } = c.router().route(&req).unwrap() else {
            panic!("expected interp target");
        };
        let baseline = c.execute(req.clone()).unwrap();
        c.router().quarantine_key(&key, "test");
        let degraded = c.execute(req.clone()).unwrap();
        assert_eq!(degraded.served_by, "interp:dft", "stable served_by contract");
        assert_eq!(c.metrics().degraded_requests.load(Ordering::Relaxed), 1);
        for (a, b) in degraded.outputs.iter().zip(&baseline.outputs) {
            assert_eq!(a, b, "degraded mode must be bit-for-bit the planned result");
        }
        // after the backoff expires the key is paroled: the planned path
        // serves again and the degraded counter stops moving
        std::thread::sleep(Duration::from_millis(60));
        c.execute(req).unwrap();
        assert_eq!(c.metrics().degraded_requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn quarantined_bucket_degrades_batched_traffic_bitwise() {
        // max_bucket 1 pins the bucketed plan key to (op, [1, L])
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: true,
                workers: 2,
                batcher: BatcherConfig {
                    max_bucket: 1,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        let x = Tensor::randn(&[1, 300], 7);
        let baseline = c
            .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]))
            .unwrap();
        let key = PlanKey::for_shapes(OpKind::Fir, &[vec![1, 300]]);
        c.router().quarantine_key(&key, "test");
        let degraded = c
            .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]))
            .unwrap();
        assert!(degraded.batched, "degraded traffic still rides the batcher");
        assert_eq!(degraded.served_by, "interp:fir");
        assert_eq!(c.metrics().degraded_requests.load(Ordering::Relaxed), 1);
        assert_eq!(degraded.outputs.len(), baseline.outputs.len());
        for (a, b) in degraded.outputs.iter().zip(&baseline.outputs) {
            assert_eq!(a, b, "degraded bucket must be bit-for-bit the planned result");
        }
    }

    #[test]
    fn mixed_length_fallback_requests_route_to_buckets() {
        // PR 1 rejected mixed-length rows sharing a batch key; bucketing
        // makes different lengths land in different buckets instead
        let c = empty_coordinator(true);
        let a = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 256], 1)],
        ));
        let b = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 320], 2)],
        ));
        let ra = a.wait().unwrap();
        let rb = b.wait().unwrap();
        assert_eq!(ra.outputs[0].shape(), &[1, 256 - 64 + 1]);
        assert_eq!(rb.outputs[0].shape(), &[1, 320 - 64 + 1]);
    }

    #[test]
    fn non_batchable_fallback_is_direct_even_with_batching() {
        // dft is not batchable: with batching on it must take the direct
        // (degenerate) planned path, not the batcher
        let c = empty_coordinator(true);
        let x = Tensor::randn(&[2, 64], 3);
        let resp = c.execute(OpRequest::new(OpKind::Dft, vec![x])).unwrap();
        assert_eq!(resp.served_by, "interp:dft");
        assert!(!resp.batched);
        assert_eq!(
            c.metrics()
                .batched_fallback_requests
                .load(Ordering::Relaxed),
            0
        );
        assert_eq!(
            c.metrics().drain_completions.load(Ordering::Relaxed),
            0,
            "direct requests must not be counted as drain completions"
        );
    }

    #[test]
    fn strict_tina_fails_without_artifacts() {
        let c = empty_coordinator(false);
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 128])])
            .with_impl(ImplPref::Tina);
        assert!(c.execute(req).is_err());
        assert_eq!(c.metrics().failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_submissions_complete() {
        let c = Arc::new(empty_coordinator(false));
        let slots: Vec<_> = (0..16)
            .map(|i| {
                let x = Tensor::randn(&[8, 8], i);
                let y = Tensor::randn(&[8, 8], 100 + i);
                c.submit(OpRequest::new(OpKind::EwAdd, vec![x, y]))
            })
            .collect();
        for s in slots {
            assert!(s.wait().is_ok());
        }
        assert_eq!(c.metrics().completed.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn streaming_session_matches_one_shot_bitwise() {
        let c = empty_coordinator(true);
        let total = Tensor::randn(&[1, 1000], 42);
        let want = c
            .execute(OpRequest::new(OpKind::Fir, vec![total.clone()]))
            .unwrap();
        let (sid, overlap) = c.session_open(OpKind::Fir).unwrap();
        assert_eq!(overlap, 63, "fir_taps - 1 with the default router config");
        let data = total.data();
        let mut got: Vec<f32> = Vec::new();
        // first chunk shorter than the overlap exercises the accumulate
        // path (no output, everything carried)
        for chunk in [&data[..10], &data[10..300], &data[300..1000]] {
            let out = c.session_push(sid, chunk, None).unwrap();
            got.extend_from_slice(&out.samples);
        }
        let want_data = want.outputs[0].data();
        assert_eq!(got.len(), want_data.len());
        for (i, (a, b)) in got.iter().zip(want_data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "chunked output diverged at {i}");
        }
        let summary = c.session_close(sid).unwrap();
        assert_eq!(summary.chunks, 3);
        assert_eq!(summary.samples_in, 1000);
        assert_eq!(summary.samples_out, got.len() as u64);
        assert_eq!(c.sessions().active(), 0);
        let m = c.metrics();
        assert_eq!(m.sessions_opened.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_closed.load(Ordering::Relaxed), 1);
        assert_eq!(m.session_chunks.load(Ordering::Relaxed), 3);
        // non-streamable ops are refused at open; unknown sessions and
        // empty chunks are refused at push
        assert!(c.session_open(OpKind::MatMul).is_err());
        assert!(c.session_push(9999, &[1.0], None).is_err());
        assert!(c.session_push(sid, &[], None).is_err());
    }

    #[test]
    fn failed_session_push_leaves_the_stream_retryable() {
        let c = empty_coordinator(true);
        let (sid, _) = c.session_open(OpKind::Fir).unwrap();
        let x = Tensor::randn(&[1, 400], 7);
        let first = c.session_push(sid, &x.data()[..200], None).unwrap();
        assert!(!first.samples.is_empty());
        // an already-expired deadline sheds inside execute(); the carry
        // must be untouched so the retry continues the stream bit-for-bit
        let err = c.session_push(sid, &x.data()[200..], Some(Duration::ZERO));
        assert!(err.is_err(), "expired deadline must shed the push");
        let retry = c.session_push(sid, &x.data()[200..], None).unwrap();
        let one_shot = c
            .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]))
            .unwrap();
        let want = one_shot.outputs[0].data();
        let mut got = first.samples.clone();
        got.extend_from_slice(&retry.samples);
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want) {
            assert_eq!(a.to_bits(), b.to_bits(), "retry corrupted the stream");
        }
        let summary = c.session_close(sid).unwrap();
        assert_eq!(summary.chunks, 2, "the shed push must not count");
    }

    #[test]
    fn shutdown_idempotent() {
        let c = empty_coordinator(true);
        c.shutdown();
        c.shutdown();
    }

    #[test]
    fn shutdown_fails_queued_batched_rows() {
        // a row parked in the batcher (long flush deadline) must settle
        // with an error at shutdown, not strand its waiter: the waiter
        // typically holds the coordinator alive, so drop-time cleanup
        // alone would deadlock
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: true,
                workers: 2,
                batcher: BatcherConfig {
                    max_wait: Duration::from_secs(60),
                    max_bucket: 8,
                },
                ..Default::default()
            },
        )
        .unwrap();
        let slot = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 128], 1)],
        ));
        c.shutdown();
        assert!(slot.wait().is_err(), "queued row must fail at shutdown");
        assert_eq!(
            c.metrics().inflight_batched_requests.load(Ordering::Relaxed),
            0,
            "the failed row's permit must be released"
        );
        assert_eq!(c.metrics().failed.load(Ordering::Relaxed), 1);
        // the batcher is now closed: a late batched submit fails fast
        // instead of stranding in a queue no drain loop will visit
        let late = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 128], 2)],
        ));
        assert!(late.wait().is_err(), "post-shutdown batched submit must fail");
    }

    /// Registry with real fir artifacts but no HLO files on disk.  The
    /// vaccel backend serves these from manifest shapes alone (programs
    /// are lowered, not read from disk); the PJRT stub cannot, so its
    /// probe disarms the Auto artifact arm.
    fn fir_registry() -> Registry {
        Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{
              "version": 1,
              "entries": [
                {"name": "fir_tina_f32_B1_L1024", "op": "fir", "impl": "tina",
                 "dtype": "f32", "params": {"l": 1024, "taps": 64, "batch": 1},
                 "inputs": [{"shape": [1, 1024], "dtype": "float32"}],
                 "outputs": [{"shape": [1, 961], "dtype": "float32"}],
                 "file": "a.hlo.txt"},
                {"name": "fir_tina_f32_B8_L1024", "op": "fir", "impl": "tina",
                 "dtype": "f32", "params": {"l": 1024, "taps": 64, "batch": 8},
                 "inputs": [{"shape": [8, 1024], "dtype": "float32"}],
                 "outputs": [{"shape": [8, 961], "dtype": "float32"}],
                 "file": "b.hlo.txt"}
              ]
            }"#,
        )
        .unwrap()
    }

    fn fir_coordinator(batching: bool) -> Coordinator {
        Coordinator::new(
            fir_registry(),
            CoordinatorConfig {
                batching,
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[cfg(not(feature = "vaccel"))]
    #[test]
    fn stub_probe_disarms_the_auto_artifact_arm() {
        // the PJRT stub cannot compile, so the typed capability probe
        // reports can_execute=false and Auto traffic never touches the
        // artifact arm — no execute-time "runtime unavailable" errors
        let c = fir_coordinator(false);
        assert!(!c.router().artifact_arm_live(), "stub probe must disarm");
        let cap = c.engine().capability();
        assert_eq!(cap.backend, "pjrt");
        assert!(!cap.can_execute);
        let x = Tensor::randn(&[1, 1024], 1);
        let resp = c.execute(OpRequest::new(OpKind::Fir, vec![x])).unwrap();
        assert_eq!(resp.served_by, "interp:fir", "Auto degrades to the plan arm");
    }

    #[cfg(feature = "vaccel")]
    #[test]
    fn vaccel_probe_arms_auto_and_serves_artifacts_bitwise() {
        let c = fir_coordinator(false);
        let cap = c.engine().capability();
        assert_eq!(cap.backend, "vaccel");
        assert!(cap.can_execute, "loaded programs must arm the backend: {}", cap.detail);
        assert!(c.router().artifact_arm_live());
        // exact-shape Auto request: unmeasured artifact arm is explored
        let x = Tensor::randn(&[8, 1024], 3);
        let resp = c
            .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]))
            .unwrap();
        assert_eq!(resp.served_by, "fir_tina_f32_B8_L1024");
        assert_eq!(c.metrics().vaccel_batches.load(Ordering::Relaxed), 1);
        // oracle contract: bit-for-bit the interpreter result
        let req = OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Interp);
        let Target::Interp { key } = c.router().route(&req).unwrap() else {
            panic!("expected interp target");
        };
        let want = c
            .router()
            .interpreter(&key, &req)
            .unwrap()
            .run(std::slice::from_ref(&x))
            .unwrap();
        assert_eq!(resp.outputs.len(), want.len());
        for (a, b) in resp.outputs.iter().zip(&want) {
            assert_eq!(a, b, "vaccel output diverged from the interpreter oracle");
        }
    }

    #[cfg(feature = "vaccel")]
    #[test]
    fn auto_follows_measured_latency_between_the_arms() {
        let c = fir_coordinator(false);
        let shapes = [vec![8usize, 1024]];
        // plant measurements: the plan arm is 5x faster than the artifact
        c.router().record_plan_latency(OpKind::Fir, &shapes, 100.0);
        c.router()
            .record_artifact_latency(OpKind::Fir, &shapes, 500.0);
        let x = Tensor::randn(&[8, 1024], 4);
        let resp = c
            .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]))
            .unwrap();
        assert_eq!(resp.served_by, "interp:fir", "Auto must pick the faster arm");
        assert!(c.metrics().auto_routed_plan.load(Ordering::Relaxed) >= 1);
        // strict pref still forces the artifact arm
        let strict = c
            .execute(OpRequest::new(OpKind::Fir, vec![x]).with_impl(ImplPref::Tina))
            .unwrap();
        assert_eq!(strict.served_by, "fir_tina_f32_B8_L1024");
    }

    #[cfg(feature = "vaccel")]
    #[test]
    fn batched_artifact_arm_rides_vaccel_bitwise() {
        // B=1 batchable requests coalesce under the B8 artifact key and
        // execute on the vaccel backend; rows must match the solo
        // (batching off, interpreter-oracle-equal) results bit-for-bit
        let batched = fir_coordinator(true);
        let solo = empty_coordinator(false);
        let xs: Vec<Tensor> = (0..5).map(|i| Tensor::randn(&[1, 1024], i)).collect();
        let slots: Vec<_> = xs
            .iter()
            .map(|x| {
                batched.submit(
                    OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Tina),
                )
            })
            .collect();
        for (x, s) in xs.iter().zip(slots) {
            let resp = s.wait().unwrap();
            assert_eq!(resp.served_by, "fir_tina_f32_B8_L1024");
            assert!(resp.batched, "artifact request must ride the batcher");
            let want = solo
                .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]))
                .unwrap();
            assert_eq!(resp.outputs.len(), want.outputs.len());
            for (a, b) in resp.outputs.iter().zip(&want.outputs) {
                assert_eq!(a, b, "batched vaccel row diverged from the solo run");
            }
        }
        let m = batched.metrics();
        assert!(m.batches_executed.load(Ordering::Relaxed) >= 1);
        assert_eq!(
            m.batched_requests.load(Ordering::Relaxed),
            5,
            "all rows must coalesce through the artifact arm"
        );
        assert!(m.vaccel_batches.load(Ordering::Relaxed) >= 1);
        assert_eq!(m.failed.load(Ordering::Relaxed), 0);
    }

    #[cfg(feature = "vaccel")]
    #[test]
    fn quarantined_artifact_degrades_to_interpreter_and_paroles() {
        let c = Coordinator::new(
            fir_registry(),
            CoordinatorConfig {
                batching: false,
                workers: 2,
                router: RouterConfig {
                    quarantine_backoff: Duration::from_millis(40),
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        let x = Tensor::randn(&[8, 1024], 5);
        let req = OpRequest::new(OpKind::Fir, vec![x.clone()]).with_impl(ImplPref::Tina);
        let baseline = c.execute(req.clone()).unwrap();
        assert_eq!(baseline.served_by, "fir_tina_f32_B8_L1024");
        c.router()
            .quarantine_artifact("fir_tina_f32_B8_L1024", "test");
        let degraded = c.execute(req.clone()).unwrap();
        assert_eq!(degraded.served_by, "interp:fir", "stable served_by contract");
        assert_eq!(c.metrics().degraded_requests.load(Ordering::Relaxed), 1);
        for (a, b) in degraded.outputs.iter().zip(&baseline.outputs) {
            assert_eq!(a, b, "degraded mode must be bit-for-bit the artifact result");
        }
        // after the backoff the artifact is paroled and serves again
        std::thread::sleep(Duration::from_millis(60));
        let paroled = c.execute(req).unwrap();
        assert_eq!(paroled.served_by, "fir_tina_f32_B8_L1024");
        assert_eq!(c.metrics().degraded_requests.load(Ordering::Relaxed), 1);
    }
}
