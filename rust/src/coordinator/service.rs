//! The coordinator service: ties router, batcher, worker pool, engine
//! handle and metrics into the serving object examples/benches/server use.
//!
//! Request path (all rust, no python):
//!
//! ```text
//!  submit(OpRequest)
//!    └─ route ──── artifact, batchable,  B==1 ─▶ batcher ─▶ engine
//!        ├──────── artifact, exact shape ──────▶ worker  ─▶ engine
//!        ├──────── fallback, batchable, B==1 ──▶ batcher ─▶ planned engine
//!        └──────── fallback, anything else ────▶ worker  ─▶ planned engine
//! ```
//!
//! With batching enabled, *all* fallback traffic runs on the planned
//! engine at a coalesced batch size: batchable single-row requests are
//! shape-bucketed by the batcher (grouped per (op, L), padded to the next
//! power-of-two bucket, executed once, scattered back per row), and every
//! other fallback request is simply the degenerate case of the same path
//! at its own batch size.
//!
//! # Completion-driven batched lifecycle
//!
//! Batched requests never touch the worker pool.  `submit` acquires an
//! in-flight slot from the [`InflightGate`] (blocking = backpressure at
//! enqueue, bounded by [`CoordinatorConfig::max_inflight_batched`]),
//! wraps the response slot + op + `t0` into a
//! [`Completion`](super::batcher::Completion), and enqueues it with the
//! row.  The drain loop forms batches and hands each one to a detached
//! per-batch execution thread, which completes every row's response
//! *directly* from the scatter — for both the artifact engine path and
//! the bucketed planned path.  Consequences the tests pin down:
//!
//! * in-flight batched requests are capped by the gate, not by the
//!   worker-pool size (`drain_completions == batched_fallback_requests`
//!   proves no request relayed through a parked worker);
//! * the drain loop itself never executes a batch, so a cold plan
//!   compile or a slow bucket cannot head-of-line-block other keys;
//! * latency histograms measure from submit (`t0` rides the `Pending`).

use super::batcher::{
    scatter_results, scatter_row_results, BatchKey, Batcher, BatcherConfig, Completion,
    InflightGate,
};
use super::metrics::Metrics;
use super::request::{OpRequest, OpResponse};
use super::router::{Router, RouterConfig, Target};
use crate::runtime::{EngineHandle, Registry};
use crate::util::threadpool::{OneShot, ThreadPool};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Drain the router's accumulated counters — plan-cache evictions and
/// fusion-pass stats — into the metrics sink.  Every serving path that
/// may have compiled (or evicted) a plan calls this one helper, so a
/// counter added to the router is surfaced on all arms at once.
fn sync_router_counters(metrics: &Metrics, router: &Router) {
    metrics.record_plan_cache_evictions(router.take_plan_cache_evictions());
    let (fused, copies) = router.take_fusion_counters();
    metrics.record_plan_fusion(fused, copies);
    let (verified, ns) = router.take_verify_counters();
    metrics.record_plan_verification(verified, ns);
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Routing parameters and fallback plan-cache bound.
    pub router: RouterConfig,
    /// Batching ceilings (adaptive sizing never exceeds them).
    pub batcher: BatcherConfig,
    /// Worker threads handling non-batched requests.
    pub workers: usize,
    /// Bound on the worker queue (backpressure).
    pub queue_capacity: usize,
    /// Bound on in-flight *batched* requests: `submit` blocks at enqueue
    /// once this many batched requests are admitted but not yet
    /// completed.  This replaces the old implicit cap (one parked
    /// worker per batched request, i.e. the pool size) with an explicit,
    /// much higher admission limit.
    pub max_inflight_batched: usize,
    /// Enable the dynamic batcher (ablation knob).
    pub batching: bool,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            router: RouterConfig::default(),
            batcher: BatcherConfig::default(),
            workers: crate::util::threadpool::default_threads(),
            queue_capacity: 256,
            max_inflight_batched: 1024,
            batching: true,
        }
    }
}

/// The serving coordinator.  Cheap to share via Arc; all methods take &self.
pub struct Coordinator {
    router: Arc<Router>,
    engine: EngineHandle,
    pool: ThreadPool,
    batcher: Arc<Batcher>,
    metrics: Arc<Metrics>,
    inflight: Arc<InflightGate>,
    config: CoordinatorConfig,
    stop: Arc<AtomicBool>,
    drain_thread: std::sync::Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Coordinator {
    /// Build from an artifact directory.
    pub fn from_dir(dir: impl AsRef<std::path::Path>, config: CoordinatorConfig) -> Result<Self> {
        let registry = Registry::load(dir)?;
        Self::new(registry, config)
    }

    /// Build from a loaded registry.
    pub fn new(registry: Registry, config: CoordinatorConfig) -> Result<Self> {
        let engine = EngineHandle::spawn(registry.clone())?;
        let router = Arc::new(Router::new(registry, config.router.clone()));
        let batcher = Arc::new(Batcher::new(config.batcher));
        let metrics = Arc::new(Metrics::new());
        let inflight = InflightGate::new(config.max_inflight_batched, Arc::clone(&metrics));
        let pool = ThreadPool::new(config.workers, config.queue_capacity);
        let stop = Arc::new(AtomicBool::new(false));

        let coord = Coordinator {
            router,
            engine,
            pool,
            batcher,
            metrics,
            inflight,
            config,
            stop,
            drain_thread: std::sync::Mutex::new(None),
        };
        if coord.config.batching {
            coord.start_drain_loop();
        }
        Ok(coord)
    }

    fn start_drain_loop(&self) {
        let batcher = Arc::clone(&self.batcher);
        let engine = self.engine.clone();
        let router = Arc::clone(&self.router);
        let metrics = Arc::clone(&self.metrics);
        let stop = Arc::clone(&self.stop);
        // the static ceiling: an adaptive cap below it counts as a shrink
        let bucket_ceiling = self.batcher.config().max_bucket;
        let handle = std::thread::Builder::new()
            .name("tina-batch-drain".into())
            .spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let Some(batch) = batcher.next_batch(Duration::from_millis(20)) else {
                        continue;
                    };
                    if let Some(d) = batch.adaptive {
                        metrics.record_adaptive_bucket(d.cap, d.wait, d.cap < bucket_ceiling);
                    }
                    // Execution — including a cold plan compile on a
                    // cache miss, and the response completions — runs on
                    // a detached per-batch thread (`spawn_batch_exec`)
                    // for BOTH arms: the drain loop keeps draining (no
                    // head-of-line blocking of co-queued batches behind
                    // a compile or a long bucket), and the worker pool
                    // is never involved, so replies cannot be capped or
                    // deadlocked by pool occupancy.
                    match batch.key.clone() {
                        BatchKey::Artifact { name, batch: b } => {
                            let engine = engine.clone();
                            let metrics = Arc::clone(&metrics);
                            spawn_batch_exec(move || {
                                let padding = b - batch.rows.len();
                                let result = engine.execute(&name, vec![batch.input.clone()]);
                                // success-only, like the fallback arm: a
                                // failed execute must not inflate the
                                // coalescing stats or the fill ratio
                                if result.is_ok() {
                                    metrics.record_batch(batch.rows.len(), padding);
                                }
                                scatter_results(batch, result);
                            });
                        }
                        BatchKey::Fallback { op, len } => {
                            // Bucketed fallback: one planned execution at
                            // the coalesced batch size, outputs scattered
                            // per row (padding rows are never gathered).
                            // Within the batch the kernels fan rows
                            // across scoped threads
                            // (`util::threadpool::parallel_for`).
                            let router = Arc::clone(&router);
                            let metrics = Arc::clone(&metrics);
                            spawn_batch_exec(move || {
                                let bucket = batch.input.shape()[0];
                                let rows_n = batch.rows.len();
                                let result = router
                                    .planned_for_shapes(op, &[vec![bucket, len]])
                                    .and_then(|(plan, hit)| {
                                        metrics.record_plan_cache_bucketed(bucket, hit);
                                        sync_router_counters(&metrics, &router);
                                        plan.run_rows(std::slice::from_ref(&batch.input), rows_n)
                                    });
                                // only successfully executed buckets
                                // count — a failed lookup/run must not
                                // inflate the coalescing stats or the
                                // fill ratio
                                if result.is_ok() {
                                    metrics.record_fallback_batch(rows_n, bucket - rows_n);
                                }
                                scatter_row_results(batch, result);
                            });
                        }
                    }
                }
            })
            .expect("spawn drain loop");
        *self.drain_thread.lock().unwrap() = Some(handle);
    }

    /// The coordinator's metrics sink.
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The request router (artifact lookup + fallback plan caches).
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The PJRT engine handle.
    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    /// Warm the executable cache for every artifact of an op (or all).
    pub fn warmup(&self, op_filter: Option<&str>) -> Result<usize> {
        let mut n = 0;
        for meta in self.router.registry().entries() {
            if let Some(f) = op_filter {
                if meta.op != f {
                    continue;
                }
            }
            self.engine.prepare(&meta.name)?;
            n += 1;
        }
        Ok(n)
    }

    /// Completion context for a request settling through this coordinator
    /// — the single `OpResponse` assembly point for every serving path.
    fn completion(
        &self,
        slot: &OneShot<Result<OpResponse>>,
        op: &'static str,
        served_by: String,
        t0: Instant,
        batched: bool,
    ) -> Completion {
        let permit = batched.then(|| self.inflight.acquire());
        Completion::new(
            Arc::clone(&self.metrics),
            slot.clone(),
            op,
            served_by,
            t0,
            permit,
        )
    }

    /// Submit asynchronously; the returned slot completes with the response.
    ///
    /// Batched requests may block here briefly when the in-flight limit
    /// is reached (backpressure at enqueue).
    pub fn submit(&self, req: OpRequest) -> OneShot<Result<OpResponse>> {
        let slot: OneShot<Result<OpResponse>> = OneShot::new();
        self.metrics.record_request();
        // surface plan-cache evictions and fusion counters from *any*
        // router path (including direct oracle/interpreter use between
        // requests), not just the fallback compile below
        sync_router_counters(&self.metrics, &self.router);
        let t0 = Instant::now();
        let op = req.op.as_str();

        let target = match self.router.route_with_batching(&req, self.config.batching) {
            Ok(t) => t,
            Err(e) => {
                self.completion(&slot, op, String::new(), t0, false).fail(e);
                return slot;
            }
        };

        match target {
            Target::Artifact { name, pad_batch } => {
                let batchable = self.config.batching
                    && req.op.batchable()
                    && req.inputs.len() == 1
                    && req.inputs[0].rank() == 2
                    && req.inputs[0].shape()[0] == 1
                    && pad_batch > 1;
                if batchable {
                    // ride the dynamic batcher; the drain-side execution
                    // thread completes the response directly
                    let key = BatchKey::Artifact {
                        name: name.clone(),
                        batch: pad_batch,
                    };
                    let completion = self.completion(&slot, op, name, t0, true);
                    self.batcher.enqueue(key, req.inputs[0].clone(), completion);
                } else {
                    let engine = self.engine.clone();
                    let completion = self.completion(&slot, op, name.clone(), t0, false);
                    let inputs = req.inputs;
                    self.pool.submit(move || {
                        completion.complete(engine.execute(&name, inputs));
                    });
                }
            }
            Target::Interp { key } => {
                // Fallback path: runs on the planned engine; the naive
                // interpreter remains the test oracle only.  `served_by`
                // keeps the "interp:" prefix as the stable fallback marker
                // of the serving API.
                self.metrics.record_interp_fallback();
                // Serving mode: batchable single-row requests ride the
                // shape-bucketed batcher, coalescing with co-arriving
                // same-(op, L) traffic into one planned execution at the
                // bucket batch size.  Everything else below is the
                // degenerate case of the same path at the request's own
                // batch size.
                let bucketable = self.config.batching
                    && req.op.batchable()
                    && req.inputs.len() == 1
                    && req.inputs[0].rank() == 2
                    && req.inputs[0].shape()[0] == 1;
                if bucketable {
                    let len = req.inputs[0].shape()[1];
                    let bkey = BatchKey::Fallback { op: req.op, len };
                    let input = req.inputs.into_iter().next().expect("checked arity");
                    let completion = self.completion(&slot, op, format!("interp:{op}"), t0, true);
                    self.batcher.enqueue(bkey, input, completion);
                    return slot;
                }
                let planned = match self.router.planned(&key, &req) {
                    Ok((p, hit)) => {
                        self.metrics.record_plan_cache(hit);
                        sync_router_counters(&self.metrics, &self.router);
                        p
                    }
                    Err(e) => {
                        self.completion(&slot, op, String::new(), t0, false).fail(e);
                        return slot;
                    }
                };
                let completion = self.completion(&slot, op, format!("interp:{op}"), t0, false);
                let inputs = req.inputs;
                self.pool.submit(move || {
                    completion.complete(planned.run(&inputs));
                });
            }
        }
        slot
    }

    /// Submit and wait.
    pub fn execute(&self, req: OpRequest) -> Result<OpResponse> {
        self.submit(req).wait()
    }

    /// Stop the batch drain loop (called on drop too).  Rows still queued
    /// in the batcher are failed here — after the drain thread has
    /// exited — so waiters blocked on their response slots get an error
    /// instead of hanging (a waiter typically holds the coordinator
    /// alive, so relying on drop-time cleanup would deadlock).  The
    /// batcher is closed in the same step: a batched request submitted
    /// concurrently with (or after) shutdown fails fast at enqueue
    /// instead of stranding in a queue no drain loop will visit.  Direct
    /// (non-batched) requests keep running on the worker pool until the
    /// coordinator drops.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.drain_thread.lock().unwrap().take() {
            let _ = h.join();
        }
        self.batcher
            .fail_pending("coordinator shut down before the batch executed");
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Run one formed batch's execution + scatter on a detached thread.
///
/// `Builder::spawn` (not `thread::spawn`): a refused OS thread under
/// resource pressure must not panic the drain loop.  On `Err` the un-run
/// closure is dropped, dropping the rows' carried `Completion`s — which
/// fails every request in the batch instead of wedging serving.  Replies
/// flow through those completions, not a join, so the thread is detached
/// on purpose; a panicking batch thread fails its rows the same way.
fn spawn_batch_exec(work: impl FnOnce() + Send + 'static) {
    let spawned = std::thread::Builder::new()
        .name("tina-batch-exec".into())
        .spawn(work);
    if let Err(e) = spawned {
        eprintln!("tina: batch exec spawn failed: {e}");
    }
}

/// Errors surfaced when building a coordinator without artifacts: kept as a
/// helper so binaries print a actionable message.
pub fn missing_artifacts_hint(dir: &std::path::Path) -> String {
    format!(
        "artifact directory '{}' not found or missing manifest.json — run `make artifacts` first",
        dir.display()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{ImplPref, OpKind};
    use crate::tensor::Tensor;
    use std::path::PathBuf;

    /// Registry with no artifacts: everything routes to the interpreter.
    fn empty_registry() -> Registry {
        Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap()
    }

    fn empty_coordinator(batching: bool) -> Coordinator {
        Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching,
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn interp_fallback_serves_requests() {
        let c = empty_coordinator(false);
        let a = Tensor::randn(&[4, 4], 1);
        let b = Tensor::randn(&[4, 4], 2);
        let resp = c
            .execute(OpRequest::new(OpKind::EwMult, vec![a.clone(), b.clone()]))
            .unwrap();
        assert_eq!(resp.served_by, "interp:ewmult");
        let want = crate::baselines::naive::ewmult(&a, &b).unwrap();
        assert!(resp.outputs[0].allclose(&want, 1e-6, 1e-6));
        assert_eq!(c.metrics().interp_fallbacks.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn repeat_fallback_requests_hit_plan_cache() {
        let c = empty_coordinator(false);
        for seed in 0..3u64 {
            let x = Tensor::randn(&[1, 256], seed);
            c.execute(OpRequest::new(OpKind::Fir, vec![x])).unwrap();
        }
        let m = c.metrics();
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 1, "one compile");
        assert_eq!(m.plan_cache_hits.load(Ordering::Relaxed), 2, "repeats hit");
        assert_eq!(c.router().cached_exec_plans(), 1);
        // a different shape signature compiles its own plan
        let y = Tensor::randn(&[1, 300], 9);
        c.execute(OpRequest::new(OpKind::Fir, vec![y])).unwrap();
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 2);
        assert_eq!(c.router().cached_exec_plans(), 2);
    }

    #[test]
    fn planned_fallback_matches_oracle_interpreter() {
        let c = empty_coordinator(false);
        let x = Tensor::randn(&[2, 400], 5);
        let resp = c
            .execute(OpRequest::new(OpKind::Stft, vec![x.clone()]))
            .unwrap();
        assert_eq!(resp.served_by, "interp:stft");
        // oracle: the naive interpreter over the router's own graph
        let req = OpRequest::new(OpKind::Stft, vec![x.clone()]).with_impl(ImplPref::Interp);
        let crate::coordinator::Target::Interp { key } = c.router().route(&req).unwrap() else {
            panic!("expected interp target");
        };
        let want = c
            .router()
            .interpreter(&key, &req)
            .unwrap()
            .run(std::slice::from_ref(&x))
            .unwrap();
        for (a, b) in resp.outputs.iter().zip(&want) {
            assert!(a.allclose(b, 1e-5, 1e-5), "planned engine diverged from oracle");
        }
    }

    #[test]
    fn shape_diverse_traffic_is_bounded_by_the_plan_cache_cap() {
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: false,
                workers: 2,
                router: crate::coordinator::RouterConfig {
                    plan_cache_cap: 2,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        for l in [128usize, 160, 192, 224] {
            let x = Tensor::randn(&[1, l], l as u64);
            c.execute(OpRequest::new(OpKind::Fir, vec![x])).unwrap();
        }
        assert_eq!(c.router().cached_exec_plans(), 2, "cap must bound the cache");
        assert_eq!(
            c.metrics().plan_cache_evictions.load(Ordering::Relaxed),
            2,
            "evictions must be surfaced in metrics"
        );
    }

    #[test]
    fn batched_fallback_matches_solo_bitwise() {
        // batching on: batchable B=1 fallback requests ride the
        // shape-bucketed batcher and must return exactly what the solo
        // (batching off) path returns for the same inputs
        let batched = empty_coordinator(true);
        let solo = empty_coordinator(false);
        let l = 300;
        let xs: Vec<Tensor> = (0..5).map(|i| Tensor::randn(&[1, l], i)).collect();
        let slots: Vec<_> = xs
            .iter()
            .map(|x| batched.submit(OpRequest::new(OpKind::Fir, vec![x.clone()])))
            .collect();
        for (x, s) in xs.iter().zip(slots) {
            let resp = s.wait().unwrap();
            assert_eq!(resp.served_by, "interp:fir");
            assert!(resp.batched, "fallback request must ride the batcher");
            let want = solo
                .execute(OpRequest::new(OpKind::Fir, vec![x.clone()]))
                .unwrap();
            assert_eq!(resp.outputs.len(), want.outputs.len());
            for (a, b) in resp.outputs.iter().zip(&want.outputs) {
                assert_eq!(a, b, "bucketed row diverged from the solo run");
            }
        }
        let m = batched.metrics();
        assert_eq!(
            m.batched_fallback_requests.load(Ordering::Relaxed),
            5,
            "every request must be counted as coalesced fallback traffic"
        );
        let batches = m.fallback_batches_executed.load(Ordering::Relaxed);
        assert!(batches >= 1, "at least one bucket must have executed");
        // completion-driven serving: every batched reply was finished by
        // a drain-side execution thread, none by a parked worker relay
        assert_eq!(
            m.drain_completions.load(Ordering::Relaxed),
            5,
            "all batched replies must complete from the drain scatter"
        );
        assert_eq!(
            m.inflight_batched_requests.load(Ordering::Relaxed),
            0,
            "in-flight gauge must settle to zero"
        );
        // per-bucket plan-cache stats cover exactly the executed buckets
        let lookups: u64 = m
            .plan_cache_bucket_stats()
            .iter()
            .map(|&(_, h, mi)| h + mi)
            .sum();
        assert_eq!(lookups, batches, "one bucketed plan lookup per batch");
        let fill = m.batch_fill_ratio();
        assert!(fill > 0.0 && fill <= 1.0, "fill ratio out of range: {fill}");
    }

    #[test]
    fn batched_requests_do_not_consume_pool_workers() {
        // the lifted-cap property at unit scale: a single-worker pool with
        // a single-slot queue serves many concurrent batched requests,
        // which the old parked-relay design could not (each in-flight
        // batched request occupied a worker)
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: true,
                workers: 1,
                queue_capacity: 1,
                ..Default::default()
            },
        )
        .unwrap();
        let n = 16usize;
        let slots: Vec<_> = (0..n)
            .map(|i| {
                let x = Tensor::randn(&[1, 256], i as u64);
                c.submit(OpRequest::new(OpKind::Fir, vec![x]))
            })
            .collect();
        for s in slots {
            let resp = s.wait().unwrap();
            assert!(resp.batched);
        }
        let m = c.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), n as u64);
        assert_eq!(
            m.drain_completions.load(Ordering::Relaxed),
            m.batched_fallback_requests.load(Ordering::Relaxed),
            "every batched reply must come from the drain scatter"
        );
        assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn inflight_limit_backpressures_but_stays_live() {
        // a tiny in-flight limit forces submit() to block at enqueue;
        // the drain loop must keep freeing slots so every request still
        // completes (liveness of the backpressure path)
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: true,
                workers: 2,
                max_inflight_batched: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let n = 8usize;
        let mut slots = Vec::new();
        for i in 0..n {
            let x = Tensor::randn(&[1, 128], i as u64);
            // sequential submits: the 3rd+ block until the drain thread
            // completes earlier rows, then proceed
            slots.push(c.submit(OpRequest::new(OpKind::Fir, vec![x])));
        }
        for s in slots {
            assert!(s.wait().is_ok());
        }
        let m = c.metrics();
        assert_eq!(m.completed.load(Ordering::Relaxed), n as u64);
        assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn mixed_length_fallback_requests_route_to_buckets() {
        // PR 1 rejected mixed-length rows sharing a batch key; bucketing
        // makes different lengths land in different buckets instead
        let c = empty_coordinator(true);
        let a = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 256], 1)],
        ));
        let b = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 320], 2)],
        ));
        let ra = a.wait().unwrap();
        let rb = b.wait().unwrap();
        assert_eq!(ra.outputs[0].shape(), &[1, 256 - 64 + 1]);
        assert_eq!(rb.outputs[0].shape(), &[1, 320 - 64 + 1]);
    }

    #[test]
    fn non_batchable_fallback_is_direct_even_with_batching() {
        // dft is not batchable: with batching on it must take the direct
        // (degenerate) planned path, not the batcher
        let c = empty_coordinator(true);
        let x = Tensor::randn(&[2, 64], 3);
        let resp = c.execute(OpRequest::new(OpKind::Dft, vec![x])).unwrap();
        assert_eq!(resp.served_by, "interp:dft");
        assert!(!resp.batched);
        assert_eq!(
            c.metrics()
                .batched_fallback_requests
                .load(Ordering::Relaxed),
            0
        );
        assert_eq!(
            c.metrics().drain_completions.load(Ordering::Relaxed),
            0,
            "direct requests must not be counted as drain completions"
        );
    }

    #[test]
    fn strict_tina_fails_without_artifacts() {
        let c = empty_coordinator(false);
        let req = OpRequest::new(OpKind::Fir, vec![Tensor::zeros(&[1, 128])])
            .with_impl(ImplPref::Tina);
        assert!(c.execute(req).is_err());
        assert_eq!(c.metrics().failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_submissions_complete() {
        let c = Arc::new(empty_coordinator(false));
        let slots: Vec<_> = (0..16)
            .map(|i| {
                let x = Tensor::randn(&[8, 8], i);
                let y = Tensor::randn(&[8, 8], 100 + i);
                c.submit(OpRequest::new(OpKind::EwAdd, vec![x, y]))
            })
            .collect();
        for s in slots {
            assert!(s.wait().is_ok());
        }
        assert_eq!(c.metrics().completed.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn shutdown_idempotent() {
        let c = empty_coordinator(true);
        c.shutdown();
        c.shutdown();
    }

    #[test]
    fn shutdown_fails_queued_batched_rows() {
        // a row parked in the batcher (long flush deadline) must settle
        // with an error at shutdown, not strand its waiter: the waiter
        // typically holds the coordinator alive, so drop-time cleanup
        // alone would deadlock
        let c = Coordinator::new(
            empty_registry(),
            CoordinatorConfig {
                batching: true,
                workers: 2,
                batcher: BatcherConfig {
                    max_wait: Duration::from_secs(60),
                    max_bucket: 8,
                },
                ..Default::default()
            },
        )
        .unwrap();
        let slot = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 128], 1)],
        ));
        c.shutdown();
        assert!(slot.wait().is_err(), "queued row must fail at shutdown");
        assert_eq!(
            c.metrics().inflight_batched_requests.load(Ordering::Relaxed),
            0,
            "the failed row's permit must be released"
        );
        assert_eq!(c.metrics().failed.load(Ordering::Relaxed), 1);
        // the batcher is now closed: a late batched submit fails fast
        // instead of stranding in a queue no drain loop will visit
        let late = c.submit(OpRequest::new(
            OpKind::Fir,
            vec![Tensor::randn(&[1, 128], 2)],
        ));
        assert!(late.wait().is_err(), "post-shutdown batched submit must fail");
    }
}
