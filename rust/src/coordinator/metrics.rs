//! Serving metrics: per-op counters, latency histograms, batch fill
//! accounting (artifact and shape-bucketed fallback batches), per-bucket
//! plan-cache statistics, and the completion-driven serving gauges.
//!
//! Invariants the counters encode:
//!
//! * every submitted request ends in exactly one `record_completion`
//!   (`completed + failed == settled requests`), with latency measured
//!   from the submit timestamp `t0` — batched requests carry `t0`
//!   through the batcher's `Pending`, so queue wait is included;
//! * `drain_completions` counts responses finished directly by a batch
//!   execution thread — successes *and* failures, since both settle from
//!   the drain-side scatter.  `batched_fallback_requests` counts only
//!   successfully executed buckets (so padding waste never includes
//!   failed buckets), so with batching on, only bucketed fallback
//!   traffic, and every bucket executing successfully,
//!   `drain_completions == batched_fallback_requests` — the
//!   "no parked-worker relays" proof the e2e tests assert (they assert
//!   `failed == 0` first); a failed bucket makes `drain_completions`
//!   strictly larger, never smaller;
//! * `inflight_batched_requests` is a gauge mirroring the admission
//!   gate: it returns to zero once all batched replies complete.

use crate::util::histogram::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests submitted (settled or not).
    pub requests: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests that settled with an error.
    pub failed: AtomicU64,
    /// Requests coalesced into artifact batches.
    pub batched_requests: AtomicU64,
    /// Artifact batches executed through the engine.
    pub batches_executed: AtomicU64,
    /// Zero rows padded onto artifact batches.
    pub padded_rows: AtomicU64,
    /// Fallback requests coalesced into shape-bucketed batches and served
    /// by one planned execution at the bucket's batch size.
    pub batched_fallback_requests: AtomicU64,
    /// Shape-bucketed fallback batches executed on the planned engine.
    pub fallback_batches_executed: AtomicU64,
    /// Zero rows padded onto fallback buckets (masked out at scatter).
    pub fallback_padded_rows: AtomicU64,
    /// Gauge: batched requests currently holding an in-flight admission
    /// slot (enqueue through reply completion).  Returns to zero when the
    /// coordinator is idle.
    pub inflight_batched_requests: AtomicU64,
    /// Responses completed directly by a drain-side batch execution
    /// thread (no worker relay).  With only bucketed fallback traffic
    /// this equals `batched_fallback_requests`.
    pub drain_completions: AtomicU64,
    /// Gauge: the effective bucket cap the adaptive policy applied to the
    /// most recently formed fallback batch.
    pub adaptive_bucket_cap: AtomicU64,
    /// Gauge: the effective flush deadline (microseconds) applied to the
    /// most recently formed fallback batch.
    pub adaptive_bucket_wait_us: AtomicU64,
    /// Fallback batches formed under a cap *below* the static
    /// `max_bucket` ceiling (the adaptive policy actually shrinking).
    pub adaptive_bucket_shrinks: AtomicU64,
    /// Requests served by the fallback (planned/interpreter) path.
    pub interp_fallbacks: AtomicU64,
    /// Fallback requests served by an already-compiled exec plan.
    pub plan_cache_hits: AtomicU64,
    /// Fallback requests that had to compile a new exec plan.
    pub plan_cache_misses: AtomicU64,
    /// Plans dropped from the router's LRU-bounded caches (shape-diverse
    /// traffic overflowing `RouterConfig::plan_cache_cap`; every
    /// (op, shape, B) bucket entry counts individually).
    pub plan_cache_evictions: AtomicU64,
    /// Kernel steps removed by the plan-level fusion pass across all
    /// plans compiled through the router (window multiplies folded into
    /// their framing convs at compile time).
    pub fused_steps: AtomicU64,
    /// `Materialize` copies the fusion pass eliminated across all plans
    /// compiled through the router (merged-axis regroupings re-expressed
    /// as split-view reads — batched STFT framing is the shipped case).
    pub fusion_eliminated_copies: AtomicU64,
    /// Plans checked by the static verifier (always in debug builds,
    /// opt-in via `RouterConfig::verify_plans` in release).
    pub plans_verified: AtomicU64,
    /// Total nanoseconds spent in the static plan verifier.
    pub verify_ns: AtomicU64,
    /// Batch/direct executions that panicked and were contained by the
    /// exec layer's `catch_unwind` (each fails only its own batch).
    pub exec_panics: AtomicU64,
    /// Plan keys quarantined after a panic or verification failure
    /// (drained from `Router::take_quarantine_counters`).
    pub quarantined_plans: AtomicU64,
    /// Requests served by the interpreter oracle because their plan key
    /// was quarantined (graceful degradation, bit-for-bit results).
    pub degraded_requests: AtomicU64,
    /// Batched rows shed before execution because their client deadline
    /// had already expired.
    pub shed_expired_rows: AtomicU64,
    /// Requests refused at admission because the in-flight gate stayed
    /// saturated past the admission timeout ("overloaded, retry-after").
    pub admission_timeouts: AtomicU64,
    /// Artifact executions (batched and direct) served by the virtual
    /// accelerator backend (`runtime::vaccel`).
    pub vaccel_batches: AtomicU64,
    /// `ImplPref::Auto` requests the router steered to the planned CPU
    /// arm although an artifact existed (quarantined, or measured
    /// slower); drained from `Router::take_auto_routed`.
    pub auto_routed_plan: AtomicU64,
    /// `ImplPref::Auto` requests the router steered to the artifact arm
    /// (unmeasured exploration, or measured at least as fast); drained
    /// from `Router::take_auto_routed`.
    pub auto_routed_artifact: AtomicU64,
    /// Wire frames (binary mode) or lines (JSON mode) refused for
    /// exceeding the server's size cap; the connection is closed after
    /// the refusal.
    pub oversized_frames: AtomicU64,
    /// Binary frames accepted by the framed reader.
    pub wire_binary_frames: AtomicU64,
    /// JSON protocol lines processed by the compat mode.
    pub wire_json_lines: AtomicU64,
    /// Streaming sessions opened.
    pub sessions_opened: AtomicU64,
    /// Streaming sessions closed.
    pub sessions_closed: AtomicU64,
    /// Chunks pushed into streaming sessions (across all sessions).
    pub session_chunks: AtomicU64,
    /// Output samples produced by streaming-session pushes.
    pub session_samples_out: AtomicU64,
    /// Plan-cache (hits, misses) per fallback bucket size B.
    plan_cache_buckets: Mutex<BTreeMap<usize, (u64, u64)>>,
    latency: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    /// Fresh all-zero sink.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Count one submitted request.
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one in-flight batched request admitted through the gate.
    pub fn inc_inflight_batched(&self) {
        self.inflight_batched_requests
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Release one in-flight batched request (its reply completed).
    pub fn dec_inflight_batched(&self) {
        self.inflight_batched_requests
            .fetch_sub(1, Ordering::Relaxed);
    }

    /// Count one response completed directly from a drain-side batch
    /// execution thread.
    pub fn record_drain_completion(&self) {
        self.drain_completions.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the adaptive sizing decision a fallback batch formed under.
    pub fn record_adaptive_bucket(&self, cap: usize, wait: Duration, shrunk: bool) {
        self.adaptive_bucket_cap.store(cap as u64, Ordering::Relaxed);
        self.adaptive_bucket_wait_us
            .store(wait.as_micros().min(u64::MAX as u128) as u64, Ordering::Relaxed);
        if shrunk {
            self.adaptive_bucket_shrinks.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Settle one request: latency is measured from its submit timestamp.
    pub fn record_completion(&self, op: &str, latency: Duration, ok: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        let mut map = self.latency.lock().unwrap();
        map.entry(op.to_string())
            .or_default()
            .record_duration(latency);
    }

    /// Record one artifact batch: `coalesced` real rows plus `padding`
    /// zero rows up to the artifact's fixed batch dim.
    pub fn record_batch(&self, coalesced: usize, padding: usize) {
        self.batches_executed.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(coalesced as u64, Ordering::Relaxed);
        self.padded_rows.fetch_add(padding as u64, Ordering::Relaxed);
    }

    /// Record one shape-bucketed fallback batch: `coalesced` real rows
    /// plus `padding` zero rows up to the bucket size.
    pub fn record_fallback_batch(&self, coalesced: usize, padding: usize) {
        self.fallback_batches_executed.fetch_add(1, Ordering::Relaxed);
        self.batched_fallback_requests
            .fetch_add(coalesced as u64, Ordering::Relaxed);
        self.fallback_padded_rows
            .fetch_add(padding as u64, Ordering::Relaxed);
    }

    /// Count one request routed to the fallback (non-artifact) path.
    pub fn record_interp_fallback(&self) {
        self.interp_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record whether a fallback request found its exec plan in the cache.
    pub fn record_plan_cache(&self, hit: bool) {
        if hit {
            self.plan_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.plan_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a plan-cache lookup for a bucketed batch plan: folds into
    /// the global hit/miss counters *and* the per-bucket breakdown.
    pub fn record_plan_cache_bucketed(&self, bucket: usize, hit: bool) {
        self.record_plan_cache(hit);
        let mut map = self.plan_cache_buckets.lock().unwrap();
        let e = map.entry(bucket).or_insert((0, 0));
        if hit {
            e.0 += 1;
        } else {
            e.1 += 1;
        }
    }

    /// Per-bucket plan-cache stats as (bucket, hits, misses), ascending.
    pub fn plan_cache_bucket_stats(&self) -> Vec<(usize, u64, u64)> {
        self.plan_cache_buckets
            .lock()
            .unwrap()
            .iter()
            .map(|(&b, &(h, m))| (b, h, m))
            .collect()
    }

    /// Fold in plans evicted from the router's bounded caches.
    pub fn record_plan_cache_evictions(&self, n: u64) {
        if n > 0 {
            self.plan_cache_evictions.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Fold in the fusion-pass counters drained from the router
    /// (`Router::take_fusion_counters`): window folds applied and
    /// materialize copies eliminated by newly compiled plans.
    pub fn record_plan_fusion(&self, fused_steps: u64, eliminated_copies: u64) {
        if fused_steps > 0 {
            self.fused_steps.fetch_add(fused_steps, Ordering::Relaxed);
        }
        if eliminated_copies > 0 {
            self.fusion_eliminated_copies
                .fetch_add(eliminated_copies, Ordering::Relaxed);
        }
    }

    /// Fold in the static-verification counters drained from the router
    /// (`Router::take_verify_counters`): plans checked and nanoseconds
    /// spent checking them.
    pub fn record_plan_verification(&self, plans: u64, ns: u64) {
        if plans > 0 {
            self.plans_verified.fetch_add(plans, Ordering::Relaxed);
        }
        if ns > 0 {
            self.verify_ns.fetch_add(ns, Ordering::Relaxed);
        }
    }

    /// Count one contained execution panic (the batch it belonged to
    /// failed; the pool and every other batch survived).
    pub fn record_exec_panic(&self) {
        self.exec_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold in quarantine events drained from the router
    /// (`Router::take_quarantine_counters`).
    pub fn record_quarantined_plans(&self, n: u64) {
        if n > 0 {
            self.quarantined_plans.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Count `n` requests served via the interpreter oracle because their
    /// plan key was quarantined.
    pub fn record_degraded_requests(&self, n: u64) {
        if n > 0 {
            self.degraded_requests.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Count `n` rows shed pre-execution on an expired client deadline.
    pub fn record_shed_expired_rows(&self, n: u64) {
        if n > 0 {
            self.shed_expired_rows.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Count one admission refused on a saturated in-flight gate.
    pub fn record_admission_timeout(&self) {
        self.admission_timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one artifact execution served by the vaccel backend.
    pub fn record_vaccel_batch(&self) {
        self.vaccel_batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one oversized wire frame / protocol line refused.
    pub fn record_oversized_frame(&self) {
        self.oversized_frames.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one binary frame accepted by the framed reader.
    pub fn record_wire_binary_frame(&self) {
        self.wire_binary_frames.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one JSON protocol line processed by the compat mode.
    pub fn record_wire_json_line(&self) {
        self.wire_json_lines.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one streaming session opened.
    pub fn record_session_opened(&self) {
        self.sessions_opened.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one streaming session closed.
    pub fn record_session_closed(&self) {
        self.sessions_closed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one streaming-session push and its output samples.
    pub fn record_session_chunk(&self, samples_out: u64) {
        self.session_chunks.fetch_add(1, Ordering::Relaxed);
        if samples_out > 0 {
            self.session_samples_out
                .fetch_add(samples_out, Ordering::Relaxed);
        }
    }

    /// Fold in Auto-routing decisions drained from the router
    /// (`Router::take_auto_routed`): requests an artifact existed for
    /// that were steered to the plan arm vs. the artifact arm.
    pub fn record_auto_routed(&self, to_plan: u64, to_artifact: u64) {
        if to_plan > 0 {
            self.auto_routed_plan.fetch_add(to_plan, Ordering::Relaxed);
        }
        if to_artifact > 0 {
            self.auto_routed_artifact
                .fetch_add(to_artifact, Ordering::Relaxed);
        }
    }

    /// Fraction of executed batch rows (artifact + fallback buckets) that
    /// were real requests rather than padding.  1.0 when no batch has run
    /// yet (an empty history carries no padding waste).
    pub fn batch_fill_ratio(&self) -> f64 {
        let real = self.batched_requests.load(Ordering::Relaxed)
            + self.batched_fallback_requests.load(Ordering::Relaxed);
        let total = real
            + self.padded_rows.load(Ordering::Relaxed)
            + self.fallback_padded_rows.load(Ordering::Relaxed);
        if total == 0 {
            return 1.0;
        }
        real as f64 / total as f64
    }

    /// Latency histogram snapshot for one op.
    pub fn latency_of(&self, op: &str) -> Option<Histogram> {
        self.latency.lock().unwrap().get(op).cloned()
    }

    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests={} completed={} failed={} batched={} batches={} padded_rows={} batched_fallback={} fallback_batches={} fallback_padded_rows={} batch_fill_ratio={:.2} inflight_batched={} drain_completions={} adaptive_bucket_cap={} adaptive_bucket_wait_us={} adaptive_bucket_shrinks={} interp_fallbacks={} plan_cache_hits={} plan_cache_misses={} plan_cache_evictions={} fused_steps={} fusion_eliminated_copies={} plans_verified={} verify_ns={} exec_panics={} quarantined_plans={} degraded_requests={} shed_expired_rows={} admission_timeouts={} vaccel_batches={} auto_routed_plan={} auto_routed_artifact={} oversized_frames={} wire_binary_frames={} wire_json_lines={} sessions_opened={} sessions_closed={} session_chunks={} session_samples_out={}\n",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batched_requests.load(Ordering::Relaxed),
            self.batches_executed.load(Ordering::Relaxed),
            self.padded_rows.load(Ordering::Relaxed),
            self.batched_fallback_requests.load(Ordering::Relaxed),
            self.fallback_batches_executed.load(Ordering::Relaxed),
            self.fallback_padded_rows.load(Ordering::Relaxed),
            self.batch_fill_ratio(),
            self.inflight_batched_requests.load(Ordering::Relaxed),
            self.drain_completions.load(Ordering::Relaxed),
            self.adaptive_bucket_cap.load(Ordering::Relaxed),
            self.adaptive_bucket_wait_us.load(Ordering::Relaxed),
            self.adaptive_bucket_shrinks.load(Ordering::Relaxed),
            self.interp_fallbacks.load(Ordering::Relaxed),
            self.plan_cache_hits.load(Ordering::Relaxed),
            self.plan_cache_misses.load(Ordering::Relaxed),
            self.plan_cache_evictions.load(Ordering::Relaxed),
            self.fused_steps.load(Ordering::Relaxed),
            self.fusion_eliminated_copies.load(Ordering::Relaxed),
            self.plans_verified.load(Ordering::Relaxed),
            self.verify_ns.load(Ordering::Relaxed),
            self.exec_panics.load(Ordering::Relaxed),
            self.quarantined_plans.load(Ordering::Relaxed),
            self.degraded_requests.load(Ordering::Relaxed),
            self.shed_expired_rows.load(Ordering::Relaxed),
            self.admission_timeouts.load(Ordering::Relaxed),
            self.vaccel_batches.load(Ordering::Relaxed),
            self.auto_routed_plan.load(Ordering::Relaxed),
            self.auto_routed_artifact.load(Ordering::Relaxed),
            self.oversized_frames.load(Ordering::Relaxed),
            self.wire_binary_frames.load(Ordering::Relaxed),
            self.wire_json_lines.load(Ordering::Relaxed),
            self.sessions_opened.load(Ordering::Relaxed),
            self.sessions_closed.load(Ordering::Relaxed),
            self.session_chunks.load(Ordering::Relaxed),
            self.session_samples_out.load(Ordering::Relaxed),
        ));
        for (bucket, hits, misses) in self.plan_cache_bucket_stats() {
            out.push_str(&format!(
                "  plan_cache bucket B={bucket}: hits={hits} misses={misses}\n"
            ));
        }
        for (op, h) in self.latency.lock().unwrap().iter() {
            out.push_str(&format!("  {op}: {}\n", h.summary()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_completion("fir", Duration::from_micros(100), true);
        m.record_completion("fir", Duration::from_micros(300), false);
        m.record_batch(5, 3);
        m.record_plan_cache(false);
        m.record_plan_cache(true);
        m.record_plan_cache(true);
        m.record_plan_cache_evictions(0);
        m.record_plan_cache_evictions(2);
        m.record_plan_fusion(0, 0);
        m.record_plan_fusion(2, 1);
        m.record_plan_verification(0, 0);
        m.record_plan_verification(3, 4_500);
        assert_eq!(m.plan_cache_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(m.plan_cache_evictions.load(Ordering::Relaxed), 2);
        assert_eq!(m.fused_steps.load(Ordering::Relaxed), 2);
        assert_eq!(m.fusion_eliminated_copies.load(Ordering::Relaxed), 1);
        assert_eq!(m.plans_verified.load(Ordering::Relaxed), 3);
        assert_eq!(m.verify_ns.load(Ordering::Relaxed), 4_500);
        assert!(m.report().contains("fused_steps=2"), "report surfaces fusion");
        assert!(
            m.report().contains("plans_verified=3"),
            "report surfaces verification"
        );
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.batched_requests.load(Ordering::Relaxed), 5);
        assert_eq!(m.padded_rows.load(Ordering::Relaxed), 3);
        let h = m.latency_of("fir").unwrap();
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn fallback_batches_and_fill_ratio() {
        let m = Metrics::new();
        assert_eq!(m.batch_fill_ratio(), 1.0, "no batches -> no waste");
        // one full artifact batch (4+0), one fallback bucket (3 real + 1 pad)
        m.record_batch(4, 0);
        m.record_fallback_batch(3, 1);
        assert_eq!(m.batched_fallback_requests.load(Ordering::Relaxed), 3);
        assert_eq!(m.fallback_batches_executed.load(Ordering::Relaxed), 1);
        assert_eq!(m.fallback_padded_rows.load(Ordering::Relaxed), 1);
        let fill = m.batch_fill_ratio();
        assert!((fill - 7.0 / 8.0).abs() < 1e-12, "fill={fill}");
    }

    #[test]
    fn per_bucket_plan_cache_stats() {
        let m = Metrics::new();
        m.record_plan_cache_bucketed(4, false);
        m.record_plan_cache_bucketed(4, true);
        m.record_plan_cache_bucketed(8, true);
        assert_eq!(
            m.plan_cache_bucket_stats(),
            vec![(4, 1, 1), (8, 1, 0)],
            "per-bucket hit/miss breakdown"
        );
        // bucketed lookups also feed the global counters
        assert_eq!(m.plan_cache_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 1);
        let r = m.report();
        assert!(r.contains("bucket B=4"), "report lists bucket stats: {r}");
    }

    #[test]
    fn completion_driven_gauges_and_counters() {
        let m = Metrics::new();
        m.inc_inflight_batched();
        m.inc_inflight_batched();
        m.dec_inflight_batched();
        assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 1);
        m.record_drain_completion();
        m.record_drain_completion();
        assert_eq!(m.drain_completions.load(Ordering::Relaxed), 2);
        // adaptive gauges: last decision wins, shrinks accumulate
        m.record_adaptive_bucket(8, Duration::from_millis(2), false);
        m.record_adaptive_bucket(2, Duration::from_micros(500), true);
        assert_eq!(m.adaptive_bucket_cap.load(Ordering::Relaxed), 2);
        assert_eq!(m.adaptive_bucket_wait_us.load(Ordering::Relaxed), 500);
        assert_eq!(m.adaptive_bucket_shrinks.load(Ordering::Relaxed), 1);
        let r = m.report();
        assert!(r.contains("drain_completions=2"), "report: {r}");
        assert!(r.contains("adaptive_bucket_cap=2"), "report: {r}");
        assert!(r.contains("inflight_batched=1"), "report: {r}");
    }

    #[test]
    fn fault_containment_counters_accumulate_and_report() {
        let m = Metrics::new();
        m.record_exec_panic();
        m.record_quarantined_plans(0);
        m.record_quarantined_plans(2);
        m.record_degraded_requests(0);
        m.record_degraded_requests(3);
        m.record_shed_expired_rows(0);
        m.record_shed_expired_rows(4);
        m.record_admission_timeout();
        assert_eq!(m.exec_panics.load(Ordering::Relaxed), 1);
        assert_eq!(m.quarantined_plans.load(Ordering::Relaxed), 2);
        assert_eq!(m.degraded_requests.load(Ordering::Relaxed), 3);
        assert_eq!(m.shed_expired_rows.load(Ordering::Relaxed), 4);
        assert_eq!(m.admission_timeouts.load(Ordering::Relaxed), 1);
        let r = m.report();
        assert!(r.contains("exec_panics=1"), "report: {r}");
        assert!(r.contains("quarantined_plans=2"), "report: {r}");
        assert!(r.contains("degraded_requests=3"), "report: {r}");
        assert!(r.contains("shed_expired_rows=4"), "report: {r}");
        assert!(r.contains("admission_timeouts=1"), "report: {r}");
    }

    #[test]
    fn backend_routing_counters_accumulate_and_report() {
        let m = Metrics::new();
        m.record_vaccel_batch();
        m.record_vaccel_batch();
        m.record_auto_routed(0, 0);
        m.record_auto_routed(3, 5);
        assert_eq!(m.vaccel_batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.auto_routed_plan.load(Ordering::Relaxed), 3);
        assert_eq!(m.auto_routed_artifact.load(Ordering::Relaxed), 5);
        let r = m.report();
        assert!(r.contains("vaccel_batches=2"), "report: {r}");
        assert!(r.contains("auto_routed_plan=3"), "report: {r}");
        assert!(r.contains("auto_routed_artifact=5"), "report: {r}");
    }

    #[test]
    fn wire_and_session_counters_accumulate_and_report() {
        let m = Metrics::new();
        m.record_oversized_frame();
        m.record_wire_binary_frame();
        m.record_wire_binary_frame();
        m.record_wire_json_line();
        m.record_session_opened();
        m.record_session_chunk(0);
        m.record_session_chunk(937);
        m.record_session_closed();
        assert_eq!(m.oversized_frames.load(Ordering::Relaxed), 1);
        assert_eq!(m.wire_binary_frames.load(Ordering::Relaxed), 2);
        assert_eq!(m.wire_json_lines.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_opened.load(Ordering::Relaxed), 1);
        assert_eq!(m.sessions_closed.load(Ordering::Relaxed), 1);
        assert_eq!(m.session_chunks.load(Ordering::Relaxed), 2);
        assert_eq!(m.session_samples_out.load(Ordering::Relaxed), 937);
        let r = m.report();
        assert!(r.contains("oversized_frames=1"), "report: {r}");
        assert!(r.contains("wire_binary_frames=2"), "report: {r}");
        assert!(r.contains("sessions_opened=1"), "report: {r}");
        assert!(r.contains("session_chunks=2"), "report: {r}");
    }

    #[test]
    fn report_contains_ops() {
        let m = Metrics::new();
        m.record_completion("pfb", Duration::from_millis(2), true);
        let r = m.report();
        assert!(r.contains("pfb:"));
        assert!(r.contains("completed=1"));
    }

    #[test]
    fn latency_of_unknown_is_none() {
        assert!(Metrics::new().latency_of("nope").is_none());
    }
}
