//! Serving metrics: per-op counters and latency histograms.

use crate::util::histogram::Histogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub batched_requests: AtomicU64,
    pub batches_executed: AtomicU64,
    pub padded_rows: AtomicU64,
    pub interp_fallbacks: AtomicU64,
    /// Fallback requests served by an already-compiled exec plan.
    pub plan_cache_hits: AtomicU64,
    /// Fallback requests that had to compile a new exec plan.
    pub plan_cache_misses: AtomicU64,
    /// Plans dropped from the router's LRU-bounded caches (shape-diverse
    /// traffic overflowing `RouterConfig::plan_cache_cap`).
    pub plan_cache_evictions: AtomicU64,
    latency: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, op: &str, latency: Duration, ok: bool) {
        if ok {
            self.completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        let mut map = self.latency.lock().unwrap();
        map.entry(op.to_string())
            .or_default()
            .record_duration(latency);
    }

    pub fn record_batch(&self, coalesced: usize, padding: usize) {
        self.batches_executed.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(coalesced as u64, Ordering::Relaxed);
        self.padded_rows.fetch_add(padding as u64, Ordering::Relaxed);
    }

    pub fn record_interp_fallback(&self) {
        self.interp_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record whether a fallback request found its exec plan in the cache.
    pub fn record_plan_cache(&self, hit: bool) {
        if hit {
            self.plan_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.plan_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fold in plans evicted from the router's bounded caches.
    pub fn record_plan_cache_evictions(&self, n: u64) {
        if n > 0 {
            self.plan_cache_evictions.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Latency histogram snapshot for one op.
    pub fn latency_of(&self, op: &str) -> Option<Histogram> {
        self.latency.lock().unwrap().get(op).cloned()
    }

    /// Multi-line human-readable report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests={} completed={} failed={} batched={} batches={} padded_rows={} interp_fallbacks={} plan_cache_hits={} plan_cache_misses={} plan_cache_evictions={}\n",
            self.requests.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batched_requests.load(Ordering::Relaxed),
            self.batches_executed.load(Ordering::Relaxed),
            self.padded_rows.load(Ordering::Relaxed),
            self.interp_fallbacks.load(Ordering::Relaxed),
            self.plan_cache_hits.load(Ordering::Relaxed),
            self.plan_cache_misses.load(Ordering::Relaxed),
            self.plan_cache_evictions.load(Ordering::Relaxed),
        ));
        for (op, h) in self.latency.lock().unwrap().iter() {
            out.push_str(&format!("  {op}: {}\n", h.summary()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_completion("fir", Duration::from_micros(100), true);
        m.record_completion("fir", Duration::from_micros(300), false);
        m.record_batch(5, 3);
        m.record_plan_cache(false);
        m.record_plan_cache(true);
        m.record_plan_cache(true);
        m.record_plan_cache_evictions(0);
        m.record_plan_cache_evictions(2);
        assert_eq!(m.plan_cache_hits.load(Ordering::Relaxed), 2);
        assert_eq!(m.plan_cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(m.plan_cache_evictions.load(Ordering::Relaxed), 2);
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.batched_requests.load(Ordering::Relaxed), 5);
        assert_eq!(m.padded_rows.load(Ordering::Relaxed), 3);
        let h = m.latency_of("fir").unwrap();
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn report_contains_ops() {
        let m = Metrics::new();
        m.record_completion("pfb", Duration::from_millis(2), true);
        let r = m.report();
        assert!(r.contains("pfb:"));
        assert!(r.contains("completed=1"));
    }

    #[test]
    fn latency_of_unknown_is_none() {
        assert!(Metrics::new().latency_of("nope").is_none());
    }
}
