//! TCP serving front-end speaking two protocols on one port.
//!
//! The mode is auto-detected per connection from its first byte:
//!
//! * **Binary framed mode** (first byte `0xB7`, see [`wire`]): length-
//!   prefixed frames — magic + version + frame type + u32 payload length
//!   — with f32 payloads as raw little-endian bytes, never decimal text.
//!   Requests are **pipelined**: the reader thread admits each request to
//!   the coordinator as it arrives and a per-connection writer thread
//!   sends replies back in frame order, so a client may write N requests
//!   before reading any reply.  Streaming sessions (`SessionOpen` /
//!   `SessionPush` / `SessionClose`) carry chunked signals with the
//!   overlap tail held server-side; chunked output equals the one-shot
//!   run bit-for-bit.  Malformed payloads get an `Error` frame and the
//!   connection survives (the frame boundary is intact); bad magic /
//!   version / oversized frames get an `Error` frame and a close
//!   (synchronization is lost).
//!
//! * **JSON line mode** (anything else): the original newline-delimited
//!   JSON protocol, kept as the debug/compat surface:
//!
//! ```text
//! -> {"id": 1, "op": "fir", "impl": "auto", "dtype": "f32",
//!     "inputs": [{"shape": [1, 1024], "data": [ ... ]}]}
//! <- {"id": 1, "ok": true, "served_by": "fir_tina_f32_B1_L1024",
//!     "batched": false, "latency_us": 812,
//!     "outputs": [{"shape": [1, 961], "data": [ ... ]}]}
//!
//! -> {"id": 2, "cmd": "stats"}
//! <- {"id": 2, "ok": true, "report": "..."}
//!
//! -> {"id": 3, "cmd": "session_open", "op": "fir"}
//! <- {"id": 3, "ok": true, "session": 1, "overlap": 63}
//! -> {"id": 4, "cmd": "session_push", "session": 1, "data": [ ... ]}
//! <- {"id": 4, "ok": true, "chunk": 0, "samples": [ ... ]}
//! -> {"id": 5, "cmd": "session_close", "session": 1}
//! <- {"id": 5, "ok": true, "chunks": 1, "samples_in": 200, "samples_out": 137}
//! ```
//!
//!   Lines are read through a bounded reader capped at
//!   [`ServerConfig::max_frame`] bytes — a client streaming bytes without
//!   a newline gets a framed `"oversized"` error and a close instead of
//!   growing server memory without limit
//!   ([`Metrics::oversized_frames`](super::metrics::Metrics)).  An output
//!   tensor containing NaN/inf cannot be represented in JSON, so JSON
//!   mode replies with a structured error for it (binary mode carries
//!   non-finite values natively, bit-exact).
//!
//! Requests in both modes may carry an optional `deadline_ms` budget —
//! fractional milliseconds included (`0.9` is 900 µs, not a zero-length
//! deadline): the coordinator sheds the request if it cannot begin
//! executing within the budget.
//!
//! One reader thread per connection, capped at [`MAX_CONNECTIONS`] (plus
//! one writer thread per binary connection); finished handler threads are
//! reaped on every accept-loop pass.  At the cap the accept loop parks
//! new connections in the OS backlog instead of spawning.  Transient
//! `accept()` errors are logged and retried after a short backoff.  The
//! coordinator handles concurrency and backpressure internally, so a
//! connection thread blocked in `execute` never wedges other connections.
//! `latency_us` in replies measures the same span the coordinator's
//! histograms record: submit through completion.

use super::request::{ImplPref, OpKind, OpRequest, Precision};
use super::service::Coordinator;
use super::wire;
use crate::coordinator::request::OpResponse;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::util::threadpool::OneShot;
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Most concurrent connection-handler threads the server will run.  At
/// the cap, new connections wait in the OS accept backlog until a
/// handler finishes — bounded fan-out instead of thread-per-connection
/// exhaustion under a connection flood.
pub const MAX_CONNECTIONS: usize = 256;

/// Per-connection protocol limits.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Cap on a binary frame's payload *and* on a JSON line, in bytes.
    /// Input past the cap gets an error reply and a close.
    pub max_frame: usize,
    /// Bound on replies queued between a binary connection's reader and
    /// writer threads — the pipelining depth before the reader
    /// backpressures.
    pub pipeline_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_frame: wire::DEFAULT_MAX_FRAME,
            pipeline_depth: 64,
        }
    }
}

/// Serve until `stop` flips true (tests) or forever (CLI).
pub fn serve(coord: Arc<Coordinator>, addr: &str, stop: Arc<AtomicBool>) -> Result<()> {
    serve_listener(coord, TcpListener::bind(addr)?, stop)
}

/// Serve on a pre-bound listener (lets tests bind port 0) with default
/// protocol limits.
pub fn serve_listener(
    coord: Arc<Coordinator>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    serve_listener_with(coord, listener, stop, ServerConfig::default())
}

/// Serve on a pre-bound listener with explicit protocol limits.
pub fn serve_listener_with(
    coord: Arc<Coordinator>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    cfg: ServerConfig,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    eprintln!("tina: serving on {}", listener.local_addr()?);
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Acquire) {
        // reap finished handlers every pass so the vec tracks only live
        // connections (a long-lived server must not grow without bound)
        conns.retain(|h| !h.is_finished());
        if conns.len() >= MAX_CONNECTIONS {
            // at the cap: leave new connections in the OS backlog until a
            // handler frees a slot
            std::thread::sleep(std::time::Duration::from_millis(5));
            continue;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if let Err(e) = stream.set_nonblocking(false) {
                    eprintln!("tina: connection {peer}: {e}");
                    continue;
                }
                let coord = Arc::clone(&coord);
                let spawned = std::thread::Builder::new()
                    .name("tina-conn".into())
                    .spawn(move || {
                        if let Err(e) = handle_connection(coord, stream, cfg) {
                            eprintln!("tina: connection {peer}: {e}");
                        }
                    });
                match spawned {
                    Ok(h) => conns.push(h),
                    // a refused OS thread drops the stream (the client
                    // sees a closed connection) but serving continues
                    Err(e) => eprintln!("tina: connection thread spawn failed: {e}"),
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => {
                // transient accept failures — EMFILE/ENFILE under fd
                // pressure, aborted handshakes, interrupts — must not
                // take the serving loop down; back off and keep accepting
                eprintln!("tina: accept error (backing off): {e}");
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

/// Sniff the protocol from the connection's first byte and dispatch:
/// `0xB7` (the binary frame magic, invalid as a JSON first byte) selects
/// the framed mode, everything else the JSON line compat mode.
fn handle_connection(coord: Arc<Coordinator>, stream: TcpStream, cfg: ServerConfig) -> Result<()> {
    let writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let first = {
        let buf = reader.fill_buf()?;
        match buf.first() {
            Some(&b) => b,
            None => return Ok(()), // EOF before any byte
        }
    };
    if first == wire::MAGIC[0] {
        handle_binary(coord, reader, writer, cfg)
    } else {
        handle_json_lines(coord, reader, writer, cfg)
    }
}

// ---------------------------------------------------------------------------
// JSON line compat mode
// ---------------------------------------------------------------------------

enum LineRead {
    /// One complete line (newline stripped).
    Line(String),
    /// Clean end of stream.
    Eof,
    /// The line exceeded the cap before a newline arrived.
    Overflow,
}

/// Read one `\n`-terminated line without ever buffering more than `max`
/// bytes — the bounded replacement for `BufRead::lines()`, which grows
/// its buffer without limit on newline-free input.
fn read_line_bounded(reader: &mut BufReader<TcpStream>, max: usize) -> std::io::Result<LineRead> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (consumed, done) = {
            let buf = reader.fill_buf()?;
            if buf.is_empty() {
                if line.is_empty() {
                    return Ok(LineRead::Eof);
                }
                (0, true) // EOF terminates a final unterminated line
            } else if let Some(nl) = buf.iter().position(|&b| b == b'\n') {
                line.extend_from_slice(&buf[..nl]);
                (nl + 1, true)
            } else {
                line.extend_from_slice(buf);
                (buf.len(), false)
            }
        };
        reader.consume(consumed);
        if line.len() > max {
            return Ok(LineRead::Overflow);
        }
        if done {
            return Ok(LineRead::Line(String::from_utf8_lossy(&line).into_owned()));
        }
    }
}

fn handle_json_lines(
    coord: Arc<Coordinator>,
    mut reader: BufReader<TcpStream>,
    mut writer: TcpStream,
    cfg: ServerConfig,
) -> Result<()> {
    loop {
        match read_line_bounded(&mut reader, cfg.max_frame)? {
            LineRead::Eof => return Ok(()),
            LineRead::Overflow => {
                coord.metrics().record_oversized_frame();
                let resp = Json::obj(vec![
                    ("id", Json::Null),
                    ("ok", Json::Bool(false)),
                    ("oversized", Json::Bool(true)),
                    (
                        "error",
                        Json::str(format!(
                            "line exceeds the {}-byte limit; closing connection",
                            cfg.max_frame
                        )),
                    ),
                ]);
                writer.write_all(resp.to_string().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                return Ok(());
            }
            LineRead::Line(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                let response = handle_line(&coord, &line);
                writer.write_all(response.to_string().as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
            }
        }
    }
}

/// Process one protocol line (exposed for tests).
pub fn handle_line(coord: &Coordinator, line: &str) -> Json {
    coord.metrics().record_wire_json_line();
    let doc = match json::parse(line) {
        Ok(d) => d,
        Err(e) => return error_response(Json::Null, &format!("bad json: {e}")),
    };
    let id = doc.get("id").cloned().unwrap_or(Json::Null);
    match handle_doc(coord, &doc) {
        Ok(mut obj) => {
            if let Json::Obj(m) = &mut obj {
                m.insert("id".into(), id);
                m.insert("ok".into(), Json::Bool(true));
            }
            obj
        }
        Err(e) => error_response(id, &e.to_string()),
    }
}

fn error_response(id: Json, msg: &str) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
}

fn session_id_from(doc: &Json) -> Result<u64> {
    doc.get("session")
        .and_then(Json::as_usize)
        .map(|s| s as u64)
        .ok_or_else(|| anyhow!("missing 'session'"))
}

fn samples_from(doc: &Json, key: &str) -> Result<Vec<f32>> {
    doc.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing '{key}'"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("bad element"))
        })
        .collect()
}

fn deadline_from(doc: &Json) -> Result<Option<std::time::Duration>> {
    match doc.get("deadline_ms") {
        None => Ok(None),
        Some(v) => {
            let ms = v
                .as_f64()
                .ok_or_else(|| anyhow!("bad 'deadline_ms': expected a number"))?;
            Ok(Some(wire::deadline_from_ms(ms)?))
        }
    }
}

fn handle_doc(coord: &Coordinator, doc: &Json) -> Result<Json> {
    if let Some(cmd) = doc.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(Json::obj(vec![(
                "report",
                Json::str(coord.metrics().report()),
            )])),
            "ops" => Ok(Json::obj(vec![(
                "ops",
                Json::Arr(
                    OpKind::all()
                        .iter()
                        .map(|o| Json::str(o.as_str()))
                        .collect(),
                ),
            )])),
            "artifacts" => Ok(Json::obj(vec![(
                "artifacts",
                Json::Arr(
                    coord
                        .router()
                        .registry()
                        .entries()
                        .iter()
                        .map(|e| Json::str(e.name.clone()))
                        .collect(),
                ),
            )])),
            "session_open" => {
                let op = OpKind::parse(
                    doc.get("op")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("missing 'op'"))?,
                )?;
                let (session, overlap) = coord.session_open(op)?;
                Ok(Json::obj(vec![
                    ("session", Json::num(session as f64)),
                    ("overlap", Json::num(overlap as f64)),
                ]))
            }
            "session_push" => {
                let session = session_id_from(doc)?;
                let samples = samples_from(doc, "data")?;
                let deadline = deadline_from(doc)?;
                let out = coord.session_push(session, &samples, deadline)?;
                if out.samples.iter().any(|v| !v.is_finite()) {
                    return Err(anyhow!(
                        "session output contains non-finite values JSON cannot carry; \
                         use the binary protocol"
                    ));
                }
                Ok(Json::obj(vec![
                    ("chunk", Json::num(out.index as f64)),
                    (
                        "samples",
                        Json::Arr(out.samples.iter().map(|&v| Json::num(v as f64)).collect()),
                    ),
                ]))
            }
            "session_close" => {
                let s = coord.session_close(session_id_from(doc)?)?;
                Ok(Json::obj(vec![
                    ("chunks", Json::num(s.chunks as f64)),
                    ("samples_in", Json::num(s.samples_in as f64)),
                    ("samples_out", Json::num(s.samples_out as f64)),
                ]))
            }
            _ => Err(anyhow!("unknown cmd '{cmd}'")),
        };
    }

    let op = OpKind::parse(
        doc.get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing 'op'"))?,
    )?;
    let impl_pref = match doc.get("impl").and_then(Json::as_str) {
        Some(s) => ImplPref::parse(s)?,
        None => ImplPref::Auto,
    };
    let precision = match doc.get("dtype").and_then(Json::as_str) {
        Some(s) => Precision::parse(s)?,
        None => Precision::F32,
    };
    let inputs = doc
        .get("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'inputs'"))?
        .iter()
        .map(tensor_from_json)
        .collect::<Result<Vec<_>>>()?;

    let mut req = OpRequest {
        op,
        impl_pref,
        precision,
        inputs,
        deadline: None,
    };
    if let Some(budget) = deadline_from(doc)? {
        req = req.with_deadline(budget);
    }

    let t0 = std::time::Instant::now();
    let resp = coord.execute(req)?;
    let latency_us = t0.elapsed().as_micros() as f64;

    // JSON has no NaN/inf: a non-finite output would serialize as null
    // and silently corrupt the reply.  Refuse with a structured error;
    // the binary protocol carries non-finite values bit-exactly.
    for (i, t) in resp.outputs.iter().enumerate() {
        if t.data().iter().any(|v| !v.is_finite()) {
            return Err(anyhow!(
                "output {i} contains non-finite values JSON cannot carry; \
                 use the binary protocol"
            ));
        }
    }

    Ok(Json::obj(vec![
        ("served_by", Json::str(resp.served_by)),
        ("batched", Json::Bool(resp.batched)),
        ("latency_us", Json::num(latency_us)),
        (
            "outputs",
            Json::Arr(resp.outputs.iter().map(tensor_to_json).collect()),
        ),
    ]))
}

/// {"shape": [..], "data": [..]} -> Tensor.
pub fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let shape: Vec<usize> = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor missing 'shape'"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<_>>()?;
    let data: Vec<f32> = samples_from(j, "data")?;
    Tensor::new(&shape, data)
}

/// Tensor -> {"shape": [..], "data": [..]}.  This is the debug/compat
/// path: decimal text is acceptable here and nowhere else (the invariant
/// lint bans `Json::Arr` tensor data outside this file).
pub fn tensor_to_json(t: &Tensor) -> Json {
    Json::obj(vec![
        (
            "shape",
            Json::Arr(t.shape().iter().map(|&d| Json::num(d as f64)).collect()),
        ),
        (
            "data",
            Json::Arr(t.data().iter().map(|&v| Json::num(v as f64)).collect()),
        ),
    ])
}

// ---------------------------------------------------------------------------
// binary framed mode
// ---------------------------------------------------------------------------

/// One reply slot in the per-connection pipeline: either bytes ready to
/// send, or a pending op whose response slot the writer thread waits on
/// in order — which is what keeps replies in frame order while the
/// coordinator executes pipelined requests concurrently.
enum Reply {
    Ready(Vec<u8>),
    Pending {
        id: u64,
        t0: Instant,
        slot: OneShot<Result<OpResponse>>,
    },
}

fn handle_binary(
    coord: Arc<Coordinator>,
    mut reader: BufReader<TcpStream>,
    writer: TcpStream,
    cfg: ServerConfig,
) -> Result<()> {
    let (tx, rx) = mpsc::sync_channel::<Reply>(cfg.pipeline_depth.max(1));
    let wr = std::thread::Builder::new()
        .name("tina-conn-wr".into())
        .spawn(move || {
            let mut writer = writer;
            while let Ok(reply) = rx.recv() {
                let bytes = match reply {
                    Reply::Ready(b) => b,
                    Reply::Pending { id, t0, slot } => match slot.wait() {
                        Ok(resp) => {
                            let latency_us = t0.elapsed().as_micros() as f64;
                            wire::encode_response(id, &resp, latency_us)
                        }
                        Err(e) => wire::encode_error(id, &format!("{e:#}")),
                    },
                };
                let sent = writer.write_all(&bytes).and_then(|()| writer.flush());
                if sent.is_err() {
                    // client gone: drain remaining replies so pending
                    // slots still settle, then exit
                    while let Ok(r) = rx.recv() {
                        if let Reply::Pending { slot, .. } = r {
                            let _ = slot.wait();
                        }
                    }
                    return;
                }
            }
        })?;
    let result = binary_read_loop(&coord, &mut reader, &tx, &cfg);
    drop(tx); // close the channel: the writer drains and exits
    let _ = wr.join();
    result
}

fn binary_read_loop(
    coord: &Arc<Coordinator>,
    reader: &mut BufReader<TcpStream>,
    tx: &mpsc::SyncSender<Reply>,
    cfg: &ServerConfig,
) -> Result<()> {
    let mut payload = Vec::new();
    loop {
        let ft = match wire::read_frame(reader, &mut payload, cfg.max_frame) {
            Ok(Some(ft)) => ft,
            Ok(None) => return Ok(()), // clean EOF at a frame boundary
            Err(wire::FrameError::Oversized(n)) => {
                coord.metrics().record_oversized_frame();
                let msg = format!(
                    "frame of {n} bytes exceeds the {}-byte limit; closing connection",
                    cfg.max_frame
                );
                let _ = tx.send(Reply::Ready(wire::encode_error(0, &msg)));
                return Ok(());
            }
            // the peer died mid-frame: nothing to reply to
            Err(wire::FrameError::Truncated) => return Ok(()),
            Err(wire::FrameError::Io(e)) => return Err(e.into()),
            Err(e) => {
                // bad magic / version / unknown type: frame
                // synchronization is lost, so report and close
                let _ = tx.send(Reply::Ready(wire::encode_error(0, &format!("{e}; closing"))));
                return Ok(());
            }
        };
        coord.metrics().record_wire_binary_frame();
        let frame = match wire::decode_client_frame(ft, &payload) {
            Ok(f) => f,
            Err(e) => {
                // the frame boundary is intact: reply and keep serving
                let id = wire::peek_id(&payload);
                if tx.send(Reply::Ready(wire::encode_error(id, &e.to_string()))).is_err() {
                    return Ok(());
                }
                continue;
            }
        };
        let reply = match frame {
            wire::ClientFrame::Request(req) => {
                let id = req.id;
                match build_op_request(req) {
                    Ok(op_req) => {
                        // pipelining: admit now, let the writer thread
                        // wait for the response in order
                        let t0 = Instant::now();
                        let slot = coord.submit(op_req);
                        Reply::Pending { id, t0, slot }
                    }
                    Err(e) => Reply::Ready(wire::encode_error(id, &format!("{e:#}"))),
                }
            }
            wire::ClientFrame::SessionOpen { id, op } => {
                let run = || -> Result<Vec<u8>> {
                    let (session, overlap) = coord.session_open(op)?;
                    Ok(wire::encode_session_opened(id, session, overlap as u64))
                };
                Reply::Ready(run().unwrap_or_else(|e| wire::encode_error(id, &format!("{e:#}"))))
            }
            wire::ClientFrame::SessionPush {
                id,
                session,
                deadline_ms,
                samples,
            } => {
                let run = || -> Result<Vec<u8>> {
                    let deadline = deadline_ms.map(wire::deadline_from_ms).transpose()?;
                    let chunk = coord.session_push(session, &samples, deadline)?;
                    Ok(wire::encode_session_data(
                        id,
                        session,
                        chunk.index,
                        &chunk.samples,
                    ))
                };
                Reply::Ready(run().unwrap_or_else(|e| wire::encode_error(id, &format!("{e:#}"))))
            }
            wire::ClientFrame::SessionClose { id, session } => {
                let run = || -> Result<Vec<u8>> {
                    let s = coord.session_close(session)?;
                    Ok(wire::encode_session_closed(
                        id,
                        session,
                        s.chunks,
                        s.samples_in,
                        s.samples_out,
                    ))
                };
                Reply::Ready(run().unwrap_or_else(|e| wire::encode_error(id, &format!("{e:#}"))))
            }
            wire::ClientFrame::Stats { id } => {
                Reply::Ready(wire::encode_stats_reply(id, &coord.metrics().report()))
            }
        };
        if tx.send(reply).is_err() {
            return Ok(()); // writer exited (client gone)
        }
    }
}

/// Build an [`OpRequest`] from a decoded wire request, converting the
/// optional fractional-millisecond deadline without truncation.
fn build_op_request(req: wire::WireRequest) -> Result<OpRequest> {
    let mut out = OpRequest {
        op: req.op,
        impl_pref: req.impl_pref,
        precision: req.precision,
        inputs: req.inputs,
        deadline: None,
    };
    if let Some(ms) = req.deadline_ms {
        out = out.with_deadline(wire::deadline_from_ms(ms)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::CoordinatorConfig;
    use crate::runtime::Registry;
    use std::path::PathBuf;

    fn coordinator() -> Coordinator {
        let registry = Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap();
        Coordinator::new(
            registry,
            CoordinatorConfig {
                batching: false,
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn tensor_json_roundtrip() {
        let t = Tensor::randn(&[2, 3], 5);
        let j = tensor_to_json(&t);
        let back = tensor_from_json(&j).unwrap();
        assert!(t.allclose(&back, 1e-6, 1e-6));
    }

    #[test]
    fn op_request_over_protocol() {
        let c = coordinator();
        let line = r#"{"id": 7, "op": "summation",
                       "inputs": [{"shape": [4], "data": [1, 2, 3, 4]}]}"#;
        let resp = handle_line(&c, line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("id").and_then(Json::as_f64), Some(7.0));
        let outs = resp.get("outputs").unwrap().as_arr().unwrap();
        let t = tensor_from_json(&outs[0]).unwrap();
        assert_eq!(t.data(), &[10.0]);
        assert_eq!(c.metrics().wire_json_lines.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn stats_command() {
        let c = coordinator();
        let resp = handle_line(&c, r#"{"id": 1, "cmd": "stats"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert!(resp.get("report").is_some());
    }

    #[test]
    fn malformed_json_is_error_response() {
        let c = coordinator();
        let resp = handle_line(&c, "{nope");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get("error").is_some());
    }

    #[test]
    fn expired_deadline_over_protocol_is_shed() {
        let c = coordinator();
        let line = r#"{"id": 3, "op": "summation", "deadline_ms": 0,
                       "inputs": [{"shape": [4], "data": [1, 2, 3, 4]}]}"#;
        let resp = handle_line(&c, line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let err = resp.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("shed"), "got: {err}");
        let bad = handle_line(
            &c,
            r#"{"id": 4, "op": "summation", "deadline_ms": -5,
                "inputs": [{"shape": [4], "data": [1, 2, 3, 4]}]}"#,
        );
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn fractional_deadline_is_not_truncated_to_zero() {
        // regression: `ms as u64` turned a 0.9 ms budget into a 0 ms
        // deadline that shed deterministically at admission.  With the
        // fix the budget is 900 µs — comfortably more than the
        // microseconds between parse and the admission check on the
        // direct path, so the request executes.
        let c = coordinator();
        let line = r#"{"id": 5, "op": "summation", "deadline_ms": 0.9,
                       "inputs": [{"shape": [4], "data": [1, 2, 3, 4]}]}"#;
        let resp = handle_line(&c, line);
        assert_eq!(
            resp.get("ok"),
            Some(&Json::Bool(true)),
            "sub-millisecond budget must not shed instantly: {resp:?}"
        );
    }

    #[test]
    fn non_finite_json_output_is_a_structured_error() {
        // f32::MAX + f32::MAX overflows to +inf, which JSON cannot carry:
        // the reply must be a parseable structured error, never a line
        // containing bare `inf`
        let c = coordinator();
        let line = format!(
            r#"{{"id": 6, "op": "summation",
                "inputs": [{{"shape": [2], "data": [{m}, {m}]}}]}}"#,
            m = f32::MAX
        );
        let resp = handle_line(&c, &line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let err = resp.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("non-finite"), "got: {err}");
        // the reply itself must round-trip through the parser
        assert!(json::parse(&resp.to_string()).is_ok());
    }

    #[test]
    fn unknown_op_is_error_response() {
        let c = coordinator();
        let resp = handle_line(&c, r#"{"id": 2, "op": "zap", "inputs": []}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn json_session_lifecycle_over_protocol() {
        let c = coordinator();
        let opened = handle_line(&c, r#"{"id": 1, "cmd": "session_open", "op": "fir"}"#);
        assert_eq!(opened.get("ok"), Some(&Json::Bool(true)));
        let sid = opened.get("session").and_then(Json::as_usize).unwrap();
        assert_eq!(opened.get("overlap").and_then(Json::as_usize), Some(63));
        let push = handle_line(
            &c,
            &format!(
                r#"{{"id": 2, "cmd": "session_push", "session": {sid},
                    "data": [{}]}}"#,
                (0..100)
                    .map(|i| format!("{}", i as f32 * 0.25))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        );
        assert_eq!(push.get("ok"), Some(&Json::Bool(true)), "{push:?}");
        assert_eq!(push.get("chunk").and_then(Json::as_usize), Some(0));
        let n = push.get("samples").unwrap().as_arr().unwrap().len();
        assert_eq!(n, 100 - 64 + 1);
        let closed = handle_line(
            &c,
            &format!(r#"{{"id": 3, "cmd": "session_close", "session": {sid}}}"#),
        );
        assert_eq!(closed.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(closed.get("chunks").and_then(Json::as_usize), Some(1));
        assert_eq!(closed.get("samples_in").and_then(Json::as_usize), Some(100));
        // double close is a structured error
        let again = handle_line(
            &c,
            &format!(r#"{{"id": 4, "cmd": "session_close", "session": {sid}}}"#),
        );
        assert_eq!(again.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let c = Arc::new(coordinator());
        let stop = Arc::new(AtomicBool::new(false));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = Arc::clone(&c);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || serve_listener(c, listener, stop))
        };
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(30)))
            .unwrap();
        stream
            .write_all(
                br#"{"id": 1, "op": "ewadd", "inputs": [{"shape": [1, 2], "data": [1, 2]}, {"shape": [1, 2], "data": [10, 20]}]}"#,
            )
            .unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(&line).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let outs = resp.get("outputs").unwrap().as_arr().unwrap();
        let t = tensor_from_json(&outs[0]).unwrap();
        assert_eq!(t.data(), &[11.0, 22.0]);
        // close BOTH handles (reader holds a clone) so the server's
        // connection thread sees EOF and join() can complete
        drop(reader);
        drop(stream);
        stop.store(true, Ordering::Release);
        server.join().unwrap().unwrap();
    }

    #[test]
    fn oversized_json_line_is_refused_and_counted() {
        // regression: `reader.lines()` buffered newline-free input
        // without limit; the bounded reader refuses past the cap
        let c = Arc::new(coordinator());
        let stop = Arc::new(AtomicBool::new(false));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = Arc::clone(&c);
            let stop = Arc::clone(&stop);
            let cfg = ServerConfig {
                max_frame: 4096,
                ..Default::default()
            };
            std::thread::spawn(move || serve_listener_with(c, listener, stop, cfg))
        };
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(30)))
            .unwrap();
        // 8 KiB of newline-free JSON-ish bytes, double the cap
        stream.write_all(&vec![b'['; 8192]).unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(&line).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(resp.get("oversized"), Some(&Json::Bool(true)));
        // the server closes the connection after the refusal
        line.clear();
        assert_eq!(reader.read_line(&mut line).unwrap(), 0, "connection closed");
        assert_eq!(c.metrics().oversized_frames.load(Ordering::Relaxed), 1);
        drop(reader);
        drop(stream);
        stop.store(true, Ordering::Release);
        server.join().unwrap().unwrap();
    }
}
