//! TCP serving front-end: newline-delimited JSON requests over a socket.
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! -> {"id": 1, "op": "fir", "impl": "auto", "dtype": "f32",
//!     "inputs": [{"shape": [1, 1024], "data": [ ... ]}]}
//! <- {"id": 1, "ok": true, "served_by": "fir_tina_f32_B1_L1024",
//!     "batched": false, "latency_us": 812,
//!     "outputs": [{"shape": [1, 961], "data": [ ... ]}]}
//!
//! -> {"id": 2, "cmd": "stats"}
//! <- {"id": 2, "ok": true, "report": "..."}
//! ```
//!
//! One thread per connection, capped at [`MAX_CONNECTIONS`]; finished
//! handler threads are reaped on every accept-loop pass, so a long-lived
//! server does not accumulate dead `JoinHandle`s.  At the cap the accept
//! loop parks new connections in the OS backlog instead of spawning.
//! Transient `accept()` errors (EMFILE under fd pressure, aborted
//! handshakes) are logged and retried after a short backoff — they never
//! take the serving loop down.  The coordinator handles concurrency and
//! backpressure internally (worker-queue backpressure for direct
//! requests, the in-flight-batched admission gate for batched ones), so
//! a connection thread blocked in `execute` never wedges other
//! connections.  `latency_us` in the reply measures the same span the
//! coordinator's histograms record: submit through completion.
//!
//! Requests may carry an optional `"deadline_ms"` budget: the coordinator
//! sheds the request (fast error reply) if it cannot begin executing
//! within that many milliseconds of being parsed.

use super::request::{ImplPref, OpKind, OpRequest, Precision};
use super::service::Coordinator;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use anyhow::{anyhow, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Most concurrent connection-handler threads the server will run.  At
/// the cap, new connections wait in the OS accept backlog until a
/// handler finishes — bounded fan-out instead of thread-per-connection
/// exhaustion under a connection flood.
pub const MAX_CONNECTIONS: usize = 256;

/// Serve until `stop` flips true (tests) or forever (CLI).
pub fn serve(coord: Arc<Coordinator>, addr: &str, stop: Arc<AtomicBool>) -> Result<()> {
    serve_listener(coord, TcpListener::bind(addr)?, stop)
}

/// Serve on a pre-bound listener (lets tests bind port 0).
pub fn serve_listener(
    coord: Arc<Coordinator>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    eprintln!("tina: serving on {}", listener.local_addr()?);
    let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Acquire) {
        // reap finished handlers every pass so the vec tracks only live
        // connections (a long-lived server must not grow without bound)
        conns.retain(|h| !h.is_finished());
        if conns.len() >= MAX_CONNECTIONS {
            // at the cap: leave new connections in the OS backlog until a
            // handler frees a slot
            std::thread::sleep(std::time::Duration::from_millis(5));
            continue;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                if let Err(e) = stream.set_nonblocking(false) {
                    eprintln!("tina: connection {peer}: {e}");
                    continue;
                }
                let coord = Arc::clone(&coord);
                let spawned = std::thread::Builder::new()
                    .name("tina-conn".into())
                    .spawn(move || {
                        if let Err(e) = handle_connection(coord, stream) {
                            eprintln!("tina: connection {peer}: {e}");
                        }
                    });
                match spawned {
                    Ok(h) => conns.push(h),
                    // a refused OS thread drops the stream (the client
                    // sees a closed connection) but serving continues
                    Err(e) => eprintln!("tina: connection thread spawn failed: {e}"),
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => {
                // transient accept failures — EMFILE/ENFILE under fd
                // pressure, aborted handshakes, interrupts — must not
                // take the serving loop down; back off and keep accepting
                eprintln!("tina: accept error (backing off): {e}");
                std::thread::sleep(std::time::Duration::from_millis(100));
            }
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn handle_connection(coord: Arc<Coordinator>, stream: TcpStream) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = handle_line(&coord, &line);
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

/// Process one protocol line (exposed for tests).
pub fn handle_line(coord: &Coordinator, line: &str) -> Json {
    let doc = match json::parse(line) {
        Ok(d) => d,
        Err(e) => return error_response(Json::Null, &format!("bad json: {e}")),
    };
    let id = doc.get("id").cloned().unwrap_or(Json::Null);
    match handle_doc(coord, &doc) {
        Ok(mut obj) => {
            if let Json::Obj(m) = &mut obj {
                m.insert("id".into(), id);
                m.insert("ok".into(), Json::Bool(true));
            }
            obj
        }
        Err(e) => error_response(id, &e.to_string()),
    }
}

fn error_response(id: Json, msg: &str) -> Json {
    Json::obj(vec![
        ("id", id),
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
    ])
}

fn handle_doc(coord: &Coordinator, doc: &Json) -> Result<Json> {
    if let Some(cmd) = doc.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "stats" => Ok(Json::obj(vec![(
                "report",
                Json::str(coord.metrics().report()),
            )])),
            "ops" => Ok(Json::obj(vec![(
                "ops",
                Json::Arr(
                    OpKind::all()
                        .iter()
                        .map(|o| Json::str(o.as_str()))
                        .collect(),
                ),
            )])),
            "artifacts" => Ok(Json::obj(vec![(
                "artifacts",
                Json::Arr(
                    coord
                        .router()
                        .registry()
                        .entries()
                        .iter()
                        .map(|e| Json::str(e.name.clone()))
                        .collect(),
                ),
            )])),
            _ => Err(anyhow!("unknown cmd '{cmd}'")),
        };
    }

    let op = OpKind::parse(
        doc.get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("missing 'op'"))?,
    )?;
    let impl_pref = match doc.get("impl").and_then(Json::as_str) {
        Some(s) => ImplPref::parse(s)?,
        None => ImplPref::Auto,
    };
    let precision = match doc.get("dtype").and_then(Json::as_str) {
        Some(s) => Precision::parse(s)?,
        None => Precision::F32,
    };
    let inputs = doc
        .get("inputs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("missing 'inputs'"))?
        .iter()
        .map(tensor_from_json)
        .collect::<Result<Vec<_>>>()?;

    let mut req = OpRequest {
        op,
        impl_pref,
        precision,
        inputs,
        deadline: None,
    };
    if let Some(v) = doc.get("deadline_ms") {
        let ms = v
            .as_f64()
            .filter(|ms| ms.is_finite() && *ms >= 0.0)
            .ok_or_else(|| anyhow!("bad 'deadline_ms': expected a non-negative number"))?;
        req = req.with_deadline(std::time::Duration::from_millis(ms as u64));
    }

    let t0 = std::time::Instant::now();
    let resp = coord.execute(req)?;
    let latency_us = t0.elapsed().as_micros() as f64;

    Ok(Json::obj(vec![
        ("served_by", Json::str(resp.served_by)),
        ("batched", Json::Bool(resp.batched)),
        ("latency_us", Json::num(latency_us)),
        (
            "outputs",
            Json::Arr(resp.outputs.iter().map(tensor_to_json).collect()),
        ),
    ]))
}

/// {"shape": [..], "data": [..]} -> Tensor.
pub fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let shape: Vec<usize> = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor missing 'shape'"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<_>>()?;
    let data: Vec<f32> = j
        .get("data")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("tensor missing 'data'"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("bad element"))
        })
        .collect::<Result<_>>()?;
    Tensor::new(&shape, data)
}

/// Tensor -> {"shape": [..], "data": [..]}.
pub fn tensor_to_json(t: &Tensor) -> Json {
    Json::obj(vec![
        (
            "shape",
            Json::Arr(t.shape().iter().map(|&d| Json::num(d as f64)).collect()),
        ),
        (
            "data",
            Json::Arr(t.data().iter().map(|&v| Json::num(v as f64)).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::CoordinatorConfig;
    use crate::runtime::Registry;
    use std::path::PathBuf;

    fn coordinator() -> Coordinator {
        let registry = Registry::from_manifest_text(
            PathBuf::from("/nonexistent"),
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap();
        Coordinator::new(
            registry,
            CoordinatorConfig {
                batching: false,
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap()
    }

    #[test]
    fn tensor_json_roundtrip() {
        let t = Tensor::randn(&[2, 3], 5);
        let j = tensor_to_json(&t);
        let back = tensor_from_json(&j).unwrap();
        assert!(t.allclose(&back, 1e-6, 1e-6));
    }

    #[test]
    fn op_request_over_protocol() {
        let c = coordinator();
        let line = r#"{"id": 7, "op": "summation",
                       "inputs": [{"shape": [4], "data": [1, 2, 3, 4]}]}"#;
        let resp = handle_line(&c, line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("id").and_then(Json::as_f64), Some(7.0));
        let outs = resp.get("outputs").unwrap().as_arr().unwrap();
        let t = tensor_from_json(&outs[0]).unwrap();
        assert_eq!(t.data(), &[10.0]);
    }

    #[test]
    fn stats_command() {
        let c = coordinator();
        let resp = handle_line(&c, r#"{"id": 1, "cmd": "stats"}"#);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert!(resp.get("report").is_some());
    }

    #[test]
    fn malformed_json_is_error_response() {
        let c = coordinator();
        let resp = handle_line(&c, "{nope");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(resp.get("error").is_some());
    }

    #[test]
    fn expired_deadline_over_protocol_is_shed() {
        let c = coordinator();
        let line = r#"{"id": 3, "op": "summation", "deadline_ms": 0,
                       "inputs": [{"shape": [4], "data": [1, 2, 3, 4]}]}"#;
        let resp = handle_line(&c, line);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let err = resp.get("error").and_then(Json::as_str).unwrap();
        assert!(err.contains("shed"), "got: {err}");
        let bad = handle_line(
            &c,
            r#"{"id": 4, "op": "summation", "deadline_ms": -5,
                "inputs": [{"shape": [4], "data": [1, 2, 3, 4]}]}"#,
        );
        assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn unknown_op_is_error_response() {
        let c = coordinator();
        let resp = handle_line(
            &c,
            r#"{"id": 2, "op": "zap", "inputs": []}"#,
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let c = Arc::new(coordinator());
        let stop = Arc::new(AtomicBool::new(false));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = {
            let c = Arc::clone(&c);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || serve_listener(c, listener, stop))
        };
        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(30)))
            .unwrap();
        stream
            .write_all(
                br#"{"id": 1, "op": "ewadd", "inputs": [{"shape": [1, 2], "data": [1, 2]}, {"shape": [1, 2], "data": [10, 20]}]}"#,
            )
            .unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = json::parse(&line).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let outs = resp.get("outputs").unwrap().as_arr().unwrap();
        let t = tensor_from_json(&outs[0]).unwrap();
        assert_eq!(t.data(), &[11.0, 22.0]);
        // close BOTH handles (reader holds a clone) so the server's
        // connection thread sees EOF and join() can complete
        drop(reader);
        drop(stream);
        stop.store(true, Ordering::Release);
        server.join().unwrap().unwrap();
    }
}
