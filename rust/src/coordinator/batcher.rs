//! Dynamic batcher: coalesces same-signature single-signal requests into
//! one padded batch execution (the TINA analog of vLLM-style request
//! batching), and carries each request's *completion context* with it so
//! replies are finished directly from the batch execution thread.
//!
//! Two kinds of traffic ride it, distinguished by [`BatchKey`]:
//!
//! * **Artifact batches** — HLO artifacts have a *fixed* leading batch
//!   dimension, so the batcher fills as many rows as arrive within the
//!   window and zero-pads the rest up to the artifact batch.
//! * **Fallback batches (shape-bucketed)** — the planned executor can
//!   compile a plan for *any* batch size, so fallback requests are grouped
//!   per `(op, per-item signal length)` and a formed batch pads up to the
//!   next power-of-two bucket `B ∈ {1, 2, 4, 8, ...}` (capped at
//!   [`BatcherConfig::max_bucket`]).  Bucketing keeps the number of
//!   compiled plans per (op, shape) bounded — the LeFlow-style fixed-shape
//!   compilation constraint — while amortizing plan lookup and kernel
//!   launch across co-arriving requests.
//!
//! # Completion-driven replies (no parked workers)
//!
//! Each queued [`Pending`] row owns a [`Completion`]: the request's
//! response slot plus the op label, `served_by` marker, and the submit
//! timestamp `t0`.  When the batch executes, the per-batch execution
//! thread assembles every row's [`OpResponse`] and completes its slot
//! *directly* ([`scatter_results`] / [`scatter_row_results`]) — no
//! thread-pool worker is parked on a relay `wait()` per request, so the
//! number of in-flight batched requests is no longer capped by the pool
//! size.  Admission is bounded instead by an [`InflightGate`]
//! (backpressure at enqueue): every batched request holds an
//! [`InflightPermit`] from submit until its reply completes.
//!
//! Latency accounting invariant: `t0` is captured at submit and travels
//! through `Pending`, so the recorded latency covers the full
//! queue-wait + execution + scatter span, exactly like the direct paths.
//! A `Completion` dropped without being completed (a died batch thread)
//! fails its request instead of leaving the caller blocked forever, and
//! the coordinator's shutdown path fails still-queued rows explicitly
//! via [`Batcher::fail_pending`].
//!
//! # Adaptive bucket sizing (clipper-style)
//!
//! Per fallback key the batcher keeps an EWMA of the observed arrival
//! rate (updated from inter-arrival gaps at enqueue) and derives an
//! *effective* bucket cap and flush deadline from it, with the static
//! [`BatcherConfig`] values as ceilings:
//!
//! * effective bucket = largest power of two the EWMA predicts will fill
//!   within `max_wait` (so sparse traffic stops paying for padding it
//!   will never use);
//! * effective wait = predicted fill time of that bucket, 2x slack,
//!   capped at `max_wait` (so dense traffic is not held for a deadline
//!   it beats anyway, and a predicted-lonely request flushes at once).
//!
//! Keys with no rate estimate yet (first arrival) see exactly the static
//! configuration, so cold-start behavior is the pre-adaptive behavior.
//!
//! Padding/masking rule: padding rows are zero-filled at batch formation
//! and are *masked out* at scatter time — per-request outputs are gathered
//! row by row from the plan's terminal views, and rows beyond the real
//! request count are never gathered, so padding can never leak into a
//! reply.  Requests with different per-item shapes land in different
//! buckets by construction (the shape is part of the key); the rejection
//! path survives only for artifact keys, whose row length is fixed by the
//! artifact ABI.

use super::metrics::Metrics;
use super::request::{OpKind, OpResponse};
use crate::tensor::Tensor;
use crate::util::threadpool::OneShot;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// EWMA weight of the newest inter-arrival sample (0 < alpha <= 1).
const EWMA_ALPHA: f64 = 0.2;
/// Floor on an observed inter-arrival gap: two enqueues inside the same
/// microsecond still yield a finite rate sample.
const MIN_ARRIVAL_GAP: Duration = Duration::from_micros(1);
/// Bound on tracked per-key rate estimates (shape-diverse traffic must
/// not grow the map without limit; the stalest key is dropped).
const RATE_KEYS_CAP: usize = 512;

/// Key grouping poolable requests.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum BatchKey {
    /// Fixed-shape PJRT artifact: same artifact -> same ABI; the formed
    /// batch always pads to the artifact's leading batch dim.
    Artifact {
        /// Artifact name (registry key).
        name: String,
        /// Rows the artifact expects (its leading batch dim).
        batch: usize,
    },
    /// Shape-bucketed fallback traffic: compatible requests grouped per
    /// (op, per-item signal length); the formed batch pads to the next
    /// power-of-two bucket (capped at [`BatcherConfig::max_bucket`]).
    Fallback {
        /// The op the bucketed requests share.
        op: OpKind,
        /// Per-item signal length L shared by every row in the bucket.
        len: usize,
    },
}

impl BatchKey {
    /// Leading dim of the formed batch holding `rows` real rows.
    fn pad_rows(&self, rows: usize, config: &BatcherConfig) -> usize {
        match self {
            BatchKey::Artifact { batch, .. } => *batch,
            BatchKey::Fallback { .. } => rows
                .next_power_of_two()
                .min(config.max_bucket.max(1))
                .max(rows),
        }
    }

    /// Expected per-row element count, when the key itself fixes it.
    fn expected_len(&self) -> Option<usize> {
        match self {
            BatchKey::Artifact { .. } => None,
            BatchKey::Fallback { len, .. } => Some(*len),
        }
    }
}

/// Bounded admission gate for batched requests: `acquire` blocks while
/// the configured limit of in-flight batched requests is reached — the
/// coordinator's backpressure-at-enqueue replacement for the implicit
/// (and much lower) cap the old parked-worker relay imposed.
///
/// The [`Metrics::inflight_batched_requests`] gauge mirrors the count.
pub struct InflightGate {
    limit: usize,
    count: Mutex<usize>,
    freed: Condvar,
    metrics: Arc<Metrics>,
}

impl InflightGate {
    /// Build a gate admitting at most `limit` in-flight batched requests
    /// (a zero limit is clamped to 1 — the gate must admit progress).
    pub fn new(limit: usize, metrics: Arc<Metrics>) -> Arc<InflightGate> {
        Arc::new(InflightGate {
            limit: limit.max(1),
            count: Mutex::new(0),
            freed: Condvar::new(),
            metrics,
        })
    }

    /// Take one in-flight slot, blocking until one frees (backpressure).
    pub fn acquire(self: &Arc<Self>) -> InflightPermit {
        let mut n = self.count.lock().unwrap();
        while *n >= self.limit {
            n = self.freed.wait(n).unwrap();
        }
        *n += 1;
        self.metrics.inc_inflight_batched();
        InflightPermit {
            gate: Arc::clone(self),
        }
    }

    /// Take one in-flight slot, waiting at most `timeout`.  Returns `None`
    /// when the gate stays saturated past the deadline — the coordinator's
    /// deadline-aware admission turns that into a fast "overloaded,
    /// retry-after" failure instead of blocking the caller indefinitely.
    /// The fault site `gate.acquire` can force the saturated outcome.
    pub fn acquire_timeout(self: &Arc<Self>, timeout: Duration) -> Option<InflightPermit> {
        if crate::testing::faults::refused("gate.acquire") {
            return None;
        }
        let deadline = Instant::now() + timeout;
        let mut n = self.count.lock().unwrap();
        while *n >= self.limit {
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            n = self.freed.wait_timeout(n, deadline - now).unwrap().0;
        }
        *n += 1;
        self.metrics.inc_inflight_batched();
        Some(InflightPermit {
            gate: Arc::clone(self),
        })
    }

    /// Batched requests currently holding a slot.
    pub fn in_flight(&self) -> usize {
        *self.count.lock().unwrap()
    }
}

/// One admitted in-flight batched request; dropping it (on completion,
/// on any path) frees the slot and wakes a blocked submitter.
pub struct InflightPermit {
    gate: Arc<InflightGate>,
}

impl Drop for InflightPermit {
    fn drop(&mut self) {
        let mut n = self.gate.count.lock().unwrap();
        *n = n.saturating_sub(1);
        self.gate.metrics.dec_inflight_batched();
        drop(n);
        // notify_all: several submitters may be blocked and another
        // permit may race the count; waking everyone keeps the gate
        // obviously live at the cost of a rare spurious re-check
        self.gate.freed.notify_all();
    }
}

/// A request's completion context: everything needed to finish its
/// response from whichever thread produces the outputs.  This is the
/// single [`OpResponse`] assembly point for the whole coordinator — the
/// direct worker paths and the drain-side scatter both end here.
pub struct Completion {
    /// The caller's response slot (`None` once completed).
    slot: Option<OneShot<Result<OpResponse>>>,
    op: &'static str,
    served_by: String,
    t0: Instant,
    /// In-flight admission slot for batched requests; released (dropped)
    /// *before* the response slot is set so the gauge never overshoots
    /// past a completed reply.
    permit: Option<InflightPermit>,
    /// The request's optional client deadline: the drain loop sheds rows
    /// whose deadline already passed instead of paying for execution.
    deadline: Option<Instant>,
    metrics: Arc<Metrics>,
}

impl Completion {
    /// Build a completion context.  `t0` is the submit timestamp the
    /// latency histogram measures from; `permit` is `Some` exactly for
    /// requests admitted through the [`InflightGate`] (batched paths);
    /// `deadline` is the request's optional client deadline.
    pub fn new(
        metrics: Arc<Metrics>,
        slot: OneShot<Result<OpResponse>>,
        op: &'static str,
        served_by: String,
        t0: Instant,
        permit: Option<InflightPermit>,
        deadline: Option<Instant>,
    ) -> Completion {
        Completion {
            slot: Some(slot),
            op,
            served_by,
            t0,
            permit,
            deadline,
            metrics,
        }
    }

    /// Whether the request's optional deadline has already passed (rows
    /// answering `true` are shed before execution; no deadline → `false`).
    pub fn deadline_expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// Complete from a direct (worker) execution path: the response is
    /// never marked batched — batched responses only come from
    /// [`Completion::complete_from_drain`], keeping the
    /// drain-completions accounting honest.
    pub fn complete(self, result: Result<Vec<Tensor>>) {
        self.finish(result, false, false);
    }

    /// Complete from a drain-side per-batch execution thread; counted in
    /// [`Metrics::drain_completions`].
    pub fn complete_from_drain(self, result: Result<Vec<Tensor>>) {
        self.finish(result, true, true);
    }

    /// Fail the request (routing/validation/enqueue errors).
    pub fn fail(self, err: anyhow::Error) {
        self.finish(Err(err), false, false);
    }

    fn finish(mut self, result: Result<Vec<Tensor>>, batched: bool, from_drain: bool) {
        let served_by = std::mem::take(&mut self.served_by);
        let result = result.map(|outputs| OpResponse {
            outputs,
            served_by,
            batched,
        });
        // release the in-flight slot and record metrics before waking the
        // waiter: a caller returning from wait() must observe a settled
        // gauge and its own completion already counted
        drop(self.permit.take());
        self.metrics
            .record_completion(self.op, self.t0.elapsed(), result.is_ok());
        if from_drain {
            self.metrics.record_drain_completion();
        }
        if let Some(slot) = self.slot.take() {
            slot.set(result);
        }
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        // a completion dropped without completing (batch thread died,
        // shutdown with rows queued) must fail its request, not strand
        // the caller on wait() forever
        if let Some(slot) = self.slot.take() {
            drop(self.permit.take());
            self.metrics
                .record_completion(self.op, self.t0.elapsed(), false);
            slot.set(Err(anyhow::anyhow!(
                "request dropped before completion (batch execution died or shut down)"
            )));
        }
    }
}

/// One queued request row.
pub struct Pending {
    /// The (1, L) signal row.
    pub input: Tensor,
    /// Completion context: finishes this request's response directly from
    /// the batch execution thread.
    pub completion: Completion,
    /// When the row entered the queue (drives the flush deadline).
    pub enqueued: Instant,
}

/// The adaptive sizing decision a fallback batch was formed under
/// (surfaced through the `adaptive_bucket_*` metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketDecision {
    /// Effective bucket cap applied (<= the static `max_bucket` ceiling).
    pub cap: usize,
    /// Effective flush deadline applied (<= the static `max_wait`).
    pub wait: Duration,
}

/// A formed batch ready for execution.
pub struct FormedBatch {
    /// The key whose queue produced this batch.
    pub key: BatchKey,
    /// Stacked (batch, L) input, zero-padded to the artifact batch
    /// (artifact keys) or to the next power-of-two bucket (fallback keys).
    pub input: Tensor,
    /// How many leading rows are real requests.
    pub rows: Vec<Pending>,
    /// The adaptive sizing in force when the batch formed (fallback keys
    /// only; artifact capacities are fixed by the ABI).
    pub adaptive: Option<BucketDecision>,
}

/// Batching configuration.  With adaptive sizing these are *ceilings*:
/// per-key effective values derived from observed arrival rates never
/// exceed them.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Max time a request may wait for co-riders before the batch flushes.
    pub max_wait: Duration,
    /// Largest fallback bucket: shape-bucketed batches flush as soon as
    /// this many rows are queued, and never pad beyond it.  Buckets are
    /// the powers of two up to this cap; [`Batcher::new`] rounds a
    /// non-power-of-two value *down* so the compiled-plan fan-out stays
    /// exactly {1, 2, 4, ...}.
    pub max_bucket: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            max_bucket: 8,
        }
    }
}

/// Per-key arrival-rate estimate (rows/sec EWMA over inter-arrival gaps).
#[derive(Debug, Clone, Copy)]
struct RateEwma {
    /// Smoothed rows/sec; 0.0 until a second arrival gives a first gap.
    rate: f64,
    /// Previous arrival (feeds the next gap sample).
    last: Instant,
}

/// Queues + rate estimates, guarded by one mutex (the rates feed the
/// flush policy, so they must be consistent with the queue scan).
struct State {
    queues: HashMap<BatchKey, Vec<Pending>>,
    rates: HashMap<BatchKey, RateEwma>,
    /// Set by [`Batcher::fail_pending`] (shutdown): later enqueues fail
    /// fast instead of parking rows no drain loop will ever visit.
    closed: bool,
}

struct Shared {
    state: Mutex<State>,
    ready: Condvar,
}

/// The batcher: producers enqueue rows; a drain loop (run by the service)
/// pops full or expired batches.
pub struct Batcher {
    shared: Arc<Shared>,
    config: BatcherConfig,
}

/// Largest power of two `<= n` (n >= 1).
fn floor_pow2(n: usize) -> usize {
    1usize << (usize::BITS - 1 - n.max(1).leading_zeros())
}

/// Effective bucket cap for an arrival-rate estimate: the largest power
/// of two the EWMA predicts will fill within the static `max_wait`,
/// ceiling-clamped to `config.max_bucket`.  No estimate -> the ceiling
/// (cold keys behave exactly as the static configuration).
fn effective_bucket(config: &BatcherConfig, rate: f64) -> usize {
    let ceiling = config.max_bucket.max(1);
    if rate <= 0.0 {
        return ceiling;
    }
    let expected = (1.0 + rate * config.max_wait.as_secs_f64()).clamp(1.0, ceiling as f64);
    floor_pow2(expected as usize)
}

/// Effective flush deadline for an arrival-rate estimate: twice the
/// predicted time to fill the effective bucket, capped at the static
/// `max_wait`.  A key predicted to stay lonely (effective bucket 1)
/// flushes immediately; a cold key waits the full static deadline.
fn effective_wait(config: &BatcherConfig, rate: f64) -> Duration {
    if rate <= 0.0 {
        return config.max_wait;
    }
    let bucket = effective_bucket(config, rate);
    if bucket <= 1 {
        return Duration::ZERO;
    }
    let predicted = 2.0 * (bucket - 1) as f64 / rate;
    config.max_wait.min(Duration::from_secs_f64(predicted))
}

impl Batcher {
    /// Build a batcher; normalizes `max_bucket` down to a power of two.
    pub fn new(mut config: BatcherConfig) -> Batcher {
        // normalize: buckets are powers of two, so a non-power-of-two cap
        // rounds down (6 -> 4) instead of silently minting bucket sizes
        // the plan-cache sizing advice doesn't account for
        config.max_bucket = floor_pow2(config.max_bucket);
        Batcher {
            shared: Arc::new(Shared {
                state: Mutex::new(State {
                    queues: HashMap::new(),
                    rates: HashMap::new(),
                    closed: false,
                }),
                ready: Condvar::new(),
            }),
            config,
        }
    }

    /// The (normalized) static configuration ceilings.
    pub fn config(&self) -> BatcherConfig {
        self.config
    }

    /// Enqueue one row; returns immediately.  The request's response slot
    /// completes when the batch it rides executes (or fails fast here).
    ///
    /// Rows sharing a [`BatchKey`] must agree on signal length — the formed
    /// batch is one dense (batch, L) stack.  Fallback keys carry the length
    /// in the key, so differently-shaped requests route to different
    /// buckets by construction; for artifact keys a mismatched row is
    /// rejected here by failing its completion, instead of poisoning the
    /// drain loop with a panic when the batch is stacked.
    pub fn enqueue(&self, key: BatchKey, input: Tensor, completion: Completion) {
        let mut st = self.shared.state.lock().unwrap();
        // a closed batcher (shutdown ran) has no drain loop left: fail
        // fast under the same lock `fail_pending` closed under, so a
        // racing enqueue can never strand a row in a dead queue
        if st.closed {
            drop(st);
            completion.fail(anyhow::anyhow!(
                "batcher is shut down; request cannot be batched"
            ));
            return;
        }
        // validate BEFORE creating the queue entry or touching the rate
        // estimate: a rejected row must not leave an empty Vec behind in
        // the map, and must not skew the arrival-rate EWMA
        let expect = key.expected_len().or_else(|| {
            st.queues
                .get(&key)
                .and_then(|rows| rows.first())
                .map(|p| p.input.len())
        });
        if let Some(expect) = expect {
            if expect != input.len() {
                let msg = format!(
                    "batch row length {} != expected row length {expect} for key {key:?}",
                    input.len()
                );
                drop(st);
                completion.fail(anyhow::anyhow!(msg));
                return;
            }
        }
        let now = Instant::now();
        Self::observe_arrival(&mut st.rates, &key, now);
        st.queues.entry(key).or_default().push(Pending {
            input,
            completion,
            enqueued: now,
        });
        drop(st);
        self.shared.ready.notify_one();
    }

    /// Fold one arrival into the key's rate EWMA (fallback keys only —
    /// artifact capacities are fixed by the ABI, so there is nothing to
    /// adapt).  The rates map is bounded: past [`RATE_KEYS_CAP`] the
    /// stalest key (oldest last arrival) is dropped.
    fn observe_arrival(rates: &mut HashMap<BatchKey, RateEwma>, key: &BatchKey, now: Instant) {
        if !matches!(key, BatchKey::Fallback { .. }) {
            return;
        }
        if let Some(e) = rates.get_mut(key) {
            let gap = now.duration_since(e.last).max(MIN_ARRIVAL_GAP);
            let inst = 1.0 / gap.as_secs_f64();
            e.rate = if e.rate <= 0.0 {
                inst
            } else {
                EWMA_ALPHA * inst + (1.0 - EWMA_ALPHA) * e.rate
            };
            e.last = now;
            return;
        }
        if rates.len() >= RATE_KEYS_CAP {
            if let Some(stalest) = rates
                .iter()
                .min_by_key(|(_, e)| e.last)
                .map(|(k, _)| k.clone())
            {
                rates.remove(&stalest);
            }
        }
        rates.insert(key.clone(), RateEwma { rate: 0.0, last: now });
    }

    /// The rate estimate for a key (0.0 when none) — policy inputs for
    /// `next_batch`'s scan.
    fn rate_of(rates: &HashMap<BatchKey, RateEwma>, key: &BatchKey) -> f64 {
        rates.get(key).map(|e| e.rate).unwrap_or(0.0)
    }

    /// Row count at which a key's batch is full and flushes immediately.
    fn capacity_of(&self, key: &BatchKey, rates: &HashMap<BatchKey, RateEwma>) -> usize {
        match key {
            BatchKey::Artifact { batch, .. } => *batch,
            BatchKey::Fallback { .. } => effective_bucket(&self.config, Self::rate_of(rates, key)),
        }
    }

    /// Flush deadline for a key's oldest row.
    fn wait_of(&self, key: &BatchKey, rates: &HashMap<BatchKey, RateEwma>) -> Duration {
        match key {
            BatchKey::Artifact { .. } => self.config.max_wait,
            BatchKey::Fallback { .. } => effective_wait(&self.config, Self::rate_of(rates, key)),
        }
    }

    /// The adaptive decision to stamp on a formed fallback batch.
    fn decision_of(
        &self,
        key: &BatchKey,
        rates: &HashMap<BatchKey, RateEwma>,
    ) -> Option<BucketDecision> {
        match key {
            BatchKey::Artifact { .. } => None,
            BatchKey::Fallback { .. } => Some(BucketDecision {
                cap: self.capacity_of(key, rates),
                wait: self.wait_of(key, rates),
            }),
        }
    }

    /// Block until a batch is full or the oldest row exceeds its flush
    /// deadline; returns None once `deadline` passes without producing a
    /// batch (pending-but-unexpired rows stay queued for the next call).
    ///
    /// Invariant: every loop iteration either returns, or blocks on the
    /// condvar until the earliest of (oldest-row expiry, deadline) — there
    /// is no busy-spin path.
    pub fn next_batch(&self, idle_timeout: Duration) -> Option<FormedBatch> {
        let deadline = Instant::now() + idle_timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            // full batch available?  (capacity is the per-key effective
            // bucket for fallback keys, the ABI batch for artifact keys)
            let full = st
                .queues
                .iter()
                .find(|(k, v)| v.len() >= self.capacity_of(k, &st.rates))
                .map(|(k, _)| k.clone());
            if let Some(key) = full {
                let cap = self.capacity_of(&key, &st.rates);
                let decision = self.decision_of(&key, &st.rates);
                let rows = st.queues.get_mut(&key).expect("key came from the scan above");
                let take: Vec<Pending> = rows.drain(..cap).collect();
                if rows.is_empty() {
                    st.queues.remove(&key);
                }
                return Some(self.form(key, take, decision));
            }
            // expired batch?  (`now` is shared with the wake computation
            // below so a due expiry is always taken on this iteration, not
            // re-spun on)
            let now = Instant::now();
            let expired = st
                .queues
                .iter()
                .filter(|(_, v)| !v.is_empty())
                .find(|(k, v)| now.duration_since(v[0].enqueued) >= self.wait_of(k, &st.rates))
                .map(|(k, _)| k.clone());
            if let Some(key) = expired {
                let decision = self.decision_of(&key, &st.rates);
                let rows = st.queues.remove(&key).expect("key came from the scan above");
                return Some(self.form(key, rows, decision));
            }
            if now >= deadline {
                return None;
            }
            // wait for the earliest wakeup: a new enqueue (condvar), the
            // oldest entry's expiry under its key's effective deadline, or
            // the idle deadline
            let oldest_expiry = st
                .queues
                .iter()
                .filter_map(|(k, v)| v.first().map(|p| p.enqueued + self.wait_of(k, &st.rates)))
                .min();
            let wake = match oldest_expiry {
                Some(e) => e.min(deadline),
                None => deadline,
            };
            if wake <= now {
                // an expiry became due in this very iteration; re-scan
                continue;
            }
            let (guard, _timeout) = self.shared.ready.wait_timeout(st, wake - now).unwrap();
            st = guard;
        }
    }

    /// Fail every queued row and close the batcher (shutdown path): each
    /// pending request's completion settles with an error instead of
    /// waiting for a drain loop that will never run again, and every
    /// *later* enqueue fails fast too.  Returns how many rows were
    /// failed.  Completions run outside the queue lock.
    pub fn fail_pending(&self, reason: &str) -> usize {
        let drained: Vec<Pending> = {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
            st.queues.drain().flat_map(|(_, rows)| rows).collect()
        };
        let n = drained.len();
        for row in drained {
            row.completion.fail(anyhow::anyhow!(reason.to_string()));
        }
        n
    }

    /// Rows currently queued across all keys (for tests/metrics).
    pub fn queued(&self) -> usize {
        self.shared
            .state
            .lock()
            .unwrap()
            .queues
            .values()
            .map(Vec::len)
            .sum()
    }

    fn form(
        &self,
        key: BatchKey,
        rows: Vec<Pending>,
        adaptive: Option<BucketDecision>,
    ) -> FormedBatch {
        let pad = key.pad_rows(rows.len(), &self.config);
        debug_assert!(!rows.is_empty() && rows.len() <= pad);
        let l = rows[0].input.len();
        let mut data = vec![0.0f32; pad * l];
        for (i, p) in rows.iter().enumerate() {
            data[i * l..(i + 1) * l].copy_from_slice(p.input.data());
        }
        FormedBatch {
            input: Tensor::new(&[pad, l], data).expect("batch stack"),
            key,
            rows,
            adaptive,
        }
    }
}

/// Complete a batched multi-output execution directly from the batch
/// execution thread: row i of every output tensor becomes rows[i]'s
/// response.  Padding rows are discarded (masked out) here.
pub fn scatter_results(batch: FormedBatch, result: Result<Vec<Tensor>>) {
    scatter_indexed_results(batch.rows.into_iter().enumerate().collect(), result);
}

/// [`scatter_results`] over *indexed* rows: each `(i, row)` pair names the
/// row's position in the stacked batch input, so callers that shed rows
/// (expired deadlines) can still scatter the survivors from the right
/// batch slots.  Indices must be ascending.
pub fn scatter_indexed_results(rows: Vec<(usize, Pending)>, result: Result<Vec<Tensor>>) {
    match result {
        Ok(outputs) => {
            for (i, row) in rows {
                let per_row: Result<Vec<Tensor>> = outputs
                    .iter()
                    .map(|o| o.slice_axis(0, i, i + 1))
                    .collect();
                row.completion.complete_from_drain(per_row);
            }
        }
        Err(e) => {
            let msg = format!("batched execution failed: {e}");
            for (_, row) in rows {
                row.completion
                    .complete_from_drain(Err(anyhow::anyhow!(msg.clone())));
            }
        }
    }
}

/// Complete a fallback batch whose outputs were already scattered per row
/// by the planned executor ([`crate::tina::Planned::run_rows`]): entry i
/// holds request i's outputs, padding rows were never gathered at all.
pub fn scatter_row_results(batch: FormedBatch, result: Result<Vec<Vec<Tensor>>>) {
    scatter_indexed_row_results(batch.rows.into_iter().enumerate().collect(), result);
}

/// [`scatter_row_results`] over *indexed* rows: `per_row[i]` answers the
/// pair `(i, row)`, where `i` is the row's position in the stacked batch
/// input.  The executor must have gathered exactly `max index + 1` rows
/// (shed or padding positions below that are gathered and ignored);
/// indices must be ascending.
pub fn scatter_indexed_row_results(rows: Vec<(usize, Pending)>, result: Result<Vec<Vec<Tensor>>>) {
    let need = rows.last().map(|(i, _)| i + 1).unwrap_or(0);
    match result {
        Ok(mut per_row) if per_row.len() == need => {
            // walk back-to-front so each take is an O(1) pop of the tail
            for (i, row) in rows.into_iter().rev() {
                per_row.truncate(i + 1);
                let outs = per_row.pop().expect("per_row.len() == max index + 1");
                row.completion.complete_from_drain(Ok(outs));
            }
        }
        Ok(per_row) => {
            let msg = format!(
                "batched fallback returned {} row results, expected {need}",
                per_row.len(),
            );
            for (_, row) in rows {
                row.completion
                    .complete_from_drain(Err(anyhow::anyhow!(msg.clone())));
            }
        }
        Err(e) => {
            let msg = format!("batched fallback execution failed: {e}");
            for (_, row) in rows {
                row.completion
                    .complete_from_drain(Err(anyhow::anyhow!(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn key(b: usize) -> BatchKey {
        BatchKey::Artifact {
            name: "fir_tina_f32_B8_L16".into(),
            batch: b,
        }
    }

    fn fkey(len: usize) -> BatchKey {
        BatchKey::Fallback {
            op: OpKind::Fir,
            len,
        }
    }

    /// A response slot + completion pair for direct batcher tests.
    fn completion(metrics: &Arc<Metrics>) -> (OneShot<Result<OpResponse>>, Completion) {
        let slot: OneShot<Result<OpResponse>> = OneShot::new();
        let c = Completion::new(
            Arc::clone(metrics),
            slot.clone(),
            "fir",
            "test".into(),
            Instant::now(),
            None,
            None,
        );
        (slot, c)
    }

    fn throwaway(metrics: &Arc<Metrics>) -> Completion {
        completion(metrics).1
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        for i in 0..4 {
            b.enqueue(key(4), Tensor::filled(&[1, 16], i as f32), throwaway(&m));
        }
        let batch = b.next_batch(Duration::from_millis(50)).expect("batch");
        assert_eq!(batch.rows.len(), 4);
        assert_eq!(batch.input.shape(), &[4, 16]);
        assert!(batch.adaptive.is_none(), "artifact batches are not adaptive");
        // rows stacked in arrival order
        assert_eq!(batch.input.at(&[2, 0]), 2.0);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn partial_batch_flushes_after_max_wait_with_padding() {
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(5),
            ..Default::default()
        });
        b.enqueue(key(4), Tensor::filled(&[1, 16], 7.0), throwaway(&m));
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
        assert!(t0.elapsed() >= Duration::from_millis(4), "flushed too early");
        assert_eq!(batch.rows.len(), 1);
        assert_eq!(batch.input.shape(), &[4, 16]); // padded
        assert_eq!(batch.input.at(&[0, 0]), 7.0);
        assert_eq!(batch.input.at(&[3, 0]), 0.0); // zero padding
    }

    #[test]
    fn idle_timeout_returns_none() {
        let b = Batcher::new(BatcherConfig::default());
        let t0 = Instant::now();
        assert!(b.next_batch(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn mismatched_row_length_rejected_at_enqueue() {
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        let (ok, c) = completion(&m);
        b.enqueue(key(4), Tensor::filled(&[1, 16], 1.0), c);
        // same key, different signal length: must fail fast, not poison form()
        let (bad, c) = completion(&m);
        b.enqueue(key(4), Tensor::filled(&[1, 32], 2.0), c);
        let err = bad.try_take().expect("reply must complete immediately");
        assert!(err.is_err(), "mismatched row must error");
        assert_eq!(b.queued(), 1, "bad row must not be queued");
        assert_eq!(m.failed.load(Ordering::Relaxed), 1, "rejection is a failed completion");
        // the well-formed row still flushes normally
        b.enqueue(key(4), Tensor::filled(&[1, 16], 3.0), throwaway(&m));
        assert_eq!(b.queued(), 2);
        assert!(ok.try_take().is_none(), "good row unaffected");
    }

    #[test]
    fn deadline_with_pending_unexpired_rows_returns_none_without_spinning() {
        // rows pending but far from expiry: next_batch must give up at the
        // idle deadline (previously this path busy-spun until expiry)
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(60),
            ..Default::default()
        });
        b.enqueue(key(4), Tensor::filled(&[1, 8], 1.0), throwaway(&m));
        let t0 = Instant::now();
        assert!(b.next_batch(Duration::from_millis(30)).is_none());
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(29), "returned early: {dt:?}");
        assert!(dt < Duration::from_secs(5), "blocked way past deadline: {dt:?}");
        assert_eq!(b.queued(), 1, "row must stay queued for the next call");
    }

    #[test]
    fn distinct_keys_do_not_mix() {
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        b.enqueue(key(2), Tensor::filled(&[1, 16], 1.0), throwaway(&m));
        let other = BatchKey::Artifact {
            name: "other".into(),
            batch: 2,
        };
        b.enqueue(other, Tensor::filled(&[1, 16], 2.0), throwaway(&m));
        let b1 = b.next_batch(Duration::from_millis(100)).unwrap();
        let b2 = b.next_batch(Duration::from_millis(100)).unwrap();
        assert_eq!(b1.rows.len(), 1);
        assert_eq!(b2.rows.len(), 1);
        assert_ne!(b1.key, b2.key);
    }

    #[test]
    fn fallback_full_bucket_flushes_immediately() {
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(10),
            max_bucket: 8,
        });
        for i in 0..8 {
            b.enqueue(fkey(16), Tensor::filled(&[1, 16], i as f32), throwaway(&m));
        }
        let batch = b.next_batch(Duration::from_millis(50)).expect("batch");
        assert_eq!(batch.rows.len(), 8);
        assert_eq!(batch.input.shape(), &[8, 16], "full bucket, no padding");
        assert_eq!(batch.input.at(&[5, 0]), 5.0);
        let d = batch.adaptive.expect("fallback batches carry the decision");
        assert_eq!(d.cap, 8, "tight-loop arrivals keep the static ceiling");
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn fallback_bucket_rounds_up_to_next_power_of_two() {
        // 3 rows expire -> bucket 4 with one zero padding row
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(2),
            max_bucket: 8,
        });
        for i in 0..3 {
            b.enqueue(fkey(16), Tensor::filled(&[1, 16], (i + 1) as f32), throwaway(&m));
        }
        let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
        assert_eq!(batch.rows.len(), 3);
        assert_eq!(batch.input.shape(), &[4, 16], "3 rows pad to bucket 4");
        assert_eq!(batch.input.at(&[2, 0]), 3.0);
        assert_eq!(batch.input.at(&[3, 0]), 0.0, "padding row must be zero");
    }

    #[test]
    fn fallback_bucket_boundary_sizes_pad_exactly() {
        // bucket-boundary row counts (1, 2, 4) need no padding at all
        let m = Arc::new(Metrics::new());
        for rows in [1usize, 2, 4] {
            let b = Batcher::new(BatcherConfig {
                max_wait: Duration::from_millis(1),
                max_bucket: 8,
            });
            for i in 0..rows {
                b.enqueue(fkey(8), Tensor::filled(&[1, 8], (i + 1) as f32), throwaway(&m));
            }
            let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
            assert_eq!(batch.rows.len(), rows);
            assert_eq!(
                batch.input.shape(),
                &[rows, 8],
                "boundary size {rows} must not pad"
            );
        }
    }

    #[test]
    fn fallback_deadline_expiry_flushes_partial_bucket() {
        // a lone row far below the bucket cap still flushes at max_wait:
        // the degenerate B=1 case of the bucketed path (a cold key has no
        // rate estimate, so the static deadline is in force)
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(5),
            max_bucket: 8,
        });
        b.enqueue(fkey(16), Tensor::filled(&[1, 16], 9.0), throwaway(&m));
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
        assert!(t0.elapsed() >= Duration::from_millis(4), "flushed too early");
        assert_eq!(batch.rows.len(), 1);
        assert_eq!(batch.input.shape(), &[1, 16], "single row -> bucket 1");
    }

    #[test]
    fn fallback_wrong_length_rejected_without_leaking_entry() {
        // fallback keys carry the expected length, so even the FIRST row
        // is validated — and the reject path must not leave an empty
        // queue entry behind
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig::default());
        let (bad, c) = completion(&m);
        b.enqueue(fkey(16), Tensor::filled(&[1, 8], 1.0), c);
        assert!(bad.try_take().expect("immediate reply").is_err());
        assert_eq!(b.queued(), 0, "rejected row must not be queued");
        assert!(
            b.next_batch(Duration::from_millis(5)).is_none(),
            "no phantom batch from a rejected row"
        );
    }

    #[test]
    fn non_power_of_two_max_bucket_rounds_down() {
        // max_bucket 6 normalizes to 4: full flush at 4 rows, remainder
        // pads to its own power-of-two bucket
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(1),
            max_bucket: 6,
        });
        assert_eq!(b.config().max_bucket, 4);
        for i in 0..6 {
            b.enqueue(fkey(8), Tensor::filled(&[1, 8], (i + 1) as f32), throwaway(&m));
        }
        let first = b.next_batch(Duration::from_secs(1)).expect("full bucket");
        assert_eq!(first.rows.len(), 4);
        assert_eq!(first.input.shape(), &[4, 8]);
        let rest = b.next_batch(Duration::from_secs(1)).expect("remainder");
        assert_eq!(rest.rows.len(), 2);
        assert_eq!(rest.input.shape(), &[2, 8]);
    }

    #[test]
    fn mixed_length_fallback_routes_to_distinct_buckets() {
        // what PR 1 rejected as an error for artifact keys is ordinary
        // bucket routing for fallback keys: the shape is part of the key
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(1),
            max_bucket: 8,
        });
        let (r16, c16) = completion(&m);
        let (r32, c32) = completion(&m);
        b.enqueue(fkey(16), Tensor::filled(&[1, 16], 1.0), c16);
        b.enqueue(fkey(32), Tensor::filled(&[1, 32], 2.0), c32);
        assert!(r16.try_take().is_none(), "no rejection for mixed lengths");
        assert!(r32.try_take().is_none(), "no rejection for mixed lengths");
        let b1 = b.next_batch(Duration::from_millis(100)).expect("bucket 1");
        let b2 = b.next_batch(Duration::from_millis(100)).expect("bucket 2");
        let mut lens = [b1.input.shape()[1], b2.input.shape()[1]];
        lens.sort_unstable();
        assert_eq!(lens, [16, 32], "each length gets its own bucket");
    }

    #[test]
    fn adaptive_policy_derives_cap_and_wait_from_rate() {
        let cfg = BatcherConfig {
            max_wait: Duration::from_millis(2),
            max_bucket: 8,
        };
        // no estimate: static ceilings (cold-start == pre-adaptive behavior)
        assert_eq!(effective_bucket(&cfg, 0.0), 8);
        assert_eq!(effective_wait(&cfg, 0.0), cfg.max_wait);
        // very fast traffic: ceiling cap, deadline shrinks to ~2x the
        // predicted fill time of the full bucket
        assert_eq!(effective_bucket(&cfg, 1_000_000.0), 8);
        assert!(effective_wait(&cfg, 1_000_000.0) < Duration::from_micros(50));
        // ~2500 rows/s with a 2ms window: ~6 expected rows -> bucket 4
        assert_eq!(effective_bucket(&cfg, 2_500.0), 4);
        // slow traffic: bucket 1, flush immediately
        assert_eq!(effective_bucket(&cfg, 100.0), 1);
        assert_eq!(effective_wait(&cfg, 100.0), Duration::ZERO);
        // the wait never exceeds the static ceiling
        assert!(effective_wait(&cfg, 2_500.0) <= cfg.max_wait);
    }

    #[test]
    fn adaptive_shrinks_bucket_for_slow_arrivals() {
        // two arrivals ~30ms apart -> rate ~33 rows/s; with a 1ms window
        // the EWMA predicts a lonely key, so the effective bucket drops to
        // 1 and both rows flush as immediate B=1 batches (no padding, no
        // deadline tax) instead of waiting to pad toward 8
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(1),
            max_bucket: 8,
        });
        b.enqueue(fkey(8), Tensor::filled(&[1, 8], 1.0), throwaway(&m));
        std::thread::sleep(Duration::from_millis(30));
        b.enqueue(fkey(8), Tensor::filled(&[1, 8], 2.0), throwaway(&m));
        let first = b.next_batch(Duration::from_secs(1)).expect("first row");
        assert_eq!(first.rows.len(), 1, "shrunk bucket takes one row");
        assert_eq!(first.input.shape(), &[1, 8], "no padding at bucket 1");
        let d = first.adaptive.expect("decision recorded");
        assert_eq!(d.cap, 1, "slow key must shrink below the ceiling");
        let second = b.next_batch(Duration::from_secs(1)).expect("second row");
        assert_eq!(second.rows.len(), 1);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn inflight_gate_blocks_at_limit_and_releases_on_drop() {
        let m = Arc::new(Metrics::new());
        let gate = InflightGate::new(2, Arc::clone(&m));
        let p1 = gate.acquire();
        let p2 = gate.acquire();
        assert_eq!(gate.in_flight(), 2);
        assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 2);
        // a third acquire must block until a permit drops
        let gate2 = Arc::clone(&gate);
        let acquired = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let flag = Arc::clone(&acquired);
        let waiter = std::thread::spawn(move || {
            let _p = gate2.acquire();
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(20));
        assert!(!acquired.load(Ordering::SeqCst), "gate must block at limit");
        drop(p1);
        waiter.join().unwrap();
        assert!(acquired.load(Ordering::SeqCst), "drop must admit the waiter");
        drop(p2);
        assert_eq!(gate.in_flight(), 0);
        assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn acquire_timeout_fails_fast_at_saturation_and_admits_after_release() {
        let m = Arc::new(Metrics::new());
        let gate = InflightGate::new(1, Arc::clone(&m));
        let held = gate.acquire();
        let t0 = Instant::now();
        assert!(
            gate.acquire_timeout(Duration::from_millis(30)).is_none(),
            "saturated gate must time out, not block"
        );
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(29), "returned early: {dt:?}");
        assert!(dt < Duration::from_secs(5), "blocked way past deadline: {dt:?}");
        drop(held);
        let p = gate
            .acquire_timeout(Duration::from_millis(100))
            .expect("freed gate must admit");
        assert_eq!(gate.in_flight(), 1);
        drop(p);
        assert_eq!(m.inflight_batched_requests.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn completion_deadline_expiry_is_observable() {
        let m = Arc::new(Metrics::new());
        let slot: OneShot<Result<OpResponse>> = OneShot::new();
        let c = Completion::new(
            Arc::clone(&m),
            slot.clone(),
            "fir",
            "test".into(),
            Instant::now(),
            None,
            Some(Instant::now() - Duration::from_millis(1)),
        );
        assert!(c.deadline_expired(), "past deadline must read expired");
        let fresh = throwaway(&m);
        assert!(!fresh.deadline_expired(), "no deadline never expires");
        c.fail(anyhow::anyhow!("deadline expired before execution"));
        assert!(slot.try_take().expect("settled").is_err());
    }

    #[test]
    fn indexed_scatter_routes_surviving_rows_to_their_batch_slots() {
        // rows 0 and 2 survive a shed of row 1: each must read its own
        // batch slot, and the executor gathers exactly max index + 1 rows
        let m = Arc::new(Metrics::new());
        let (s0, c0) = completion(&m);
        let (s2, c2) = completion(&m);
        let live = vec![
            (
                0usize,
                Pending {
                    input: Tensor::zeros(&[1, 4]),
                    completion: c0,
                    enqueued: Instant::now(),
                },
            ),
            (
                2usize,
                Pending {
                    input: Tensor::zeros(&[1, 4]),
                    completion: c2,
                    enqueued: Instant::now(),
                },
            ),
        ];
        let per_row = vec![
            vec![Tensor::filled(&[1, 3], 0.0)],
            vec![Tensor::filled(&[1, 3], 1.0)],
            vec![Tensor::filled(&[1, 3], 2.0)],
        ];
        scatter_indexed_row_results(live, Ok(per_row));
        assert_eq!(s0.try_take().unwrap().unwrap().outputs[0].data(), &[0.0; 3]);
        assert_eq!(s2.try_take().unwrap().unwrap().outputs[0].data(), &[2.0; 3]);

        // the dense-output variant slices the same way
        let (s0, c0) = completion(&m);
        let (s2, c2) = completion(&m);
        let live = vec![
            (
                0usize,
                Pending {
                    input: Tensor::zeros(&[1, 4]),
                    completion: c0,
                    enqueued: Instant::now(),
                },
            ),
            (
                2usize,
                Pending {
                    input: Tensor::zeros(&[1, 4]),
                    completion: c2,
                    enqueued: Instant::now(),
                },
            ),
        ];
        let out = Tensor::new(
            &[4, 3],
            (0..4).flat_map(|i| [i as f32; 3]).collect::<Vec<_>>(),
        )
        .unwrap();
        scatter_indexed_results(live, Ok(vec![out]));
        assert_eq!(s0.try_take().unwrap().unwrap().outputs[0].data(), &[0.0; 3]);
        assert_eq!(s2.try_take().unwrap().unwrap().outputs[0].data(), &[2.0; 3]);
    }

    #[test]
    fn fail_pending_settles_queued_rows() {
        // the shutdown path: rows parked behind a long flush deadline are
        // failed explicitly so their waiters unblock with an error
        let m = Arc::new(Metrics::new());
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(60),
            ..Default::default()
        });
        let (s1, c1) = completion(&m);
        let (s2, c2) = completion(&m);
        b.enqueue(fkey(16), Tensor::filled(&[1, 16], 1.0), c1);
        b.enqueue(fkey(32), Tensor::filled(&[1, 32], 2.0), c2);
        assert_eq!(b.fail_pending("shutting down"), 2);
        assert!(s1.try_take().expect("settled").is_err());
        assert!(s2.try_take().expect("settled").is_err());
        assert_eq!(b.queued(), 0);
        assert_eq!(m.failed.load(Ordering::Relaxed), 2);
        // the batcher is closed now: a racing/late enqueue fails fast
        // instead of stranding in a queue no drain loop will visit
        let (s3, c3) = completion(&m);
        b.enqueue(fkey(16), Tensor::filled(&[1, 16], 3.0), c3);
        assert!(s3.try_take().expect("settled").is_err());
        assert_eq!(b.queued(), 0, "closed batcher must not queue rows");
    }

    #[test]
    fn dropped_completion_fails_its_request() {
        // a completion dropped without completing (died batch thread,
        // shutdown) must error the caller instead of stranding it
        let m = Arc::new(Metrics::new());
        let (slot, c) = completion(&m);
        drop(c);
        let got = slot.try_take().expect("drop must settle the slot");
        assert!(got.is_err());
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn scatter_splits_rows_and_discards_padding() {
        let m = Arc::new(Metrics::new());
        let replies: Vec<_> = (0..2).map(|_| completion(&m)).collect();
        let mut slots = Vec::new();
        let mut rows = Vec::new();
        for (slot, c) in replies {
            slots.push(slot);
            rows.push(Pending {
                input: Tensor::zeros(&[1, 4]),
                completion: c,
                enqueued: Instant::now(),
            });
        }
        let batch = FormedBatch {
            key: key(4),
            input: Tensor::zeros(&[4, 4]),
            rows,
            adaptive: None,
        };
        // one output of shape (4, 3): row i filled with i
        let out = Tensor::new(
            &[4, 3],
            (0..4).flat_map(|i| [i as f32; 3]).collect::<Vec<_>>(),
        )
        .unwrap();
        scatter_results(batch, Ok(vec![out]));
        for (i, r) in slots.iter().enumerate() {
            let got = r.try_take().unwrap().unwrap();
            assert_eq!(got.outputs[0].shape(), &[1, 3]);
            assert_eq!(got.outputs[0].data(), &[i as f32; 3]);
            assert!(got.batched, "drain completions are batched responses");
        }
        assert_eq!(m.drain_completions.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn scatter_propagates_errors_to_all_rows() {
        let m = Arc::new(Metrics::new());
        let replies: Vec<_> = (0..3).map(|_| completion(&m)).collect();
        let mut slots = Vec::new();
        let mut rows = Vec::new();
        for (slot, c) in replies {
            slots.push(slot);
            rows.push(Pending {
                input: Tensor::zeros(&[1, 4]),
                completion: c,
                enqueued: Instant::now(),
            });
        }
        let batch = FormedBatch {
            key: key(4),
            input: Tensor::zeros(&[4, 4]),
            rows,
            adaptive: None,
        };
        scatter_results(batch, Err(anyhow::anyhow!("boom")));
        for r in &slots {
            assert!(r.try_take().unwrap().is_err());
        }
        assert_eq!(m.failed.load(Ordering::Relaxed), 3);
        assert_eq!(
            m.drain_completions.load(Ordering::Relaxed),
            3,
            "failed drain completions still count as drain-side"
        );
    }

    #[test]
    fn scatter_rows_delivers_per_request_outputs() {
        let m = Arc::new(Metrics::new());
        let replies: Vec<_> = (0..2).map(|_| completion(&m)).collect();
        let mut slots = Vec::new();
        let mut rows = Vec::new();
        for (slot, c) in replies {
            slots.push(slot);
            rows.push(Pending {
                input: Tensor::zeros(&[1, 4]),
                completion: c,
                enqueued: Instant::now(),
            });
        }
        let batch = FormedBatch {
            key: fkey(4),
            input: Tensor::zeros(&[2, 4]),
            rows,
            adaptive: None,
        };
        let per_row = vec![
            vec![Tensor::filled(&[1, 3], 0.0)],
            vec![Tensor::filled(&[1, 3], 1.0)],
        ];
        scatter_row_results(batch, Ok(per_row));
        for (i, r) in slots.iter().enumerate() {
            let got = r.try_take().unwrap().unwrap();
            assert_eq!(got.outputs[0].shape(), &[1, 3]);
            assert_eq!(got.outputs[0].data(), &[i as f32; 3]);
        }
        assert_eq!(m.drain_completions.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn rate_key_map_stays_bounded_under_key_churn() {
        // adversarial shape-diverse traffic: far more distinct (op, L)
        // fallback keys than RATE_KEYS_CAP.  The map must stay bounded,
        // evicting the stalest key, and the freshest keys must survive
        // with their estimates intact.
        let mut rates = HashMap::new();
        let t0 = Instant::now();
        let n = RATE_KEYS_CAP + 100;
        for i in 0..n {
            let key = BatchKey::Fallback {
                op: OpKind::Fir,
                len: 1000 + i,
            };
            Batcher::observe_arrival(&mut rates, &key, t0 + Duration::from_micros(i as u64));
        }
        assert_eq!(rates.len(), RATE_KEYS_CAP, "map must stay at the cap");
        // the stalest (earliest) keys were evicted, the newest survive
        for i in 0..100 {
            let key = BatchKey::Fallback {
                op: OpKind::Fir,
                len: 1000 + i,
            };
            assert!(!rates.contains_key(&key), "stale key {i} must be evicted");
        }
        for i in n - RATE_KEYS_CAP..n {
            let key = BatchKey::Fallback {
                op: OpKind::Fir,
                len: 1000 + i,
            };
            assert!(rates.contains_key(&key), "fresh key {i} must survive");
        }
        // a re-arrival of a surviving key still updates its EWMA in place
        // (no spurious re-insert, no growth)
        let key = BatchKey::Fallback {
            op: OpKind::Fir,
            len: 1000 + n - 1,
        };
        Batcher::observe_arrival(&mut rates, &key, t0 + Duration::from_millis(10));
        assert_eq!(rates.len(), RATE_KEYS_CAP);
        assert!(Batcher::rate_of(&rates, &key) > 0.0, "gap sample folded in");
        // artifact keys never enter the rate map (nothing to adapt)
        let akey = BatchKey::Artifact {
            name: "a".into(),
            batch: 8,
        };
        Batcher::observe_arrival(&mut rates, &akey, t0 + Duration::from_millis(11));
        assert_eq!(rates.len(), RATE_KEYS_CAP, "artifact keys are not tracked");
    }

    #[test]
    fn scatter_rows_errors_on_arity_mismatch_and_failure() {
        for bad in [true, false] {
            let m = Arc::new(Metrics::new());
            let replies: Vec<_> = (0..2).map(|_| completion(&m)).collect();
            let mut slots = Vec::new();
            let mut rows = Vec::new();
            for (slot, c) in replies {
                slots.push(slot);
                rows.push(Pending {
                    input: Tensor::zeros(&[1, 4]),
                    completion: c,
                    enqueued: Instant::now(),
                });
            }
            let batch = FormedBatch {
                key: fkey(4),
                input: Tensor::zeros(&[2, 4]),
                rows,
                adaptive: None,
            };
            if bad {
                // one row result for two requests: everyone must error
                scatter_row_results(batch, Ok(vec![vec![Tensor::zeros(&[1, 3])]]));
            } else {
                scatter_row_results(batch, Err(anyhow::anyhow!("boom")));
            }
            for r in &slots {
                assert!(r.try_take().unwrap().is_err());
            }
        }
    }
}
