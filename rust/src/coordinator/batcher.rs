//! Dynamic batcher: coalesces same-signature single-signal requests into
//! one padded batch execution (the TINA analog of vLLM-style request
//! batching — HLO artifacts have a fixed leading batch dimension, so the
//! batcher fills as many rows as arrive within the window and zero-pads
//! the rest).

use crate::tensor::Tensor;
use crate::util::threadpool::OneShot;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Key grouping poolable requests: same artifact -> same ABI.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub artifact: String,
    /// Rows the artifact expects (its leading batch dim).
    pub batch: usize,
}

/// One queued request row.
pub struct Pending {
    /// The (1, L) signal row.
    pub input: Tensor,
    /// Completion slot: receives this row's outputs.
    pub reply: OneShot<Result<Vec<Tensor>>>,
    pub enqueued: Instant,
}

/// A formed batch ready for execution.
pub struct FormedBatch {
    pub key: BatchKey,
    /// Stacked (batch, L) input, zero-padded to the artifact batch.
    pub input: Tensor,
    /// How many leading rows are real requests.
    pub rows: Vec<Pending>,
}

/// Batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Max time a request may wait for co-riders before the batch flushes.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
        }
    }
}

struct Shared {
    queues: Mutex<HashMap<BatchKey, Vec<Pending>>>,
    ready: Condvar,
}

/// The batcher: producers enqueue rows; a drain loop (run by the service)
/// pops full or expired batches.
pub struct Batcher {
    shared: Arc<Shared>,
    config: BatcherConfig,
}

impl Batcher {
    pub fn new(config: BatcherConfig) -> Batcher {
        Batcher {
            shared: Arc::new(Shared {
                queues: Mutex::new(HashMap::new()),
                ready: Condvar::new(),
            }),
            config,
        }
    }

    pub fn config(&self) -> BatcherConfig {
        self.config
    }

    /// Enqueue one row; returns immediately.  The reply slot completes when
    /// the batch it rides executes.
    ///
    /// Rows sharing a [`BatchKey`] must agree on signal length — the formed
    /// batch is one dense (batch, L) stack.  A mismatched row is rejected
    /// here by completing its reply with an error, instead of poisoning the
    /// drain loop with a panic when the batch is stacked.
    pub fn enqueue(&self, key: BatchKey, input: Tensor, reply: OneShot<Result<Vec<Tensor>>>) {
        let mut q = self.shared.queues.lock().unwrap();
        let rows = q.entry(key).or_default();
        if let Some(first) = rows.first() {
            if first.input.len() != input.len() {
                let msg = format!(
                    "batch row length {} != queued rows' length {} for the same artifact",
                    input.len(),
                    first.input.len()
                );
                drop(q);
                reply.set(Err(anyhow::anyhow!(msg)));
                return;
            }
        }
        rows.push(Pending {
            input,
            reply,
            enqueued: Instant::now(),
        });
        drop(q);
        self.shared.ready.notify_one();
    }

    /// Block until a batch is full or the oldest row exceeds `max_wait`;
    /// returns None once `deadline` passes without producing a batch
    /// (pending-but-unexpired rows stay queued for the next call).
    ///
    /// Invariant: every loop iteration either returns, or blocks on the
    /// condvar until the earliest of (oldest-row expiry, deadline) — there
    /// is no busy-spin path.  (The previous version spun hot for up to
    /// `max_wait` when the idle deadline passed while unexpired rows were
    /// queued.)
    pub fn next_batch(&self, idle_timeout: Duration) -> Option<FormedBatch> {
        let deadline = Instant::now() + idle_timeout;
        let mut q = self.shared.queues.lock().unwrap();
        loop {
            // full batch available?
            let full = q
                .iter()
                .find(|(k, v)| v.len() >= k.batch)
                .map(|(k, _)| k.clone());
            if let Some(key) = full {
                let rows = q.get_mut(&key).unwrap();
                let take: Vec<Pending> = rows.drain(..key.batch).collect();
                if rows.is_empty() {
                    q.remove(&key);
                }
                return Some(Self::form(key, take));
            }
            // expired batch?  (`now` is shared with the wake computation
            // below so a due expiry is always taken on this iteration, not
            // re-spun on)
            let now = Instant::now();
            let expired = q
                .iter()
                .filter(|(_, v)| !v.is_empty())
                .find(|(_, v)| now.duration_since(v[0].enqueued) >= self.config.max_wait)
                .map(|(k, _)| k.clone());
            if let Some(key) = expired {
                let rows = q.remove(&key).unwrap();
                return Some(Self::form(key, rows));
            }
            if now >= deadline {
                return None;
            }
            // wait for the earliest wakeup: a new enqueue (condvar), the
            // oldest entry's expiry, or the idle deadline
            let oldest_expiry = q
                .values()
                .filter_map(|v| v.first())
                .map(|p| p.enqueued + self.config.max_wait)
                .min();
            let wake = match oldest_expiry {
                Some(e) => e.min(deadline),
                None => deadline,
            };
            if wake <= now {
                // an expiry became due in this very iteration; re-scan
                continue;
            }
            let (guard, _timeout) = self
                .shared
                .ready
                .wait_timeout(q, wake - now)
                .unwrap();
            q = guard;
        }
    }

    /// Rows currently queued across all keys (for tests/metrics).
    pub fn queued(&self) -> usize {
        self.shared.queues.lock().unwrap().values().map(Vec::len).sum()
    }

    fn form(key: BatchKey, rows: Vec<Pending>) -> FormedBatch {
        debug_assert!(!rows.is_empty() && rows.len() <= key.batch);
        let l = rows[0].input.len();
        let mut data = vec![0.0f32; key.batch * l];
        for (i, p) in rows.iter().enumerate() {
            data[i * l..(i + 1) * l].copy_from_slice(p.input.data());
        }
        FormedBatch {
            input: Tensor::new(&[key.batch, l], data).expect("batch stack"),
            key,
            rows,
        }
    }
}

/// Split a batched multi-output execution result back into per-row replies.
///
/// Each output tensor has leading dim = key.batch; row i of every output
/// goes to rows[i].  Padding rows are discarded.
pub fn scatter_results(batch: FormedBatch, result: Result<Vec<Tensor>>) {
    match result {
        Ok(outputs) => {
            for (i, row) in batch.rows.into_iter().enumerate() {
                let per_row: Result<Vec<Tensor>> = outputs
                    .iter()
                    .map(|o| o.slice_axis(0, i, i + 1))
                    .collect();
                row.reply.set(per_row);
            }
        }
        Err(e) => {
            let msg = format!("batched execution failed: {e}");
            for row in batch.rows {
                row.reply.set(Err(anyhow::anyhow!(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(b: usize) -> BatchKey {
        BatchKey {
            artifact: "fir_tina_f32_B8_L16".into(),
            batch: b,
        }
    }

    fn slot() -> OneShot<Result<Vec<Tensor>>> {
        OneShot::new()
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(10),
        });
        for i in 0..4 {
            b.enqueue(key(4), Tensor::filled(&[1, 16], i as f32), slot());
        }
        let batch = b.next_batch(Duration::from_millis(50)).expect("batch");
        assert_eq!(batch.rows.len(), 4);
        assert_eq!(batch.input.shape(), &[4, 16]);
        // rows stacked in arrival order
        assert_eq!(batch.input.at(&[2, 0]), 2.0);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn partial_batch_flushes_after_max_wait_with_padding() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(5),
        });
        b.enqueue(key(4), Tensor::filled(&[1, 16], 7.0), slot());
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
        assert!(t0.elapsed() >= Duration::from_millis(4), "flushed too early");
        assert_eq!(batch.rows.len(), 1);
        assert_eq!(batch.input.shape(), &[4, 16]); // padded
        assert_eq!(batch.input.at(&[0, 0]), 7.0);
        assert_eq!(batch.input.at(&[3, 0]), 0.0); // zero padding
    }

    #[test]
    fn idle_timeout_returns_none() {
        let b = Batcher::new(BatcherConfig::default());
        let t0 = Instant::now();
        assert!(b.next_batch(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn mismatched_row_length_rejected_at_enqueue() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(10),
        });
        let ok = slot();
        b.enqueue(key(4), Tensor::filled(&[1, 16], 1.0), ok.clone());
        // same key, different signal length: must fail fast, not poison form()
        let bad = slot();
        b.enqueue(key(4), Tensor::filled(&[1, 32], 2.0), bad.clone());
        let err = bad.try_take().expect("reply must complete immediately");
        assert!(err.is_err(), "mismatched row must error");
        assert_eq!(b.queued(), 1, "bad row must not be queued");
        // the well-formed row still flushes normally
        b.enqueue(key(4), Tensor::filled(&[1, 16], 3.0), slot());
        assert_eq!(b.queued(), 2);
        assert!(ok.try_take().is_none(), "good row unaffected");
    }

    #[test]
    fn deadline_with_pending_unexpired_rows_returns_none_without_spinning() {
        // rows pending but far from expiry: next_batch must give up at the
        // idle deadline (previously this path busy-spun until expiry)
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(60),
        });
        b.enqueue(key(4), Tensor::filled(&[1, 8], 1.0), slot());
        let t0 = Instant::now();
        assert!(b.next_batch(Duration::from_millis(30)).is_none());
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(29), "returned early: {dt:?}");
        assert!(dt < Duration::from_secs(5), "blocked way past deadline: {dt:?}");
        assert_eq!(b.queued(), 1, "row must stay queued for the next call");
    }

    #[test]
    fn distinct_keys_do_not_mix() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(1),
        });
        b.enqueue(key(2), Tensor::filled(&[1, 16], 1.0), slot());
        let mut other = key(2);
        other.artifact = "other".into();
        b.enqueue(other, Tensor::filled(&[1, 16], 2.0), slot());
        let b1 = b.next_batch(Duration::from_millis(100)).unwrap();
        let b2 = b.next_batch(Duration::from_millis(100)).unwrap();
        assert_eq!(b1.rows.len(), 1);
        assert_eq!(b2.rows.len(), 1);
        assert_ne!(b1.key.artifact, b2.key.artifact);
    }

    #[test]
    fn scatter_splits_rows_and_discards_padding() {
        let replies: Vec<_> = (0..2).map(|_| slot()).collect();
        let rows: Vec<Pending> = replies
            .iter()
            .map(|r| Pending {
                input: Tensor::zeros(&[1, 4]),
                reply: r.clone(),
                enqueued: Instant::now(),
            })
            .collect();
        let batch = FormedBatch {
            key: key(4),
            input: Tensor::zeros(&[4, 4]),
            rows,
        };
        // one output of shape (4, 3): row i filled with i
        let out = Tensor::new(
            &[4, 3],
            (0..4).flat_map(|i| [i as f32; 3]).collect::<Vec<_>>(),
        )
        .unwrap();
        scatter_results(batch, Ok(vec![out]));
        for (i, r) in replies.iter().enumerate() {
            let got = r.try_take().unwrap().unwrap();
            assert_eq!(got[0].shape(), &[1, 3]);
            assert_eq!(got[0].data(), &[i as f32; 3]);
        }
    }

    #[test]
    fn scatter_propagates_errors_to_all_rows() {
        let replies: Vec<_> = (0..3).map(|_| slot()).collect();
        let rows: Vec<Pending> = replies
            .iter()
            .map(|r| Pending {
                input: Tensor::zeros(&[1, 4]),
                reply: r.clone(),
                enqueued: Instant::now(),
            })
            .collect();
        let batch = FormedBatch {
            key: key(4),
            input: Tensor::zeros(&[4, 4]),
            rows,
        };
        scatter_results(batch, Err(anyhow::anyhow!("boom")));
        for r in &replies {
            assert!(r.try_take().unwrap().is_err());
        }
    }
}
