//! Dynamic batcher: coalesces same-signature single-signal requests into
//! one padded batch execution (the TINA analog of vLLM-style request
//! batching).
//!
//! Two kinds of traffic ride it, distinguished by [`BatchKey`]:
//!
//! * **Artifact batches** — HLO artifacts have a *fixed* leading batch
//!   dimension, so the batcher fills as many rows as arrive within the
//!   window and zero-pads the rest up to the artifact batch.
//! * **Fallback batches (shape-bucketed)** — the planned executor can
//!   compile a plan for *any* batch size, so fallback requests are grouped
//!   per `(op, per-item signal length)` and a formed batch pads up to the
//!   next power-of-two bucket `B ∈ {1, 2, 4, 8, ...}` (capped at
//!   [`BatcherConfig::max_bucket`]).  Bucketing keeps the number of
//!   compiled plans per (op, shape) bounded — the LeFlow-style fixed-shape
//!   compilation constraint — while amortizing plan lookup and kernel
//!   launch across co-arriving requests.
//!
//! Padding/masking rule: padding rows are zero-filled at batch formation
//! and are *masked out* at scatter time — per-request outputs are gathered
//! row by row from the plan's terminal views, and rows beyond the real
//! request count are never gathered, so padding can never leak into a
//! reply.  Requests with different per-item shapes land in different
//! buckets by construction (the shape is part of the key), which replaces
//! the old mixed-length rejection with bucket routing; the rejection path
//! survives only for artifact keys, whose row length is fixed by the
//! artifact ABI.

use super::request::OpKind;
use crate::tensor::Tensor;
use crate::util::threadpool::OneShot;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Key grouping poolable requests.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum BatchKey {
    /// Fixed-shape PJRT artifact: same artifact -> same ABI; the formed
    /// batch always pads to the artifact's leading batch dim.
    Artifact {
        name: String,
        /// Rows the artifact expects (its leading batch dim).
        batch: usize,
    },
    /// Shape-bucketed fallback traffic: compatible requests grouped per
    /// (op, per-item signal length); the formed batch pads to the next
    /// power-of-two bucket (capped at [`BatcherConfig::max_bucket`]).
    Fallback { op: OpKind, len: usize },
}

impl BatchKey {
    /// Row count at which a batch is full and flushes immediately.
    fn capacity(&self, config: &BatcherConfig) -> usize {
        match self {
            BatchKey::Artifact { batch, .. } => *batch,
            BatchKey::Fallback { .. } => config.max_bucket.max(1),
        }
    }

    /// Leading dim of the formed batch holding `rows` real rows.
    fn pad_rows(&self, rows: usize, config: &BatcherConfig) -> usize {
        match self {
            BatchKey::Artifact { batch, .. } => *batch,
            BatchKey::Fallback { .. } => rows
                .next_power_of_two()
                .min(config.max_bucket.max(1))
                .max(rows),
        }
    }

    /// Expected per-row element count, when the key itself fixes it.
    fn expected_len(&self) -> Option<usize> {
        match self {
            BatchKey::Artifact { .. } => None,
            BatchKey::Fallback { len, .. } => Some(*len),
        }
    }
}

/// One queued request row.
pub struct Pending {
    /// The (1, L) signal row.
    pub input: Tensor,
    /// Completion slot: receives this row's outputs.
    pub reply: OneShot<Result<Vec<Tensor>>>,
    pub enqueued: Instant,
}

/// A formed batch ready for execution.
pub struct FormedBatch {
    pub key: BatchKey,
    /// Stacked (batch, L) input, zero-padded to the artifact batch
    /// (artifact keys) or to the next power-of-two bucket (fallback keys).
    pub input: Tensor,
    /// How many leading rows are real requests.
    pub rows: Vec<Pending>,
}

/// Batching configuration.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Max time a request may wait for co-riders before the batch flushes.
    pub max_wait: Duration,
    /// Largest fallback bucket: shape-bucketed batches flush as soon as
    /// this many rows are queued, and never pad beyond it.  Buckets are
    /// the powers of two up to this cap; [`Batcher::new`] rounds a
    /// non-power-of-two value *down* so the compiled-plan fan-out stays
    /// exactly {1, 2, 4, ...}.
    pub max_bucket: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(2),
            max_bucket: 8,
        }
    }
}

struct Shared {
    queues: Mutex<HashMap<BatchKey, Vec<Pending>>>,
    ready: Condvar,
}

/// The batcher: producers enqueue rows; a drain loop (run by the service)
/// pops full or expired batches.
pub struct Batcher {
    shared: Arc<Shared>,
    config: BatcherConfig,
}

impl Batcher {
    pub fn new(mut config: BatcherConfig) -> Batcher {
        // normalize: buckets are powers of two, so a non-power-of-two cap
        // rounds down (6 -> 4) instead of silently minting bucket sizes
        // the plan-cache sizing advice doesn't account for
        let mb = config.max_bucket.max(1);
        config.max_bucket = 1usize << (usize::BITS - 1 - mb.leading_zeros());
        Batcher {
            shared: Arc::new(Shared {
                queues: Mutex::new(HashMap::new()),
                ready: Condvar::new(),
            }),
            config,
        }
    }

    pub fn config(&self) -> BatcherConfig {
        self.config
    }

    /// Enqueue one row; returns immediately.  The reply slot completes when
    /// the batch it rides executes.
    ///
    /// Rows sharing a [`BatchKey`] must agree on signal length — the formed
    /// batch is one dense (batch, L) stack.  Fallback keys carry the length
    /// in the key, so differently-shaped requests route to different
    /// buckets by construction; for artifact keys a mismatched row is
    /// rejected here by completing its reply with an error, instead of
    /// poisoning the drain loop with a panic when the batch is stacked.
    pub fn enqueue(&self, key: BatchKey, input: Tensor, reply: OneShot<Result<Vec<Tensor>>>) {
        let mut q = self.shared.queues.lock().unwrap();
        // validate BEFORE creating the queue entry: a rejected row must
        // not leave an empty Vec behind in the map (next_batch's cleanup
        // only fires on formed batches)
        let expect = key
            .expected_len()
            .or_else(|| q.get(&key).and_then(|rows| rows.first()).map(|p| p.input.len()));
        if let Some(expect) = expect {
            if expect != input.len() {
                let msg = format!(
                    "batch row length {} != expected row length {expect} for key {key:?}",
                    input.len()
                );
                drop(q);
                reply.set(Err(anyhow::anyhow!(msg)));
                return;
            }
        }
        q.entry(key).or_default().push(Pending {
            input,
            reply,
            enqueued: Instant::now(),
        });
        drop(q);
        self.shared.ready.notify_one();
    }

    /// Block until a batch is full or the oldest row exceeds `max_wait`;
    /// returns None once `deadline` passes without producing a batch
    /// (pending-but-unexpired rows stay queued for the next call).
    ///
    /// Invariant: every loop iteration either returns, or blocks on the
    /// condvar until the earliest of (oldest-row expiry, deadline) — there
    /// is no busy-spin path.  (The previous version spun hot for up to
    /// `max_wait` when the idle deadline passed while unexpired rows were
    /// queued.)
    pub fn next_batch(&self, idle_timeout: Duration) -> Option<FormedBatch> {
        let deadline = Instant::now() + idle_timeout;
        let mut q = self.shared.queues.lock().unwrap();
        loop {
            // full batch available?
            let full = q
                .iter()
                .find(|(k, v)| v.len() >= k.capacity(&self.config))
                .map(|(k, _)| k.clone());
            if let Some(key) = full {
                let cap = key.capacity(&self.config);
                let rows = q.get_mut(&key).unwrap();
                let take: Vec<Pending> = rows.drain(..cap).collect();
                if rows.is_empty() {
                    q.remove(&key);
                }
                return Some(self.form(key, take));
            }
            // expired batch?  (`now` is shared with the wake computation
            // below so a due expiry is always taken on this iteration, not
            // re-spun on)
            let now = Instant::now();
            let expired = q
                .iter()
                .filter(|(_, v)| !v.is_empty())
                .find(|(_, v)| now.duration_since(v[0].enqueued) >= self.config.max_wait)
                .map(|(k, _)| k.clone());
            if let Some(key) = expired {
                let rows = q.remove(&key).unwrap();
                return Some(self.form(key, rows));
            }
            if now >= deadline {
                return None;
            }
            // wait for the earliest wakeup: a new enqueue (condvar), the
            // oldest entry's expiry, or the idle deadline
            let oldest_expiry = q
                .values()
                .filter_map(|v| v.first())
                .map(|p| p.enqueued + self.config.max_wait)
                .min();
            let wake = match oldest_expiry {
                Some(e) => e.min(deadline),
                None => deadline,
            };
            if wake <= now {
                // an expiry became due in this very iteration; re-scan
                continue;
            }
            let (guard, _timeout) = self
                .shared
                .ready
                .wait_timeout(q, wake - now)
                .unwrap();
            q = guard;
        }
    }

    /// Rows currently queued across all keys (for tests/metrics).
    pub fn queued(&self) -> usize {
        self.shared.queues.lock().unwrap().values().map(Vec::len).sum()
    }

    fn form(&self, key: BatchKey, rows: Vec<Pending>) -> FormedBatch {
        let pad = key.pad_rows(rows.len(), &self.config);
        debug_assert!(!rows.is_empty() && rows.len() <= pad);
        let l = rows[0].input.len();
        let mut data = vec![0.0f32; pad * l];
        for (i, p) in rows.iter().enumerate() {
            data[i * l..(i + 1) * l].copy_from_slice(p.input.data());
        }
        FormedBatch {
            input: Tensor::new(&[pad, l], data).expect("batch stack"),
            key,
            rows,
        }
    }
}

/// Split a batched multi-output execution result back into per-row replies.
///
/// Each output tensor has a leading batch dim; row i of every output goes
/// to rows[i].  Padding rows are discarded (masked out) here.
pub fn scatter_results(batch: FormedBatch, result: Result<Vec<Tensor>>) {
    match result {
        Ok(outputs) => {
            for (i, row) in batch.rows.into_iter().enumerate() {
                let per_row: Result<Vec<Tensor>> = outputs
                    .iter()
                    .map(|o| o.slice_axis(0, i, i + 1))
                    .collect();
                row.reply.set(per_row);
            }
        }
        Err(e) => {
            let msg = format!("batched execution failed: {e}");
            for row in batch.rows {
                row.reply.set(Err(anyhow::anyhow!(msg.clone())));
            }
        }
    }
}

/// Complete a fallback batch whose outputs were already scattered per row
/// by the planned executor ([`crate::tina::Planned::run_rows`]): entry i
/// holds request i's outputs, padding rows were never gathered at all.
pub fn scatter_row_results(batch: FormedBatch, result: Result<Vec<Vec<Tensor>>>) {
    match result {
        Ok(per_row) if per_row.len() == batch.rows.len() => {
            for (row, outs) in batch.rows.into_iter().zip(per_row) {
                row.reply.set(Ok(outs));
            }
        }
        Ok(per_row) => {
            let msg = format!(
                "batched fallback returned {} row results for {} requests",
                per_row.len(),
                batch.rows.len()
            );
            for row in batch.rows {
                row.reply.set(Err(anyhow::anyhow!(msg.clone())));
            }
        }
        Err(e) => {
            let msg = format!("batched fallback execution failed: {e}");
            for row in batch.rows {
                row.reply.set(Err(anyhow::anyhow!(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(b: usize) -> BatchKey {
        BatchKey::Artifact {
            name: "fir_tina_f32_B8_L16".into(),
            batch: b,
        }
    }

    fn fkey(len: usize) -> BatchKey {
        BatchKey::Fallback {
            op: OpKind::Fir,
            len,
        }
    }

    fn slot() -> OneShot<Result<Vec<Tensor>>> {
        OneShot::new()
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        for i in 0..4 {
            b.enqueue(key(4), Tensor::filled(&[1, 16], i as f32), slot());
        }
        let batch = b.next_batch(Duration::from_millis(50)).expect("batch");
        assert_eq!(batch.rows.len(), 4);
        assert_eq!(batch.input.shape(), &[4, 16]);
        // rows stacked in arrival order
        assert_eq!(batch.input.at(&[2, 0]), 2.0);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn partial_batch_flushes_after_max_wait_with_padding() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(5),
            ..Default::default()
        });
        b.enqueue(key(4), Tensor::filled(&[1, 16], 7.0), slot());
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
        assert!(t0.elapsed() >= Duration::from_millis(4), "flushed too early");
        assert_eq!(batch.rows.len(), 1);
        assert_eq!(batch.input.shape(), &[4, 16]); // padded
        assert_eq!(batch.input.at(&[0, 0]), 7.0);
        assert_eq!(batch.input.at(&[3, 0]), 0.0); // zero padding
    }

    #[test]
    fn idle_timeout_returns_none() {
        let b = Batcher::new(BatcherConfig::default());
        let t0 = Instant::now();
        assert!(b.next_batch(Duration::from_millis(20)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(19));
    }

    #[test]
    fn mismatched_row_length_rejected_at_enqueue() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(10),
            ..Default::default()
        });
        let ok = slot();
        b.enqueue(key(4), Tensor::filled(&[1, 16], 1.0), ok.clone());
        // same key, different signal length: must fail fast, not poison form()
        let bad = slot();
        b.enqueue(key(4), Tensor::filled(&[1, 32], 2.0), bad.clone());
        let err = bad.try_take().expect("reply must complete immediately");
        assert!(err.is_err(), "mismatched row must error");
        assert_eq!(b.queued(), 1, "bad row must not be queued");
        // the well-formed row still flushes normally
        b.enqueue(key(4), Tensor::filled(&[1, 16], 3.0), slot());
        assert_eq!(b.queued(), 2);
        assert!(ok.try_take().is_none(), "good row unaffected");
    }

    #[test]
    fn deadline_with_pending_unexpired_rows_returns_none_without_spinning() {
        // rows pending but far from expiry: next_batch must give up at the
        // idle deadline (previously this path busy-spun until expiry)
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(60),
            ..Default::default()
        });
        b.enqueue(key(4), Tensor::filled(&[1, 8], 1.0), slot());
        let t0 = Instant::now();
        assert!(b.next_batch(Duration::from_millis(30)).is_none());
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(29), "returned early: {dt:?}");
        assert!(dt < Duration::from_secs(5), "blocked way past deadline: {dt:?}");
        assert_eq!(b.queued(), 1, "row must stay queued for the next call");
    }

    #[test]
    fn distinct_keys_do_not_mix() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(1),
            ..Default::default()
        });
        b.enqueue(key(2), Tensor::filled(&[1, 16], 1.0), slot());
        let other = BatchKey::Artifact {
            name: "other".into(),
            batch: 2,
        };
        b.enqueue(other, Tensor::filled(&[1, 16], 2.0), slot());
        let b1 = b.next_batch(Duration::from_millis(100)).unwrap();
        let b2 = b.next_batch(Duration::from_millis(100)).unwrap();
        assert_eq!(b1.rows.len(), 1);
        assert_eq!(b2.rows.len(), 1);
        assert_ne!(b1.key, b2.key);
    }

    #[test]
    fn fallback_full_bucket_flushes_immediately() {
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_secs(10),
            max_bucket: 8,
        });
        for i in 0..8 {
            b.enqueue(fkey(16), Tensor::filled(&[1, 16], i as f32), slot());
        }
        let batch = b.next_batch(Duration::from_millis(50)).expect("batch");
        assert_eq!(batch.rows.len(), 8);
        assert_eq!(batch.input.shape(), &[8, 16], "full bucket, no padding");
        assert_eq!(batch.input.at(&[5, 0]), 5.0);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn fallback_bucket_rounds_up_to_next_power_of_two() {
        // 3 rows expire -> bucket 4 with one zero padding row
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(2),
            max_bucket: 8,
        });
        for i in 0..3 {
            b.enqueue(fkey(16), Tensor::filled(&[1, 16], (i + 1) as f32), slot());
        }
        let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
        assert_eq!(batch.rows.len(), 3);
        assert_eq!(batch.input.shape(), &[4, 16], "3 rows pad to bucket 4");
        assert_eq!(batch.input.at(&[2, 0]), 3.0);
        assert_eq!(batch.input.at(&[3, 0]), 0.0, "padding row must be zero");
    }

    #[test]
    fn fallback_bucket_boundary_sizes_pad_exactly() {
        // bucket-boundary row counts (1, 2, 4) need no padding at all
        for rows in [1usize, 2, 4] {
            let b = Batcher::new(BatcherConfig {
                max_wait: Duration::from_millis(1),
                max_bucket: 8,
            });
            for i in 0..rows {
                b.enqueue(fkey(8), Tensor::filled(&[1, 8], (i + 1) as f32), slot());
            }
            let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
            assert_eq!(batch.rows.len(), rows);
            assert_eq!(
                batch.input.shape(),
                &[rows, 8],
                "boundary size {rows} must not pad"
            );
        }
    }

    #[test]
    fn fallback_deadline_expiry_flushes_partial_bucket() {
        // a lone row far below the bucket cap still flushes at max_wait:
        // the degenerate B=1 case of the bucketed path
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(5),
            max_bucket: 8,
        });
        b.enqueue(fkey(16), Tensor::filled(&[1, 16], 9.0), slot());
        let t0 = Instant::now();
        let batch = b.next_batch(Duration::from_secs(1)).expect("batch");
        assert!(t0.elapsed() >= Duration::from_millis(4), "flushed too early");
        assert_eq!(batch.rows.len(), 1);
        assert_eq!(batch.input.shape(), &[1, 16], "single row -> bucket 1");
    }

    #[test]
    fn fallback_wrong_length_rejected_without_leaking_entry() {
        // fallback keys carry the expected length, so even the FIRST row
        // is validated — and the reject path must not leave an empty
        // queue entry behind
        let b = Batcher::new(BatcherConfig::default());
        let bad = slot();
        b.enqueue(fkey(16), Tensor::filled(&[1, 8], 1.0), bad.clone());
        assert!(bad.try_take().expect("immediate reply").is_err());
        assert_eq!(b.queued(), 0, "rejected row must not be queued");
        assert!(
            b.next_batch(Duration::from_millis(5)).is_none(),
            "no phantom batch from a rejected row"
        );
    }

    #[test]
    fn non_power_of_two_max_bucket_rounds_down() {
        // max_bucket 6 normalizes to 4: full flush at 4 rows, remainder
        // pads to its own power-of-two bucket
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(1),
            max_bucket: 6,
        });
        assert_eq!(b.config().max_bucket, 4);
        for i in 0..6 {
            b.enqueue(fkey(8), Tensor::filled(&[1, 8], (i + 1) as f32), slot());
        }
        let first = b.next_batch(Duration::from_secs(1)).expect("full bucket");
        assert_eq!(first.rows.len(), 4);
        assert_eq!(first.input.shape(), &[4, 8]);
        let rest = b.next_batch(Duration::from_secs(1)).expect("remainder");
        assert_eq!(rest.rows.len(), 2);
        assert_eq!(rest.input.shape(), &[2, 8]);
    }

    #[test]
    fn mixed_length_fallback_routes_to_distinct_buckets() {
        // what PR 1 rejected as an error for artifact keys is ordinary
        // bucket routing for fallback keys: the shape is part of the key
        let b = Batcher::new(BatcherConfig {
            max_wait: Duration::from_millis(1),
            max_bucket: 8,
        });
        let r16 = slot();
        let r32 = slot();
        b.enqueue(fkey(16), Tensor::filled(&[1, 16], 1.0), r16.clone());
        b.enqueue(fkey(32), Tensor::filled(&[1, 32], 2.0), r32.clone());
        assert!(r16.try_take().is_none(), "no rejection for mixed lengths");
        assert!(r32.try_take().is_none(), "no rejection for mixed lengths");
        let b1 = b.next_batch(Duration::from_millis(100)).expect("bucket 1");
        let b2 = b.next_batch(Duration::from_millis(100)).expect("bucket 2");
        let mut lens = [b1.input.shape()[1], b2.input.shape()[1]];
        lens.sort_unstable();
        assert_eq!(lens, [16, 32], "each length gets its own bucket");
    }

    #[test]
    fn scatter_splits_rows_and_discards_padding() {
        let replies: Vec<_> = (0..2).map(|_| slot()).collect();
        let rows: Vec<Pending> = replies
            .iter()
            .map(|r| Pending {
                input: Tensor::zeros(&[1, 4]),
                reply: r.clone(),
                enqueued: Instant::now(),
            })
            .collect();
        let batch = FormedBatch {
            key: key(4),
            input: Tensor::zeros(&[4, 4]),
            rows,
        };
        // one output of shape (4, 3): row i filled with i
        let out = Tensor::new(
            &[4, 3],
            (0..4).flat_map(|i| [i as f32; 3]).collect::<Vec<_>>(),
        )
        .unwrap();
        scatter_results(batch, Ok(vec![out]));
        for (i, r) in replies.iter().enumerate() {
            let got = r.try_take().unwrap().unwrap();
            assert_eq!(got[0].shape(), &[1, 3]);
            assert_eq!(got[0].data(), &[i as f32; 3]);
        }
    }

    #[test]
    fn scatter_propagates_errors_to_all_rows() {
        let replies: Vec<_> = (0..3).map(|_| slot()).collect();
        let rows: Vec<Pending> = replies
            .iter()
            .map(|r| Pending {
                input: Tensor::zeros(&[1, 4]),
                reply: r.clone(),
                enqueued: Instant::now(),
            })
            .collect();
        let batch = FormedBatch {
            key: key(4),
            input: Tensor::zeros(&[4, 4]),
            rows,
        };
        scatter_results(batch, Err(anyhow::anyhow!("boom")));
        for r in &replies {
            assert!(r.try_take().unwrap().is_err());
        }
    }

    #[test]
    fn scatter_rows_delivers_per_request_outputs() {
        let replies: Vec<_> = (0..2).map(|_| slot()).collect();
        let rows: Vec<Pending> = replies
            .iter()
            .map(|r| Pending {
                input: Tensor::zeros(&[1, 4]),
                reply: r.clone(),
                enqueued: Instant::now(),
            })
            .collect();
        let batch = FormedBatch {
            key: fkey(4),
            input: Tensor::zeros(&[2, 4]),
            rows,
        };
        let per_row = vec![
            vec![Tensor::filled(&[1, 3], 0.0)],
            vec![Tensor::filled(&[1, 3], 1.0)],
        ];
        scatter_row_results(batch, Ok(per_row));
        for (i, r) in replies.iter().enumerate() {
            let got = r.try_take().unwrap().unwrap();
            assert_eq!(got[0].shape(), &[1, 3]);
            assert_eq!(got[0].data(), &[i as f32; 3]);
        }
    }

    #[test]
    fn scatter_rows_errors_on_arity_mismatch_and_failure() {
        for bad in [true, false] {
            let replies: Vec<_> = (0..2).map(|_| slot()).collect();
            let rows: Vec<Pending> = replies
                .iter()
                .map(|r| Pending {
                    input: Tensor::zeros(&[1, 4]),
                    reply: r.clone(),
                    enqueued: Instant::now(),
                })
                .collect();
            let batch = FormedBatch {
                key: fkey(4),
                input: Tensor::zeros(&[2, 4]),
                rows,
            };
            if bad {
                // one row result for two requests: everyone must error
                scatter_row_results(batch, Ok(vec![vec![Tensor::zeros(&[1, 3])]]));
            } else {
                scatter_row_results(batch, Err(anyhow::anyhow!("boom")));
            }
            for r in &replies {
                assert!(r.try_take().unwrap().is_err());
            }
        }
    }
}
