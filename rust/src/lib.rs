//! # TINA-rs
//!
//! Reproduction of *"TINA: Acceleration of Non-NN Signal Processing
//! Algorithms Using NN Accelerators"* (Boerkamp, van der Vlugt, Al-Ars,
//! 2024) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** — the paper's four building blocks (standard / depthwise /
//!   pointwise convolution, fully connected) as Pallas kernels, compiled
//!   ahead of time (`python/compile`, `make artifacts`).
//! * **L2** — the §3/§4 function→layer mappings lowered to HLO text.
//! * **L3** — this crate: a self-contained runtime that loads the AOT
//!   artifacts via PJRT and serves signal-processing requests, plus every
//!   substrate the evaluation needs (baselines, DSP reference code, a
//!   pure-rust TINA interpreter, benchmarking and property-testing kits).
//!
//! Python never runs on the request path; after `make artifacts` the
//! `tina` binary only needs the `artifacts/` directory.
//!
//! See `DESIGN.md` for the full system inventory and per-experiment index,
//! and the repo-root `ARCHITECTURE.md` for the serving request lifecycle.

// Every public item carries rustdoc; CI builds docs with
// RUSTDOCFLAGS="-D warnings" so the contract cannot rot.
#![warn(missing_docs)]
// Every pointer dereference must be inside an explicit `unsafe {}` block
// with its own `// SAFETY:` justification, even inside `unsafe fn` —
// enforced alongside the repo-invariant lint (rust/scripts/lint_invariants.py)
// that rejects undocumented unsafe blocks.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baselines;
pub mod benchkit;
pub mod coordinator;
pub mod dsp;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod tina;
pub mod util;

/// Crate-wide result alias (anyhow is the only non-xla dependency).
pub type Result<T> = anyhow::Result<T>;
