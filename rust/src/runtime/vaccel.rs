//! The virtual accelerator backend (`--features vaccel`): a second,
//! self-contained implementation of the engine contract that executes
//! compiled [`ExecPlan`]s through the load-time specializer
//! ([`LinearProgram`]) with device-style semantics:
//!
//! * **explicit artifact lifecycle** — [`VaccelEngine::load`] specializes
//!   a plan once (the device "JIT"); [`VaccelEngine::unload`] frees it;
//!   executing an unloaded name is a typed
//!   [`EngineError::UnknownArtifact`], not a stringly error;
//! * **capability probe** — [`VaccelEngine::capability`] reports up
//!   front whether the backend can execute (programs loaded, workers
//!   alive), so the router arms the artifact arm against a type;
//! * **bounded command queue** — executions are submitted to a
//!   fixed-depth queue drained by a small set of named worker threads
//!   (`tina-vaccel-{i}`), mirroring a device's command processor; a full
//!   queue applies backpressure to the submitter instead of spawning
//!   unbounded work;
//! * **fault containment** — a kernel panic on a worker is caught on
//!   that worker and surfaced to the submitter as a typed
//!   [`EngineError::Execution`]; the worker survives to serve the next
//!   job.
//!
//! The oracle contract carries over unchanged: the specializer dispatches
//! into the exact same `fused` kernels as the planned executor, so vaccel
//! output is **bit-for-bit** equal to the interpreter (asserted per
//! random graph by the differential fuzzer in `rust/tests/properties.rs`
//! and end-to-end by the coordinator tests).

use super::engine::{Backend, Capability, EngineError, EngineStats};
use crate::tensor::Tensor;
use crate::tina::{ExecPlan, LinearProgram};
use crate::util::threadpool::OneShot;
use anyhow::Result;
use std::collections::HashMap;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Default worker threads draining the command queue.
pub const DEFAULT_WORKERS: usize = 2;

/// Default command-queue depth (submissions beyond this block).
pub const DEFAULT_QUEUE_DEPTH: usize = 16;

/// What a submitted command asks the device to do.
enum Work {
    /// Run the whole program; return outputs in declaration order.
    Batch(Vec<Tensor>),
    /// Run the (batched) program, then gather the first `n` rows of
    /// every output into per-request tensors (leading dim 1).
    Rows(Vec<Tensor>, usize),
}

/// What a completed command hands back.
enum Done {
    Batch(Vec<Tensor>),
    Rows(Vec<Vec<Tensor>>),
}

/// One queued command: the resolved program, its payload, and the
/// submitter's reply slot.  The worker also reports execution
/// nanoseconds so stats accounting stays on the submitting thread.
struct Job {
    program: Arc<LinearProgram>,
    work: Work,
    reply: OneShot<(Result<Done, EngineError>, u64)>,
}

/// The virtual accelerator: loaded linear programs plus a bounded
/// worker set.  `Send + Sync` — unlike the PJRT [`super::Engine`], a
/// `VaccelEngine` is shared directly (via `Arc`) rather than through a
/// dedicated owner thread.
pub struct VaccelEngine {
    programs: Mutex<HashMap<String, Arc<LinearProgram>>>,
    stats: Mutex<EngineStats>,
    /// `Some` until drop; taking it closes the queue and stops workers.
    queue: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl VaccelEngine {
    /// Build an engine with an explicit worker count and queue depth
    /// (both clamped to at least 1).
    pub fn new(workers: usize, queue_depth: usize) -> VaccelEngine {
        let workers = workers.max(1);
        let (tx, rx) = sync_channel::<Job>(queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("tina-vaccel-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawning vaccel worker thread")
            })
            .collect();
        VaccelEngine {
            programs: Mutex::new(HashMap::new()),
            stats: Mutex::new(EngineStats::default()),
            queue: Some(tx),
            workers: handles,
        }
    }

    /// Build an engine with the default worker/queue sizing.
    pub fn with_defaults() -> VaccelEngine {
        VaccelEngine::new(DEFAULT_WORKERS, DEFAULT_QUEUE_DEPTH)
    }

    /// Specialize a compiled plan and install it under `name` (the
    /// device "artifact load").  Replaces any previous program of the
    /// same name.  A plan that violates the kernel ABI fails here, at
    /// load time, with a typed [`EngineError::Abi`].
    pub fn load(&self, name: &str, plan: &ExecPlan) -> Result<(), EngineError> {
        let t0 = Instant::now();
        let program = LinearProgram::load(plan).map_err(|e| EngineError::Abi {
            backend: "vaccel",
            reason: format!("loading '{name}': {e:#}"),
        })?;
        {
            let mut stats = self.stats.lock().expect("vaccel stats lock poisoned");
            stats.compiles += 1;
            stats.compile_ns += t0.elapsed().as_nanos() as u64;
        }
        self.programs
            .lock()
            .expect("vaccel program table poisoned")
            .insert(name.to_string(), Arc::new(program));
        Ok(())
    }

    /// Remove a loaded program.  Returns whether it was present.
    pub fn unload(&self, name: &str) -> bool {
        self.programs
            .lock()
            .expect("vaccel program table poisoned")
            .remove(name)
            .is_some()
    }

    /// Whether `name` is currently loaded.
    pub fn is_loaded(&self, name: &str) -> bool {
        self.programs
            .lock()
            .expect("vaccel program table poisoned")
            .contains_key(name)
    }

    /// Names of all loaded programs (sorted, for stable output).
    pub fn loaded(&self) -> Vec<String> {
        let mut names: Vec<String> = self
            .programs
            .lock()
            .expect("vaccel program table poisoned")
            .keys()
            .cloned()
            .collect();
        names.sort();
        names
    }

    /// Typed capability probe: executable iff at least one program is
    /// loaded and the command queue is alive.
    pub fn capability(&self) -> Capability {
        let n = self
            .programs
            .lock()
            .expect("vaccel program table poisoned")
            .len();
        if self.queue.is_none() {
            Capability {
                backend: "vaccel",
                can_execute: false,
                detail: "command queue closed".to_string(),
            }
        } else if n == 0 {
            Capability {
                backend: "vaccel",
                can_execute: false,
                detail: "no programs loaded".to_string(),
            }
        } else {
            Capability {
                backend: "vaccel",
                can_execute: true,
                detail: format!("{n} program(s) loaded; {} worker(s)", self.workers.len()),
            }
        }
    }

    /// Snapshot of the accumulated statistics (`compiles` counts
    /// [`VaccelEngine::load`] specializations).
    pub fn stats(&self) -> EngineStats {
        *self.stats.lock().expect("vaccel stats lock poisoned")
    }

    /// Zero the accumulated statistics.
    pub fn reset_stats(&self) {
        *self.stats.lock().expect("vaccel stats lock poisoned") = EngineStats::default();
    }

    /// Execute a loaded program with typed errors (lookup, ABI check,
    /// queue submit, reply wait).
    pub fn try_execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>, EngineError> {
        match self.submit(name, inputs, None)? {
            Done::Batch(outputs) => Ok(outputs),
            Done::Rows(_) => unreachable!("batch submit returned row payload"),
        }
    }

    /// Batched-serving entry: execute once at the program's batch size,
    /// then gather the first `rows` rows of every output into
    /// per-request tensors (leading dim 1) — padding rows are never
    /// gathered, mirroring `ExecPlan::run_rows_in`.
    pub fn try_execute_rows(
        &self,
        name: &str,
        inputs: &[Tensor],
        rows: usize,
    ) -> Result<Vec<Vec<Tensor>>, EngineError> {
        match self.submit(name, inputs, Some(rows))? {
            Done::Rows(rows) => Ok(rows),
            Done::Batch(_) => unreachable!("rows submit returned batch payload"),
        }
    }

    /// Anyhow-facing wrapper over [`VaccelEngine::try_execute`] (the
    /// engine-contract signature).
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.try_execute(name, inputs).map_err(Into::into)
    }

    /// Anyhow-facing wrapper over [`VaccelEngine::try_execute_rows`].
    pub fn execute_rows(
        &self,
        name: &str,
        inputs: &[Tensor],
        rows: usize,
    ) -> Result<Vec<Vec<Tensor>>> {
        self.try_execute_rows(name, inputs, rows).map_err(Into::into)
    }

    fn submit(
        &self,
        name: &str,
        inputs: &[Tensor],
        rows: Option<usize>,
    ) -> Result<Done, EngineError> {
        let program = self
            .programs
            .lock()
            .expect("vaccel program table poisoned")
            .get(name)
            .cloned()
            .ok_or_else(|| EngineError::UnknownArtifact {
                backend: "vaccel",
                name: name.to_string(),
            })?;
        self.check_abi(name, &program, inputs)?;
        let queue = self.queue.as_ref().ok_or_else(|| EngineError::Unavailable {
            backend: "vaccel",
            reason: "command queue closed".to_string(),
        })?;
        let reply = OneShot::new();
        let work = match rows {
            None => Work::Batch(inputs.to_vec()),
            Some(n) => Work::Rows(inputs.to_vec(), n),
        };
        queue
            .send(Job {
                program,
                work,
                reply: reply.clone(),
            })
            .map_err(|_| EngineError::Unavailable {
                backend: "vaccel",
                reason: "worker queue disconnected".to_string(),
            })?;
        let (result, elapsed_ns) = reply.wait();
        {
            let mut stats = self.stats.lock().expect("vaccel stats lock poisoned");
            stats.executions += 1;
            stats.execute_ns += elapsed_ns;
        }
        result
    }

    fn check_abi(
        &self,
        name: &str,
        program: &LinearProgram,
        inputs: &[Tensor],
    ) -> Result<(), EngineError> {
        let declared = program.input_shapes();
        if inputs.len() != declared.len() {
            return Err(EngineError::Abi {
                backend: "vaccel",
                reason: format!(
                    "program '{name}' wants {} inputs, got {}",
                    declared.len(),
                    inputs.len()
                ),
            });
        }
        for (i, (t, shape)) in inputs.iter().zip(declared).enumerate() {
            if t.shape() != shape.as_slice() {
                return Err(EngineError::Abi {
                    backend: "vaccel",
                    reason: format!(
                        "program '{name}' input {i}: shape {:?} != declared {:?}",
                        t.shape(),
                        shape
                    ),
                });
            }
        }
        Ok(())
    }
}

impl Drop for VaccelEngine {
    fn drop(&mut self) {
        // Closing the channel wakes every worker's recv with Err.
        drop(self.queue.take());
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Backend for VaccelEngine {
    fn name(&self) -> &'static str {
        "vaccel"
    }

    fn capability(&self) -> Capability {
        VaccelEngine::capability(self)
    }

    fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        VaccelEngine::execute(self, name, inputs)
    }

    fn prepare(&self, name: &str) -> Result<()> {
        if self.is_loaded(name) {
            Ok(())
        } else {
            Err(EngineError::UnknownArtifact {
                backend: "vaccel",
                name: name.to_string(),
            }
            .into())
        }
    }

    fn stats(&self) -> EngineStats {
        VaccelEngine::stats(self)
    }
}

impl std::fmt::Debug for VaccelEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VaccelEngine")
            .field("loaded", &self.loaded())
            .field("workers", &self.workers.len())
            .finish()
    }
}

/// Worker drain loop: pop a command, run it with panic containment,
/// reply with the result and the measured execution nanoseconds.
fn worker_loop(rx: &Arc<Mutex<Receiver<Job>>>) {
    loop {
        let job = {
            let guard = rx.lock().expect("vaccel command queue poisoned");
            guard.recv()
        };
        let Ok(Job { program, work, reply }) = job else {
            return; // queue closed: engine dropped
        };
        let t0 = Instant::now();
        let outcome = std::panic::catch_unwind(AssertUnwindSafe(|| match work {
            Work::Batch(inputs) => program.run(&inputs).map(Done::Batch),
            Work::Rows(inputs, n) => program.run_rows(&inputs, n).map(Done::Rows),
        }));
        let result = match outcome {
            Ok(Ok(done)) => Ok(done),
            Ok(Err(e)) => Err(EngineError::Execution {
                backend: "vaccel",
                reason: format!("{e:#}"),
            }),
            Err(payload) => Err(EngineError::Execution {
                backend: "vaccel",
                reason: format!("kernel panicked: {}", panic_message(&payload)),
            }),
        };
        reply.set((result, t0.elapsed().as_nanos() as u64));
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tina::lower;
    use crate::tina::Interpreter;

    fn engine() -> VaccelEngine {
        VaccelEngine::new(2, 8)
    }

    fn load_stft(eng: &VaccelEngine, name: &str, b: usize) {
        let graph = lower::stft(b, 320, 32, 16).unwrap();
        let plan = ExecPlan::compile(&graph).unwrap();
        eng.load(name, &plan).unwrap();
    }

    #[test]
    fn executes_loaded_program_bitwise_equal_to_interpreter() {
        let eng = engine();
        load_stft(&eng, "stft", 2);
        let inputs = vec![Tensor::randn(&[2, 320], 7)];
        let want = Interpreter::new(lower::stft(2, 320, 32, 16).unwrap())
            .unwrap()
            .run(&inputs)
            .unwrap();
        let got = eng.try_execute("stft", &inputs).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a, b, "vaccel output diverged from the oracle");
        }
    }

    #[test]
    fn execute_rows_gathers_per_request_rows() {
        let eng = engine();
        load_stft(&eng, "stft_b4", 4);
        let solo = Interpreter::new(lower::stft(1, 320, 32, 16).unwrap()).unwrap();
        let rows: Vec<Tensor> = (0..3).map(|r| Tensor::randn(&[1, 320], 40 + r)).collect();
        let mut data = Vec::new();
        for r in &rows {
            data.extend_from_slice(r.data());
        }
        data.resize(4 * 320, 0.0);
        let batched = Tensor::new(&[4, 320], data).unwrap();
        let got = eng
            .try_execute_rows("stft_b4", std::slice::from_ref(&batched), 3)
            .unwrap();
        for (r, row_in) in rows.iter().enumerate() {
            let want = solo.run(std::slice::from_ref(row_in)).unwrap();
            for (a, b) in got[r].iter().zip(&want) {
                assert_eq!(a, b, "row {r} diverged");
            }
        }
    }

    #[test]
    fn unknown_artifact_is_typed() {
        let eng = engine();
        let err = eng.try_execute("nope", &[]).unwrap_err();
        assert_eq!(
            err,
            EngineError::UnknownArtifact {
                backend: "vaccel",
                name: "nope".to_string(),
            }
        );
    }

    #[test]
    fn abi_mismatch_is_typed() {
        let eng = engine();
        load_stft(&eng, "stft", 2);
        let err = eng
            .try_execute("stft", &[Tensor::randn(&[3, 320], 1)])
            .unwrap_err();
        assert!(
            matches!(err, EngineError::Abi { backend: "vaccel", .. }),
            "got {err:?}"
        );
        let err = eng.try_execute("stft", &[]).unwrap_err();
        assert!(matches!(err, EngineError::Abi { .. }), "got {err:?}");
    }

    #[test]
    fn unload_flips_capability_and_lookup() {
        let eng = engine();
        assert!(!eng.capability().can_execute, "empty engine must not arm");
        load_stft(&eng, "stft", 1);
        assert!(eng.capability().can_execute);
        assert!(eng.is_loaded("stft"));
        assert_eq!(eng.loaded(), vec!["stft".to_string()]);
        assert!(eng.unload("stft"));
        assert!(!eng.unload("stft"), "double unload reports absence");
        assert!(!eng.capability().can_execute);
        assert!(matches!(
            eng.try_execute("stft", &[]).unwrap_err(),
            EngineError::UnknownArtifact { .. }
        ));
    }

    #[test]
    fn stats_count_loads_and_executions() {
        let eng = engine();
        load_stft(&eng, "stft", 1);
        let inputs = vec![Tensor::randn(&[1, 320], 3)];
        eng.try_execute("stft", &inputs).unwrap();
        eng.try_execute("stft", &inputs).unwrap();
        let stats = eng.stats();
        assert_eq!(stats.compiles, 1);
        assert_eq!(stats.executions, 2);
        assert!(stats.compile_ns > 0);
        assert!(stats.execute_ns > 0);
        eng.reset_stats();
        assert_eq!(eng.stats().executions, 0);
    }

    #[test]
    fn concurrent_submitters_share_the_worker_set() {
        let eng = Arc::new(engine());
        load_stft(&eng, "stft", 1);
        let want = Interpreter::new(lower::stft(1, 320, 32, 16).unwrap())
            .unwrap()
            .run(&[Tensor::randn(&[1, 320], 5)])
            .unwrap();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let eng = Arc::clone(&eng);
                std::thread::spawn(move || {
                    for _ in 0..8 {
                        let got = eng
                            .try_execute("stft", &[Tensor::randn(&[1, 320], 5)])
                            .unwrap();
                        for (a, b) in got.iter().zip(&want) {
                            assert_eq!(a, b);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(eng.stats().executions, 32);
    }

    #[test]
    fn engine_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<VaccelEngine>();
    }
}
