//! Single-threaded PJRT engine: compile HLO text once, execute many times.
//!
//! Not Send (the `xla` crate's client is `Rc`-based); multi-threaded
//! callers go through [`super::handle::EngineHandle`].

use super::artifact::{ArtifactMeta, Registry};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Execution statistics (reset-able; used by the §Perf pass).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// HLO compiles performed.
    pub compiles: u64,
    /// Executions performed.
    pub executions: u64,
    /// Total nanoseconds spent compiling.
    pub compile_ns: u64,
    /// Total nanoseconds spent executing.
    pub execute_ns: u64,
}

/// PJRT CPU engine with a per-artifact executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    registry: Registry,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    /// Create a CPU engine over an artifact registry.
    pub fn new(registry: Registry) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            registry,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    /// Convenience: load the registry from a directory and build an engine.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Engine::new(Registry::load(dir)?)
    }

    /// The registry the engine serves.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Snapshot of the accumulated statistics.
    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    /// Zero the accumulated statistics.
    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = EngineStats::default();
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn prepare(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let meta = self
            .registry
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.registry.hlo_path(meta);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let exe = Rc::new(exe);
        {
            let mut stats = self.stats.borrow_mut();
            stats.compiles += 1;
            stats.compile_ns += t0.elapsed().as_nanos() as u64;
        }
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact on host tensors, with ABI checking against the
    /// manifest.  Returns the output tensors in manifest order.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .registry
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        self.check_inputs(meta, inputs)?;
        let exe = self.prepare(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;

        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact '{name}'"))?;
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("artifact '{name}' returned no buffers"))?
            .to_literal_sync()
            .context("fetching result literal")?;
        {
            let mut stats = self.stats.borrow_mut();
            stats.executions += 1;
            stats.execute_ns += t0.elapsed().as_nanos() as u64;
        }

        // aot.py lowers with return_tuple=True: always a tuple literal.
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| literal_to_tensor(&lit, &spec.shape))
            .collect()
    }

    fn check_inputs(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{}' wants {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "artifact '{}' input {i}: shape {:?} != expected {:?}",
                    meta.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }

    /// Upload a host tensor to a device buffer (outside the hot path).
    ///
    /// The paper's measurement protocol starts timing *after* input data is
    /// resident on the accelerator; `upload` + [`Engine::execute_buffers`]
    /// reproduce that split (see EXPERIMENTS.md §Perf L3).
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)
            .map_err(|e| anyhow!("upload {:?}: {e:?}", t.shape()))
    }

    /// Execute on pre-uploaded device buffers; only the computation and the
    /// device->host result fetch are in this call.
    pub fn execute_buffers(
        &self,
        name: &str,
        inputs: &[xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let meta = self
            .registry
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let exe = self.prepare(name)?;
        let t0 = std::time::Instant::now();
        let result = exe
            .execute_b::<xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing artifact '{name}' (buffers)"))?;
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("artifact '{name}' returned no buffers"))?
            .to_literal_sync()
            .context("fetching result literal")?;
        {
            let mut stats = self.stats.borrow_mut();
            stats.executions += 1;
            stats.execute_ns += t0.elapsed().as_nanos() as u64;
        }
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| literal_to_tensor(&lit, &spec.shape))
            .collect()
    }

    /// Number of executables resident in the cache.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Drop all cached executables (frees PJRT memory).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }
}

/// Host tensor -> XLA literal (f32, row-major).
///
/// Uses the single-copy constructor (`create_from_shape_and_untyped_data`)
/// rather than `vec1` + `reshape`, which copies the buffer twice — measured
/// at ~15% of small-artifact execution time (EXPERIMENTS.md §Perf L3).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    // SAFETY: reinterprets the tensor's f32 buffer as its raw bytes —
    // same allocation, len * size_of::<f32>() bytes, and u8 has no
    // alignment or validity requirements.  The borrow of `t` keeps the
    // buffer alive for the lifetime of `bytes`.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, t.shape(), bytes)
        .map_err(|e| anyhow!("literal create for {:?}: {e:?}", t.shape()))
}

/// XLA literal -> host tensor, validated against the expected shape.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    let want: usize = shape.iter().product();
    if data.len() != want {
        bail!(
            "literal has {} elements, expected {} for shape {:?}",
            data.len(),
            want,
            shape
        );
    }
    Tensor::new(shape, data)
}
