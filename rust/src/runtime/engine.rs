//! Single-threaded PJRT engine: compile HLO text once, execute many times.
//!
//! Not Send (the `xla` crate's client is `Rc`-based); multi-threaded
//! callers go through [`super::handle::EngineHandle`].

use super::artifact::{ArtifactMeta, Registry};
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;

/// Execution statistics (reset-able; used by the §Perf pass).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// HLO compiles performed.
    pub compiles: u64,
    /// Executions performed.
    pub executions: u64,
    /// Total nanoseconds spent compiling.
    pub compile_ns: u64,
    /// Total nanoseconds spent executing.
    pub execute_ns: u64,
}

/// Typed engine failure taxonomy shared by every backend.
///
/// Before this existed, the only signal that a backend could not execute
/// was a stringly `"runtime unavailable"` buried in an execute-time error
/// chain — impossible to branch on without message matching.  Routing
/// decisions now consume [`Capability`] (probed once, up front) and
/// failures carry a variant the caller can classify.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The backend cannot execute at all (stub bindings, dead worker
    /// queue, missing runtime).  The capability probe reports this state
    /// *before* any request is routed to the backend.
    Unavailable {
        /// Backend name (`"pjrt"`, `"vaccel"`).
        backend: &'static str,
        /// Human-readable cause.
        reason: String,
    },
    /// The named artifact is not registered/loaded on this backend.
    UnknownArtifact {
        /// Backend name.
        backend: &'static str,
        /// The artifact that was requested.
        name: String,
    },
    /// Inputs do not match the artifact's declared ABI.
    Abi {
        /// Backend name.
        backend: &'static str,
        /// What mismatched.
        reason: String,
    },
    /// The artifact was accepted but execution failed (including a
    /// contained kernel panic on a backend worker).
    Execution {
        /// Backend name.
        backend: &'static str,
        /// Human-readable cause.
        reason: String,
    },
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Unavailable { backend, reason } => {
                write!(f, "backend '{backend}' unavailable: {reason}")
            }
            EngineError::UnknownArtifact { backend, name } => {
                write!(f, "backend '{backend}': unknown artifact '{name}'")
            }
            EngineError::Abi { backend, reason } => {
                write!(f, "backend '{backend}': ABI mismatch: {reason}")
            }
            EngineError::Execution { backend, reason } => {
                write!(f, "backend '{backend}': execution failed: {reason}")
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// Result of probing a backend for execute capability.
///
/// Probed once (and cached) instead of discovering unavailability at
/// execute time: the coordinator reads this at construction and tells
/// the router whether the artifact arm is live, so `ImplPref::Auto`
/// routing is decided against a type, not an error-message match.
#[derive(Debug, Clone)]
pub struct Capability {
    /// Backend name (`"pjrt"`, `"vaccel"`).
    pub backend: &'static str,
    /// Whether the backend can actually execute artifacts.
    pub can_execute: bool,
    /// Human-readable probe detail (platform, loaded-program count, or
    /// why the probe failed).
    pub detail: String,
}

/// The contract every execution backend implements: a named engine that
/// owns compiled artifacts, probes its own capability, and executes by
/// artifact name against a declared ABI.
///
/// Two implementations ship: the PJRT [`Engine`] (real accelerator
/// bindings when available; an offline stub otherwise — the probe
/// reports which) and the feature-gated `runtime::vaccel::VaccelEngine`
/// virtual accelerator.  Multi-threaded callers hold a
/// [`super::handle::EngineHandle`], which dispatches to whichever
/// backend it wraps (the PJRT client is `Rc`-based and lives on a
/// dedicated thread; the vaccel engine is `Sync` and is called
/// directly).
pub trait Backend {
    /// Stable backend name (used in metrics and error taxonomy).
    fn name(&self) -> &'static str;

    /// Probe (or return the cached) execute capability.
    fn capability(&self) -> Capability;

    /// Execute an artifact by name on host tensors, ABI-checked.
    fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Warm whatever per-artifact state execution needs (compile cache,
    /// loaded program).
    fn prepare(&self, name: &str) -> Result<()>;

    /// Snapshot of accumulated statistics.
    fn stats(&self) -> EngineStats;
}

/// PJRT CPU engine with a per-artifact executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    registry: Registry,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<EngineStats>,
    capability: RefCell<Option<Capability>>,
}

impl Engine {
    /// Create a CPU engine over an artifact registry.
    pub fn new(registry: Registry) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            registry,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
            capability: RefCell::new(None),
        })
    }

    /// Convenience: load the registry from a directory and build an engine.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        Engine::new(Registry::load(dir)?)
    }

    /// The registry the engine serves.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Snapshot of the accumulated statistics.
    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    /// Zero the accumulated statistics.
    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = EngineStats::default();
    }

    /// Probe (once, cached) whether this engine can actually execute.
    ///
    /// The offline `xla` stub compiles fine but fails every compile /
    /// execute call at runtime; previously that surfaced as a stringly
    /// `"runtime unavailable"` error at execute time.  The probe attempts
    /// to [`Engine::prepare`] the first registered artifact and classifies
    /// the outcome, so callers (the coordinator, the router's artifact
    /// arm) learn availability up front as a typed [`Capability`].
    pub fn capability(&self) -> Capability {
        if let Some(cap) = self.capability.borrow().as_ref() {
            return cap.clone();
        }
        let cap = self.probe();
        *self.capability.borrow_mut() = Some(cap.clone());
        cap
    }

    fn probe(&self) -> Capability {
        let Some(first) = self.registry.entries().first() else {
            return Capability {
                backend: "pjrt",
                can_execute: false,
                detail: "no artifacts registered".to_string(),
            };
        };
        match self.prepare(&first.name) {
            Ok(_) => Capability {
                backend: "pjrt",
                can_execute: true,
                detail: format!("platform '{}'", self.platform()),
            },
            Err(e) => Capability {
                backend: "pjrt",
                can_execute: false,
                detail: format!("probe compile of '{}' failed: {e:#}", first.name),
            },
        }
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn prepare(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let meta = self
            .registry
            .get(name)
            .ok_or_else(|| {
                anyhow::Error::from(EngineError::UnknownArtifact {
                    backend: "pjrt",
                    name: name.to_string(),
                })
            })?;
        let path = self.registry.hlo_path(meta);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {}", path.display()))?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let exe = Rc::new(exe);
        {
            let mut stats = self.stats.borrow_mut();
            stats.compiles += 1;
            stats.compile_ns += t0.elapsed().as_nanos() as u64;
        }
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Execute an artifact on host tensors, with ABI checking against the
    /// manifest.  Returns the output tensors in manifest order.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .registry
            .get(name)
            .ok_or_else(|| {
                anyhow::Error::from(EngineError::UnknownArtifact {
                    backend: "pjrt",
                    name: name.to_string(),
                })
            })?;
        self.check_inputs(meta, inputs)?;
        let exe = self.prepare(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;

        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact '{name}'"))?;
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("artifact '{name}' returned no buffers"))?
            .to_literal_sync()
            .context("fetching result literal")?;
        {
            let mut stats = self.stats.borrow_mut();
            stats.executions += 1;
            stats.execute_ns += t0.elapsed().as_nanos() as u64;
        }

        // aot.py lowers with return_tuple=True: always a tuple literal.
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| literal_to_tensor(&lit, &spec.shape))
            .collect()
    }

    fn check_inputs(&self, meta: &ArtifactMeta, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != meta.inputs.len() {
            bail!(
                "artifact '{}' wants {} inputs, got {}",
                meta.name,
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "artifact '{}' input {i}: shape {:?} != expected {:?}",
                    meta.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }

    /// Upload a host tensor to a device buffer (outside the hot path).
    ///
    /// The paper's measurement protocol starts timing *after* input data is
    /// resident on the accelerator; `upload` + [`Engine::execute_buffers`]
    /// reproduce that split (see EXPERIMENTS.md §Perf L3).
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)
            .map_err(|e| anyhow!("upload {:?}: {e:?}", t.shape()))
    }

    /// Execute on pre-uploaded device buffers; only the computation and the
    /// device->host result fetch are in this call.
    pub fn execute_buffers(
        &self,
        name: &str,
        inputs: &[xla::PjRtBuffer],
    ) -> Result<Vec<Tensor>> {
        let meta = self
            .registry
            .get(name)
            .ok_or_else(|| {
                anyhow::Error::from(EngineError::UnknownArtifact {
                    backend: "pjrt",
                    name: name.to_string(),
                })
            })?;
        let exe = self.prepare(name)?;
        let t0 = std::time::Instant::now();
        let result = exe
            .execute_b::<xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing artifact '{name}' (buffers)"))?;
        let tuple = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("artifact '{name}' returned no buffers"))?
            .to_literal_sync()
            .context("fetching result literal")?;
        {
            let mut stats = self.stats.borrow_mut();
            stats.executions += 1;
            stats.execute_ns += t0.elapsed().as_nanos() as u64;
        }
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, spec)| literal_to_tensor(&lit, &spec.shape))
            .collect()
    }

    /// Number of executables resident in the cache.
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Drop all cached executables (frees PJRT memory).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn capability(&self) -> Capability {
        Engine::capability(self)
    }

    fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        Engine::execute(self, name, inputs)
    }

    fn prepare(&self, name: &str) -> Result<()> {
        Engine::prepare(self, name).map(|_| ())
    }

    fn stats(&self) -> EngineStats {
        Engine::stats(self)
    }
}

/// Host tensor -> XLA literal (f32, row-major).
///
/// Uses the single-copy constructor (`create_from_shape_and_untyped_data`)
/// rather than `vec1` + `reshape`, which copies the buffer twice — measured
/// at ~15% of small-artifact execution time (EXPERIMENTS.md §Perf L3).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    // SAFETY: reinterprets the tensor's f32 buffer as its raw bytes —
    // same allocation, len * size_of::<f32>() bytes, and u8 has no
    // alignment or validity requirements.  The borrow of `t` keeps the
    // buffer alive for the lifetime of `bytes`.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, t.shape(), bytes)
        .map_err(|e| anyhow!("literal create for {:?}: {e:?}", t.shape()))
}

/// XLA literal -> host tensor, validated against the expected shape.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit
        .to_vec::<f32>()
        .map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    let want: usize = shape.iter().product();
    if data.len() != want {
        bail!(
            "literal has {} elements, expected {} for shape {:?}",
            data.len(),
            want,
            shape
        );
    }
    Tensor::new(shape, data)
}
