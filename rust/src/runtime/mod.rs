//! Execution backends behind one engine contract.
//!
//! * [`artifact`] — manifest.json parsing and the artifact registry;
//! * [`engine`] — the PJRT backend: HLO text -> compile -> execute with
//!   an executable cache (PJRT handles are `Rc`-based and not Send),
//!   plus the backend-agnostic pieces of the contract: the [`Backend`]
//!   trait, the typed [`EngineError`] taxonomy, and the [`Capability`]
//!   probe result;
//! * [`handle`] — a Send + Clone handle the multi-threaded coordinator
//!   talks to; wraps either a dedicated PJRT engine thread or (under
//!   `--features vaccel`) a shared virtual accelerator;
//! * [`vaccel`] *(feature-gated)* — the virtual accelerator backend:
//!   compiled `ExecPlan`s specialized once at load into linear programs
//!   and executed on a bounded device-style worker queue.

pub mod artifact;
pub mod engine;
pub mod handle;
#[cfg(feature = "vaccel")]
pub mod vaccel;

pub use artifact::{ArtifactMeta, Registry, TensorSpec};
pub use engine::{Backend, Capability, Engine, EngineError, EngineStats};
pub use handle::EngineHandle;
#[cfg(feature = "vaccel")]
pub use vaccel::VaccelEngine;
