//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) produced
//! by `make artifacts` and executes them on the CPU PJRT client.
//!
//! * [`artifact`] — manifest.json parsing and the artifact registry;
//! * [`engine`] — single-threaded engine: HLO text -> compile -> execute,
//!   with an executable cache (PJRT handles are `Rc`-based and not Send);
//! * [`handle`] — a Send + Clone handle that owns an engine on a dedicated
//!   thread and serializes execution requests through a channel; this is
//!   what the multi-threaded coordinator talks to.

pub mod artifact;
pub mod engine;
pub mod handle;

pub use artifact::{ArtifactMeta, Registry, TensorSpec};
pub use engine::Engine;
pub use handle::EngineHandle;
