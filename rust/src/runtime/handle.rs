//! Send + Clone handle to an execution backend.
//!
//! The `xla` crate's PJRT client is `Rc`-based, so that engine cannot
//! cross threads: the handle owns a dedicated engine thread and forwards
//! execution requests over an mpsc channel, returning results through
//! one-shot slots.  The feature-gated virtual accelerator
//! ([`super::vaccel::VaccelEngine`]) is `Sync` and is dispatched to
//! directly through a shared `Arc`.  Either way the coordinator workers
//! see one uniform, backend-agnostic handle: `execute` / `prepare` /
//! `stats` / [`EngineHandle::capability`] /
//! [`EngineHandle::backend_name`].

use super::artifact::Registry;
use super::engine::{Capability, Engine, EngineStats};
use crate::tensor::Tensor;
use crate::util::threadpool::OneShot;
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

enum Request {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: OneShot<Result<Vec<Tensor>>>,
    },
    Prepare {
        name: String,
        reply: OneShot<Result<()>>,
    },
    Stats {
        reply: OneShot<EngineStats>,
    },
    Capability {
        reply: OneShot<Capability>,
    },
    Shutdown,
}

/// Cloneable, Send handle to an execution backend (a dedicated PJRT
/// engine thread, or a shared virtual accelerator under
/// `--features vaccel`).
pub struct EngineHandle {
    inner: HandleInner,
}

enum HandleInner {
    Pjrt {
        tx: Sender<Request>,
        // joined on explicit shutdown; detached otherwise
        _thread: std::sync::Arc<EngineThread>,
    },
    #[cfg(feature = "vaccel")]
    Vaccel(std::sync::Arc<super::vaccel::VaccelEngine>),
}

impl Clone for EngineHandle {
    fn clone(&self) -> Self {
        let inner = match &self.inner {
            HandleInner::Pjrt { tx, _thread } => HandleInner::Pjrt {
                tx: tx.clone(),
                _thread: std::sync::Arc::clone(_thread),
            },
            #[cfg(feature = "vaccel")]
            HandleInner::Vaccel(engine) => HandleInner::Vaccel(std::sync::Arc::clone(engine)),
        };
        EngineHandle { inner }
    }
}

struct EngineThread {
    tx: Sender<Request>,
    join: std::sync::Mutex<Option<JoinHandle<()>>>,
}

impl Drop for EngineThread {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.join.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl EngineHandle {
    /// Spawn a PJRT engine thread over a registry.
    pub fn spawn(registry: Registry) -> Result<EngineHandle> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("tina-engine".into())
            .spawn(move || {
                let engine = match Engine::new(registry) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Request::Execute {
                            name,
                            inputs,
                            reply,
                        } => {
                            reply.set(engine.execute(&name, &inputs));
                        }
                        Request::Prepare { name, reply } => {
                            reply.set(engine.prepare(&name).map(|_| ()));
                        }
                        Request::Stats { reply } => reply.set(engine.stats()),
                        Request::Capability { reply } => reply.set(engine.capability()),
                        Request::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(EngineHandle {
            inner: HandleInner::Pjrt {
                tx: tx.clone(),
                _thread: std::sync::Arc::new(EngineThread {
                    tx,
                    join: std::sync::Mutex::new(Some(join)),
                }),
            },
        })
    }

    /// Spawn from an artifact directory.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<EngineHandle> {
        Self::spawn(Registry::load(dir)?)
    }

    /// Wrap a shared virtual accelerator — no dedicated thread; the
    /// engine is `Sync` and calls dispatch directly into its bounded
    /// worker queue.
    #[cfg(feature = "vaccel")]
    pub fn vaccel(engine: std::sync::Arc<super::vaccel::VaccelEngine>) -> EngineHandle {
        EngineHandle {
            inner: HandleInner::Vaccel(engine),
        }
    }

    /// Stable name of the backend this handle dispatches to.
    pub fn backend_name(&self) -> &'static str {
        match &self.inner {
            HandleInner::Pjrt { .. } => "pjrt",
            #[cfg(feature = "vaccel")]
            HandleInner::Vaccel(_) => "vaccel",
        }
    }

    /// Typed capability probe of the underlying backend.  A dead engine
    /// thread reports as not-executable rather than erroring.
    pub fn capability(&self) -> Capability {
        match &self.inner {
            HandleInner::Pjrt { tx, .. } => {
                let reply = OneShot::new();
                if tx
                    .send(Request::Capability {
                        reply: reply.clone(),
                    })
                    .is_err()
                {
                    return Capability {
                        backend: "pjrt",
                        can_execute: false,
                        detail: "engine thread gone".to_string(),
                    };
                }
                reply.wait()
            }
            #[cfg(feature = "vaccel")]
            HandleInner::Vaccel(engine) => engine.capability(),
        }
    }

    /// Execute an artifact (blocking until the backend replies).
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        match &self.inner {
            HandleInner::Pjrt { tx, .. } => {
                let reply = OneShot::new();
                tx.send(Request::Execute {
                    name: name.to_string(),
                    inputs,
                    reply: reply.clone(),
                })
                .map_err(|_| anyhow!("engine thread gone"))?;
                reply.wait()
            }
            #[cfg(feature = "vaccel")]
            HandleInner::Vaccel(engine) => engine.execute(name, &inputs),
        }
    }

    /// Warm the backend's per-artifact state (executable cache / loaded
    /// program check).
    pub fn prepare(&self, name: &str) -> Result<()> {
        match &self.inner {
            HandleInner::Pjrt { tx, .. } => {
                let reply = OneShot::new();
                tx.send(Request::Prepare {
                    name: name.to_string(),
                    reply: reply.clone(),
                })
                .map_err(|_| anyhow!("engine thread gone"))?;
                reply.wait()
            }
            #[cfg(feature = "vaccel")]
            HandleInner::Vaccel(engine) => {
                use super::engine::Backend;
                engine.prepare(name)
            }
        }
    }

    /// Backend-side statistics snapshot.
    pub fn stats(&self) -> Result<EngineStats> {
        match &self.inner {
            HandleInner::Pjrt { tx, .. } => {
                let reply = OneShot::new();
                tx.send(Request::Stats {
                    reply: reply.clone(),
                })
                .map_err(|_| anyhow!("engine thread gone"))?;
                Ok(reply.wait())
            }
            #[cfg(feature = "vaccel")]
            HandleInner::Vaccel(engine) => Ok(engine.stats()),
        }
    }
}
