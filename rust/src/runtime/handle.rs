//! Send + Clone handle to an [`Engine`] running on its own thread.
//!
//! The `xla` crate's PJRT client is `Rc`-based, so the engine itself cannot
//! cross threads.  `EngineHandle` owns a dedicated engine thread and
//! forwards execution requests over an mpsc channel, returning results
//! through one-shot slots.  This is the execution backend the coordinator
//! workers share.

use super::artifact::Registry;
use super::engine::{Engine, EngineStats};
use crate::tensor::Tensor;
use crate::util::threadpool::OneShot;
use anyhow::{anyhow, Result};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

enum Request {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: OneShot<Result<Vec<Tensor>>>,
    },
    Prepare {
        name: String,
        reply: OneShot<Result<()>>,
    },
    Stats {
        reply: OneShot<EngineStats>,
    },
    Shutdown,
}

/// Cloneable, Send handle to a dedicated engine thread.
pub struct EngineHandle {
    tx: Sender<Request>,
    // joined on explicit shutdown; detached otherwise
    _thread: std::sync::Arc<EngineThread>,
}

impl Clone for EngineHandle {
    fn clone(&self) -> Self {
        EngineHandle {
            tx: self.tx.clone(),
            _thread: std::sync::Arc::clone(&self._thread),
        }
    }
}

struct EngineThread {
    tx: Sender<Request>,
    join: std::sync::Mutex<Option<JoinHandle<()>>>,
}

impl Drop for EngineThread {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.join.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl EngineHandle {
    /// Spawn an engine thread over a registry.
    pub fn spawn(registry: Registry) -> Result<EngineHandle> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("tina-engine".into())
            .spawn(move || {
                let engine = match Engine::new(registry) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Request::Execute {
                            name,
                            inputs,
                            reply,
                        } => {
                            reply.set(engine.execute(&name, &inputs));
                        }
                        Request::Prepare { name, reply } => {
                            reply.set(engine.prepare(&name).map(|_| ()));
                        }
                        Request::Stats { reply } => reply.set(engine.stats()),
                        Request::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(EngineHandle {
            tx: tx.clone(),
            _thread: std::sync::Arc::new(EngineThread {
                tx,
                join: std::sync::Mutex::new(Some(join)),
            }),
        })
    }

    /// Spawn from an artifact directory.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<EngineHandle> {
        Self::spawn(Registry::load(dir)?)
    }

    /// Execute an artifact (blocking until the engine thread replies).
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let reply = OneShot::new();
        self.tx
            .send(Request::Execute {
                name: name.to_string(),
                inputs,
                reply: reply.clone(),
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply.wait()
    }

    /// Warm the executable cache for an artifact.
    pub fn prepare(&self, name: &str) -> Result<()> {
        let reply = OneShot::new();
        self.tx
            .send(Request::Prepare {
                name: name.to_string(),
                reply: reply.clone(),
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        reply.wait()
    }

    /// Engine-side statistics snapshot.
    pub fn stats(&self) -> Result<EngineStats> {
        let reply = OneShot::new();
        self.tx
            .send(Request::Stats {
                reply: reply.clone(),
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        Ok(reply.wait())
    }
}
