//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python -m compile.aot`) and resolves variant names to HLO files and
//! ABI metadata.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimension extents.
    pub shape: Vec<usize>,
    /// Element type name (manifest spelling, e.g. "float32").
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count of the spec.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("float32")
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One manifest entry: a compiled (op, impl, dtype, size) variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    /// Unique artifact name (registry key).
    pub name: String,
    /// Op this artifact implements (manifest `op` string).
    pub op: String,
    /// "tina" or "jaxref".
    pub impl_: String,
    /// Internal compute dtype: "f32" or "bf16" (interface is always f32).
    pub dtype: String,
    /// Op-specific parameters (sizes, taps, branches, batch, ...).
    pub params: BTreeMap<String, f64>,
    /// Input ABI in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output ABI in declaration order.
    pub outputs: Vec<TensorSpec>,
    /// HLO filename relative to the artifact directory.
    pub file: String,
}

impl ArtifactMeta {
    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let s = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("entry missing '{key}'"))
        };
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("entry missing '{key}'"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let mut params = BTreeMap::new();
        if let Some(obj) = j.get("params").and_then(Json::as_obj) {
            for (k, v) in obj {
                if let Some(x) = v.as_f64() {
                    params.insert(k.clone(), x);
                }
            }
        }
        Ok(ArtifactMeta {
            name: s("name")?,
            op: s("op")?,
            impl_: s("impl")?,
            dtype: s("dtype")?,
            params,
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            file: s("file")?,
        })
    }

    /// Batch dimension of the first input (1 when the op has no batch).
    pub fn batch(&self) -> usize {
        self.params.get("batch").map(|&b| b as usize).unwrap_or(1)
    }

    /// Op-specific parameter by name.
    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.get(key).copied()
    }
}

/// The artifact registry: all manifest entries plus the directory they
/// live in.
#[derive(Debug, Clone)]
pub struct Registry {
    dir: PathBuf,
    entries: Vec<ArtifactMeta>,
    by_name: BTreeMap<String, usize>,
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        Self::from_manifest_text(dir, &text)
    }

    /// Parse a manifest from text (exposed for tests).
    pub fn from_manifest_text(dir: PathBuf, text: &str) -> Result<Registry> {
        let doc = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = doc
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing version"))?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let entries = doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing entries"))?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut by_name = BTreeMap::new();
        for (i, e) in entries.iter().enumerate() {
            if by_name.insert(e.name.clone(), i).is_some() {
                bail!("duplicate artifact name '{}'", e.name);
            }
        }
        Ok(Registry {
            dir,
            entries,
            by_name,
        })
    }

    /// Directory the artifacts live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of manifest entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the manifest has no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All manifest entries.
    pub fn entries(&self) -> &[ArtifactMeta] {
        &self.entries
    }

    /// Entry by artifact name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// All artifacts for a given (op, impl, dtype), sorted by name —
    /// what the router sweeps when matching a request.
    pub fn find(&self, op: &str, impl_: &str, dtype: &str) -> Vec<&ArtifactMeta> {
        self.entries
            .iter()
            .filter(|e| e.op == op && e.impl_ == impl_ && e.dtype == dtype)
            .collect()
    }

    /// Verify every referenced HLO file exists on disk.
    pub fn check_files(&self) -> Result<()> {
        for e in &self.entries {
            let p = self.hlo_path(e);
            if !p.is_file() {
                bail!("artifact file missing: {}", p.display());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "version": 1,
      "jax_version": "0.8.2",
      "entries": [
        {"name": "fir_tina_f32_B1_L1024", "op": "fir", "impl": "tina",
         "dtype": "f32", "params": {"l": 1024, "taps": 64, "batch": 1},
         "inputs": [{"shape": [1, 1024], "dtype": "float32"}],
         "outputs": [{"shape": [1, 961], "dtype": "float32"}],
         "file": "fir_tina_f32_B1_L1024.hlo.txt"},
        {"name": "dft_jaxref_f32_B4_N64", "op": "dft", "impl": "jaxref",
         "dtype": "f32", "params": {"n": 64, "batch": 4},
         "inputs": [{"shape": [4, 64], "dtype": "float32"}],
         "outputs": [{"shape": [4, 64], "dtype": "float32"},
                     {"shape": [4, 64], "dtype": "float32"}],
         "file": "dft_jaxref_f32_B4_N64.hlo.txt"}
      ]
    }"#;

    fn registry() -> Registry {
        Registry::from_manifest_text(PathBuf::from("/nonexistent"), MANIFEST).unwrap()
    }

    #[test]
    fn parses_entries() {
        let r = registry();
        assert_eq!(r.len(), 2);
        let fir = r.get("fir_tina_f32_B1_L1024").unwrap();
        assert_eq!(fir.op, "fir");
        assert_eq!(fir.impl_, "tina");
        assert_eq!(fir.batch(), 1);
        assert_eq!(fir.param("taps"), Some(64.0));
        assert_eq!(fir.inputs[0].shape, vec![1, 1024]);
        assert_eq!(fir.outputs[0].elements(), 961);
    }

    #[test]
    fn multi_output_entry() {
        let r = registry();
        let dft = r.get("dft_jaxref_f32_B4_N64").unwrap();
        assert_eq!(dft.outputs.len(), 2);
    }

    #[test]
    fn find_filters() {
        let r = registry();
        assert_eq!(r.find("fir", "tina", "f32").len(), 1);
        assert_eq!(r.find("fir", "jaxref", "f32").len(), 0);
        assert_eq!(r.find("dft", "jaxref", "f32").len(), 1);
    }

    #[test]
    fn missing_files_detected() {
        let r = registry();
        assert!(r.check_files().is_err());
    }

    #[test]
    fn rejects_bad_version_and_duplicates() {
        let bad = MANIFEST.replace("\"version\": 1", "\"version\": 9");
        assert!(Registry::from_manifest_text(PathBuf::new(), &bad).is_err());
        let dup = MANIFEST.replace("dft_jaxref_f32_B4_N64", "fir_tina_f32_B1_L1024");
        assert!(Registry::from_manifest_text(PathBuf::new(), &dup).is_err());
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(registry().get("nope").is_none());
    }
}
