//! Slab arena backing the planned executor.
//!
//! A plan's liveness analysis maps every *materialized* value to one of a
//! small set of *slots*; two values share a slot exactly when their
//! lifetimes are disjoint.  Strided views (transposes, permutes, slices,
//! reshapes) occupy no slot at all — they alias their backing value's
//! slot, and the plan's liveness pass keeps that slot live until the last
//! view consumer has run.  Slot sizes therefore derive from materialized
//! extents only.  At run time the arena is just those slots as reusable
//! `Vec<f32>` buffers: `prepare` grows them to the plan's high-water sizes
//! once, and repeat executions (the serving steady state) touch the
//! allocator not at all — the GPTPU/ONNX-to-hardware lesson of amortizing
//! planning and buffer setup across invocations.

/// Reusable buffer slab.  One arena serves one plan execution at a time;
/// [`super::Planned`] keeps a pool of them for concurrent requests.
#[derive(Debug, Default)]
pub struct Arena {
    slots: Vec<Vec<f32>>,
}

impl Arena {
    /// Empty arena (slots materialize on first prepare).
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Ensure at least `sizes.len()` slots exist with `slots[i].len() >=
    /// sizes[i]`.  Buffers are kept across calls — repeat executions of the
    /// same plan never reallocate.
    pub fn prepare(&mut self, sizes: &[usize]) {
        if self.slots.len() < sizes.len() {
            self.slots.resize_with(sizes.len(), Vec::new);
        }
        for (slot, &n) in self.slots.iter_mut().zip(sizes) {
            if slot.len() < n {
                slot.resize(n, 0.0);
            }
        }
    }

    /// Borrow a slot's buffer (contents beyond the live value are garbage).
    pub fn slot(&self, i: usize) -> &[f32] {
        &self.slots[i]
    }

    /// Detach a slot's buffer for writing (put it back with [`Arena::put`]).
    pub fn take(&mut self, i: usize) -> Vec<f32> {
        std::mem::take(&mut self.slots[i])
    }

    /// Re-attach a buffer taken with [`Arena::take`].
    pub fn put(&mut self, i: usize, buf: Vec<f32>) {
        self.slots[i] = buf;
    }

    /// Number of slots currently materialized.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Total bytes resident across all slots.
    pub fn allocated_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.capacity() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_sizes_slots_and_keeps_capacity() {
        let mut a = Arena::new();
        a.prepare(&[4, 16]);
        assert_eq!(a.slot_count(), 2);
        assert_eq!(a.slot(0).len(), 4);
        assert_eq!(a.slot(1).len(), 16);
        let bytes = a.allocated_bytes();
        // re-preparing with smaller sizes must not shrink or reallocate
        a.prepare(&[2, 8]);
        assert_eq!(a.slot(1).len(), 16);
        assert_eq!(a.allocated_bytes(), bytes);
        // growing one slot only grows that slot
        a.prepare(&[4, 32]);
        assert!(a.slot(1).len() >= 32);
    }

    #[test]
    fn take_put_roundtrip_preserves_contents() {
        let mut a = Arena::new();
        a.prepare(&[3]);
        let mut buf = a.take(0);
        buf[..3].copy_from_slice(&[1.0, 2.0, 3.0]);
        a.put(0, buf);
        assert_eq!(&a.slot(0)[..3], &[1.0, 2.0, 3.0]);
    }
}
