//! [`ExecPlan`] -> [`LinearProgram`] specialization: the virtual
//! accelerator's load-time "JIT".
//!
//! The planned executor re-resolves every step argument on every
//! dispatch: locate the backing buffer, re-derive stride triples and
//! split tables from the stored [`View`]s, re-check contiguity, re-read
//! kernel dims out of argument shapes.  A device runtime does that work
//! once, when an artifact is *loaded*: this module walks a compiled plan
//! and bakes each step down to a [`LinearStep`] — the kernel thunk
//! selected once, strides/split tables pre-extracted into fixed arrays,
//! dense argument ranges pre-sliced to `(start, len)` windows, output
//! lengths pre-multiplied — so execution is a straight walk over a flat
//! step list with zero per-dispatch decisions.
//!
//! Buffer space is fixed at load too: a [`LinearProgram`] knows its slot
//! sizes up front, and each pooled execution state pre-allocates every
//! slot exactly once (the planned executor's `Arena::prepare` grow-only
//! check runs per execution; here it does not exist at all).
//!
//! # Oracle contract
//!
//! The specialization is *structural only*.  Every [`LinearStep`]
//! dispatches into the exact same [`fused`] kernels as the planned
//! executor, with bit-identical dims, strides, split tables and packed
//! panels — so the per-element reduction order, and therefore the f32
//! rounding, is unchanged, and linear-program output is **bit-for-bit**
//! equal to both the planned executor and the interpreter oracle.  The
//! differential fuzzer (`rust/tests/properties.rs`) asserts this on
//! every random graph, with the fusion pass on and off.
//!
//! This module is deliberately independent of the `vaccel` cargo
//! feature: the specializer is pure compute (the benches ablate it
//! without any feature flags); `runtime::vaccel` wraps it with device
//! semantics (explicit load/unload, capability probe, bounded worker
//! queue, typed errors).

use super::fused;
use super::plan::ExecPlan;
use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Result};
use std::sync::Mutex;

/// Pooled execution states kept per program (mirrors the planned
/// executor's arena pool cap).
const STATE_POOL_CAP: usize = 8;

/// Where a pre-resolved argument's bytes live at execution time.
#[derive(Debug, Clone, Copy)]
enum Src {
    /// Caller input `i` (never copied).
    External(usize),
    /// Program-owned constant `k` (cloned from the plan at load).
    Const(usize),
    /// Execution-state slot `s` (pre-allocated at load size).
    Slot(usize),
}

/// A dense argument window, pre-sliced at load: `data[start..start+len]`.
#[derive(Debug, Clone, Copy)]
struct DenseArg {
    src: Src,
    start: usize,
    len: usize,
}

/// A strided rank-3 activation window with the stride triple and
/// optional split table pre-extracted at load.
#[derive(Debug, Clone, Copy)]
struct X3Arg {
    src: Src,
    off: usize,
    s: [usize; 3],
    split0: Option<(usize, usize)>,
    /// Pre-extracted `(tracks, cin, w)` kernel dims.
    dims: (usize, usize, usize),
}

/// A strided rank-2 activation window (FC path; never split).
#[derive(Debug, Clone, Copy)]
struct X2Arg {
    src: Src,
    off: usize,
    s: [usize; 2],
    /// Pre-extracted `(rows, cin)` kernel dims.
    dims: (usize, usize),
}

/// The weight operand of a matmul-family thunk: either a dense window or
/// an index into the program's pre-packed NR panels.
#[derive(Debug, Clone)]
enum Weight {
    Dense(DenseArg),
    Packed(usize),
}

/// One fully pre-resolved kernel thunk.  Each variant carries exactly
/// the values its [`fused`] kernel call needs — nothing is re-derived
/// at dispatch time.
#[derive(Debug, Clone)]
enum Thunk {
    Depthwise {
        x: X3Arg,
        k: DenseArg,
        m: usize,
        bias: DenseArg,
    },
    Standard {
        x: X3Arg,
        k: DenseArg,
        /// Pre-extracted `(cout, taps)` of the kernel tensor.
        ks: (usize, usize),
        bias: DenseArg,
    },
    Pointwise {
        x: X3Arg,
        w: Weight,
        cout: usize,
        bias: DenseArg,
    },
    FullyConnected {
        x: X2Arg,
        w: Weight,
        cout: usize,
        bias: DenseArg,
    },
    Materialize {
        src: Src,
        off: usize,
        shape: Vec<usize>,
        strides: Vec<usize>,
    },
    FusedEw {
        terms: Vec<(f32, DenseArg)>,
    },
}

/// One step of the lowered linear program: a thunk plus its pre-sized
/// output window.
#[derive(Debug, Clone)]
struct LinearStep {
    thunk: Thunk,
    out_slot: usize,
    out_len: usize,
}

/// A pre-resolved program output gather.
#[derive(Debug, Clone)]
struct LinearOutput {
    src: Src,
    off: usize,
    shape: Vec<usize>,
    strides: Vec<usize>,
    /// Dense fast path decided at load: contiguous outputs slice,
    /// view-shaped outputs gather through [`fused::materialize`].
    contiguous: bool,
    numel: usize,
}

/// Per-execution mutable state: one pre-allocated buffer per slot,
/// sized exactly once at load.  States are pooled on the program.
#[derive(Debug, Default)]
struct LinearState {
    slots: Vec<Vec<f32>>,
}

impl LinearState {
    fn sized(slot_sizes: &[usize]) -> LinearState {
        LinearState {
            slots: slot_sizes.iter().map(|&n| vec![0.0f32; n]).collect(),
        }
    }

    /// Move a slot's buffer out for mutation (put back after the thunk).
    fn take(&mut self, i: usize) -> Vec<f32> {
        std::mem::take(&mut self.slots[i])
    }

    fn put(&mut self, i: usize, buf: Vec<f32>) {
        self.slots[i] = buf;
    }

    fn slot(&self, i: usize) -> &[f32] {
        &self.slots[i]
    }
}

/// A compiled plan lowered to the virtual accelerator's linear form:
/// constants and packed panels owned by the program, every step a
/// pre-selected kernel thunk with pre-resolved strides/splits/ranges,
/// slot sizes fixed at load, and a pool of pre-allocated execution
/// states.  Immutable after load; `Send + Sync` (one loaded program
/// serves many concurrent executions, like [`super::Planned`]).
#[derive(Debug)]
pub struct LinearProgram {
    input_shapes: Vec<Vec<usize>>,
    constants: Vec<Tensor>,
    packed: Vec<Vec<f32>>,
    steps: Vec<LinearStep>,
    slot_sizes: Vec<usize>,
    outputs: Vec<LinearOutput>,
    states: Mutex<Vec<LinearState>>,
}

impl LinearProgram {
    /// Specialize a compiled plan into its linear form.  All structural
    /// validation the planned executor defers to dispatch time (argument
    /// contiguity, stride ranks, split placement) happens here, once;
    /// a plan that violates the kernel ABI fails to *load* instead of
    /// panicking mid-execution.
    pub fn load(plan: &ExecPlan) -> Result<LinearProgram> {
        let steps = plan
            .steps
            .iter()
            .enumerate()
            .map(|(i, s)| lower_step(plan, i, s))
            .collect::<Result<Vec<_>>>()?;
        let outputs = plan
            .outputs
            .iter()
            .map(|o| LinearOutput {
                src: lower_src(&o.loc),
                off: o.view.offset,
                shape: o.view.shape.clone(),
                strides: o.view.strides.clone(),
                contiguous: o.view.is_contiguous(),
                numel: o.view.numel(),
            })
            .collect();
        Ok(LinearProgram {
            input_shapes: plan.input_shapes.clone(),
            constants: plan.constants.clone(),
            packed: plan.packed.clone(),
            steps,
            slot_sizes: plan.slot_sizes.clone(),
            outputs,
            states: Mutex::new(Vec::new()),
        })
    }

    /// Number of lowered steps (== the plan's kernel step count).
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// Total bytes of pre-allocated slot space per execution state.
    pub fn state_bytes(&self) -> usize {
        self.slot_sizes.iter().sum::<usize>() * std::mem::size_of::<f32>()
    }

    /// Declared input shapes (the program's ABI).
    pub fn input_shapes(&self) -> &[Vec<usize>] {
        &self.input_shapes
    }

    /// Output shapes in declaration order.
    pub fn output_shapes(&self) -> Vec<Vec<usize>> {
        self.outputs.iter().map(|o| o.shape.clone()).collect()
    }

    /// Execute once, pooling the execution state.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut state = self.pop_state();
        let result = self.run_in(&mut state, inputs);
        self.push_state(state);
        result
    }

    /// Execute a batched program once, then scatter the first `rows`
    /// rows of every output into per-request tensors (leading dim 1) —
    /// mirrors [`ExecPlan::run_rows_in`] for the batched artifact arm.
    pub fn run_rows(&self, inputs: &[Tensor], rows: usize) -> Result<Vec<Vec<Tensor>>> {
        if rows == 0 {
            bail!("run_rows needs at least one row");
        }
        for (oi, o) in self.outputs.iter().enumerate() {
            if o.shape.is_empty() || o.shape[0] < rows {
                bail!("output {oi} shape {:?} cannot scatter {rows} rows", o.shape);
            }
        }
        let mut state = self.pop_state();
        let result = self.execute(&mut state, inputs).and_then(|()| {
            (0..rows)
                .map(|r| {
                    self.outputs
                        .iter()
                        .map(|o| {
                            let d = self.bytes(o.src, inputs, &state);
                            let off = o.off + r * o.strides[0];
                            let rest_shape = &o.shape[1..];
                            let rest_strides = &o.strides[1..];
                            let n: usize = rest_shape.iter().product();
                            let mut v = vec![0.0f32; n];
                            fused::materialize(d, off, rest_shape, rest_strides, &mut v);
                            let mut shape = Vec::with_capacity(o.shape.len());
                            shape.push(1);
                            shape.extend_from_slice(rest_shape);
                            Tensor::new(&shape, v)
                        })
                        .collect::<Result<Vec<Tensor>>>()
                })
                .collect()
        });
        self.push_state(state);
        result
    }

    fn run_in(&self, state: &mut LinearState, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.execute(state, inputs)?;
        self.outputs
            .iter()
            .map(|o| {
                let d = self.bytes(o.src, inputs, state);
                let data = if o.contiguous {
                    d[o.off..o.off + o.numel].to_vec()
                } else {
                    let mut v = vec![0.0f32; o.numel];
                    fused::materialize(d, o.off, &o.shape, &o.strides, &mut v);
                    v
                };
                Tensor::new(&o.shape, data)
            })
            .collect()
    }

    /// The straight-line dispatch loop: validate the input ABI, then
    /// walk the thunks.  No per-step resolution happens here — every
    /// stride, range and dim was fixed at load.
    fn execute(&self, state: &mut LinearState, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.input_shapes.len() {
            bail!(
                "expected {} inputs, got {}",
                self.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (t, shape)) in inputs.iter().zip(&self.input_shapes).enumerate() {
            if t.shape() != shape.as_slice() {
                bail!("input {i} shape {:?} != declared {:?}", t.shape(), shape);
            }
        }
        for step in &self.steps {
            let mut out_buf = state.take(step.out_slot);
            {
                let out = &mut out_buf[..step.out_len];
                match &step.thunk {
                    Thunk::Depthwise { x, k, m, bias } => fused::depthwise_conv(
                        self.x3(x, inputs, state),
                        x.dims,
                        self.dense(*k, inputs, state),
                        *m,
                        self.dense(*bias, inputs, state),
                        out,
                    ),
                    Thunk::Standard { x, k, ks, bias } => fused::standard_conv(
                        self.x3(x, inputs, state),
                        x.dims,
                        self.dense(*k, inputs, state),
                        *ks,
                        self.dense(*bias, inputs, state),
                        out,
                    ),
                    Thunk::Pointwise { x, w, cout, bias } => {
                        let xv = self.x3(x, inputs, state);
                        let b = self.dense(*bias, inputs, state);
                        match w {
                            Weight::Packed(pi) => fused::pointwise_conv_packed(
                                xv,
                                x.dims,
                                &self.packed[*pi],
                                *cout,
                                b,
                                out,
                            ),
                            Weight::Dense(k) => fused::pointwise_conv(
                                xv,
                                x.dims,
                                self.dense(*k, inputs, state),
                                *cout,
                                b,
                                out,
                            ),
                        }
                    }
                    Thunk::FullyConnected { x, w, cout, bias } => {
                        let xv = fused::X2 {
                            d: self.bytes(x.src, inputs, state),
                            off: x.off,
                            s: x.s,
                        };
                        let b = self.dense(*bias, inputs, state);
                        match w {
                            Weight::Packed(pi) => fused::fully_connected_packed(
                                xv,
                                x.dims,
                                &self.packed[*pi],
                                *cout,
                                b,
                                out,
                            ),
                            Weight::Dense(k) => fused::fully_connected(
                                xv,
                                x.dims,
                                self.dense(*k, inputs, state),
                                *cout,
                                b,
                                out,
                            ),
                        }
                    }
                    Thunk::Materialize {
                        src,
                        off,
                        shape,
                        strides,
                    } => {
                        let d = self.bytes(*src, inputs, state);
                        fused::materialize(d, *off, shape, strides, out);
                    }
                    Thunk::FusedEw { terms } => {
                        let bound: Vec<(f32, &[f32])> = terms
                            .iter()
                            .map(|&(sign, a)| (sign, self.dense(a, inputs, state)))
                            .collect();
                        fused::fused_ew(&bound, out);
                    }
                }
            }
            state.put(step.out_slot, out_buf);
        }
        Ok(())
    }

    fn bytes<'a>(&'a self, src: Src, inputs: &'a [Tensor], state: &'a LinearState) -> &'a [f32] {
        match src {
            Src::External(i) => inputs[i].data(),
            Src::Const(k) => self.constants[k].data(),
            Src::Slot(s) => state.slot(s),
        }
    }

    fn dense<'a>(&'a self, a: DenseArg, inputs: &'a [Tensor], state: &'a LinearState) -> &'a [f32] {
        &self.bytes(a.src, inputs, state)[a.start..a.start + a.len]
    }

    fn x3<'a>(&'a self, a: &X3Arg, inputs: &'a [Tensor], state: &'a LinearState) -> fused::X3<'a> {
        fused::X3 {
            d: self.bytes(a.src, inputs, state),
            off: a.off,
            s: a.s,
            split0: a.split0,
        }
    }

    fn pop_state(&self) -> LinearState {
        self.states
            .lock()
            .expect("linear state pool poisoned")
            .pop()
            .unwrap_or_else(|| LinearState::sized(&self.slot_sizes))
    }

    fn push_state(&self, state: LinearState) {
        let mut pool = self.states.lock().expect("linear state pool poisoned");
        if pool.len() < STATE_POOL_CAP {
            pool.push(state);
        }
    }
}

fn lower_src(loc: &super::plan::Loc) -> Src {
    use super::plan::Loc;
    match *loc {
        Loc::External(i) => Src::External(i),
        Loc::Const(k) => Src::Const(k),
        Loc::Slot(s) => Src::Slot(s),
    }
}

/// Pre-resolve a dense (contiguous) argument window, failing the load if
/// the plan handed the kernel a strided operand.
fn lower_dense(step: usize, what: &str, a: &super::plan::ArgRef) -> Result<DenseArg> {
    if !a.view.is_contiguous() {
        bail!("step {step}: {what} operand is not contiguous");
    }
    Ok(DenseArg {
        src: lower_src(&a.loc),
        start: a.view.offset,
        len: a.view.numel(),
    })
}

/// Pre-resolve a rank-3 activation window.
fn lower_x3(step: usize, a: &super::plan::ArgRef) -> Result<X3Arg> {
    if a.view.strides.len() != 3 || a.view.shape.len() != 3 {
        bail!("step {step}: activation is rank {}, want 3", a.view.shape.len());
    }
    Ok(X3Arg {
        src: lower_src(&a.loc),
        off: a.view.offset,
        s: [a.view.strides[0], a.view.strides[1], a.view.strides[2]],
        split0: a.view.split0.map(|sp| (sp.inner, sp.outer_stride)),
        dims: (a.view.shape[0], a.view.shape[1], a.view.shape[2]),
    })
}

fn lower_step(plan: &ExecPlan, i: usize, s: &super::plan::Step) -> Result<LinearStep> {
    use super::plan::Kernel;
    let arg = |n: usize| -> Result<&super::plan::ArgRef> {
        s.args.get(n).ok_or_else(|| anyhow!("step {i}: missing arg {n}"))
    };
    let weight = |packed: &Option<usize>, a: &super::plan::ArgRef| -> Result<Weight> {
        match packed {
            Some(pi) => {
                if *pi >= plan.packed.len() {
                    bail!("step {i}: packed panel {pi} out of range");
                }
                Ok(Weight::Packed(*pi))
            }
            None => Ok(Weight::Dense(lower_dense(i, "weight", a)?)),
        }
    };
    let thunk = match &s.kernel {
        Kernel::DepthwiseConv1d => Thunk::Depthwise {
            x: lower_x3(i, arg(0)?)?,
            k: lower_dense(i, "kernel", arg(1)?)?,
            m: arg(1)?.view.shape[1],
            bias: lower_dense(i, "bias", arg(2)?)?,
        },
        Kernel::StandardConv1d => {
            let ks = &arg(1)?.view.shape;
            Thunk::Standard {
                x: lower_x3(i, arg(0)?)?,
                k: lower_dense(i, "kernel", arg(1)?)?,
                ks: (ks[0], ks[2]),
                bias: lower_dense(i, "bias", arg(2)?)?,
            }
        }
        Kernel::PointwiseConv { packed } => Thunk::Pointwise {
            x: lower_x3(i, arg(0)?)?,
            w: weight(packed, arg(1)?)?,
            cout: arg(1)?.view.shape[1],
            bias: lower_dense(i, "bias", arg(2)?)?,
        },
        Kernel::FullyConnected { packed } => {
            let a = arg(0)?;
            if a.view.split0.is_some() {
                bail!("step {i}: FC activation carries a split view");
            }
            if a.view.strides.len() != 2 {
                bail!("step {i}: FC activation is rank {}, want 2", a.view.strides.len());
            }
            Thunk::FullyConnected {
                x: X2Arg {
                    src: lower_src(&a.loc),
                    off: a.view.offset,
                    s: [a.view.strides[0], a.view.strides[1]],
                    dims: (a.view.shape[0], a.view.shape[1]),
                },
                w: weight(packed, arg(1)?)?,
                cout: arg(1)?.view.shape[1],
                bias: lower_dense(i, "bias", arg(2)?)?,
            }
        }
        Kernel::Materialize { .. } => {
            let a = arg(0)?;
            Thunk::Materialize {
                src: lower_src(&a.loc),
                off: a.view.offset,
                shape: a.view.shape.clone(),
                strides: a.view.strides.clone(),
            }
        }
        Kernel::FusedEw { signs } => {
            if signs.len() != s.args.len() {
                bail!("step {i}: {} signs for {} args", signs.len(), s.args.len());
            }
            Thunk::FusedEw {
                terms: signs
                    .iter()
                    .zip(&s.args)
                    .map(|(&sign, a)| Ok((sign, lower_dense(i, "ew term", a)?)))
                    .collect::<Result<Vec<_>>>()?,
            }
        }
    };
    let out_len: usize = s.out_shape.iter().product();
    if s.out_slot >= plan.slot_sizes.len() || plan.slot_sizes[s.out_slot] < out_len {
        bail!("step {i}: output slot {} cannot hold {out_len} elements", s.out_slot);
    }
    Ok(LinearStep {
        thunk,
        out_slot: s.out_slot,
        out_len,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::PfbConfig;
    use crate::tina::exec::CompileOptions;
    use crate::tina::interp::Interpreter;
    use crate::tina::lower;

    fn check_bitwise(graph: &crate::tina::graph::Graph, inputs: &[Tensor]) {
        let want = Interpreter::new(graph.clone()).unwrap().run(inputs).unwrap();
        for fusion in [true, false] {
            let plan = ExecPlan::compile_with(
                graph,
                CompileOptions {
                    fusion,
                    verify: true,
                },
            )
            .unwrap();
            let prog = LinearProgram::load(&plan).unwrap();
            let got = prog.run(inputs).unwrap();
            assert_eq!(got.len(), want.len());
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(a.shape(), b.shape(), "output {i} shape (fusion={fusion})");
                assert_eq!(a, b, "output {i} diverged (fusion={fusion})");
            }
        }
    }

    #[test]
    fn linear_program_matches_interpreter_on_shipped_lowerings() {
        let taps = crate::dsp::fir_lowpass(16, 0.25).unwrap();
        let cfg = PfbConfig::new(8, 4);
        check_bitwise(
            &lower::fir(2, 256, &taps).unwrap(),
            &[Tensor::randn(&[2, 256], 11)],
        );
        check_bitwise(
            &lower::pfb(2, 8 * 40, cfg).unwrap(),
            &[Tensor::randn(&[2, 8 * 40], 12)],
        );
        check_bitwise(
            &lower::stft(2, 320, 32, 16).unwrap(),
            &[Tensor::randn(&[2, 320], 13)],
        );
        check_bitwise(
            &lower::matmul(6, 10, 8),
            &[Tensor::randn(&[6, 10], 14), Tensor::randn(&[10, 8], 15)],
        );
        check_bitwise(&lower::dft(2, 16), &[Tensor::randn(&[2, 16], 16)]);
    }

    #[test]
    fn pooled_states_stay_request_safe() {
        let graph = lower::stft(1, 320, 32, 16).unwrap();
        let interp = Interpreter::new(graph.clone()).unwrap();
        let plan = ExecPlan::compile(&graph).unwrap();
        let prog = LinearProgram::load(&plan).unwrap();
        for seed in 0..4u64 {
            let inputs = vec![Tensor::randn(&[1, 320], 100 + seed)];
            let want = interp.run(&inputs).unwrap();
            let got = prog.run(&inputs).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a, b, "stale pooled state leaked into a result");
            }
        }
    }

    #[test]
    fn run_rows_matches_solo_interpreter_with_poison_padding() {
        let (l, nfft, hop) = (320usize, 32usize, 16usize);
        let bucket = 4usize;
        let rows_n = 3usize;
        let solo = Interpreter::new(lower::stft(1, l, nfft, hop).unwrap()).unwrap();
        let plan = ExecPlan::compile(&lower::stft(bucket, l, nfft, hop).unwrap()).unwrap();
        let prog = LinearProgram::load(&plan).unwrap();
        let per_row: Vec<Tensor> =
            (0..rows_n).map(|r| Tensor::randn(&[1, l], 900 + r as u64)).collect();
        let mut data = Vec::with_capacity(bucket * l);
        for r in &per_row {
            data.extend_from_slice(r.data());
        }
        data.resize(bucket * l, 1.0e30); // poison, not the batcher's zeros
        let batched = Tensor::new(&[bucket, l], data).unwrap();
        let got = prog.run_rows(std::slice::from_ref(&batched), rows_n).unwrap();
        for (r, row_in) in per_row.iter().enumerate() {
            let want = solo.run(std::slice::from_ref(row_in)).unwrap();
            for (a, b) in got[r].iter().zip(&want) {
                assert_eq!(a, b, "row {r} diverged or padding leaked");
            }
        }
    }

    #[test]
    fn bad_abi_is_a_load_or_execute_error_not_a_panic() {
        let plan = ExecPlan::compile(&lower::dft(2, 16)).unwrap();
        let prog = LinearProgram::load(&plan).unwrap();
        assert!(prog.run(&[]).is_err(), "arity mismatch must error");
        assert!(
            prog.run(&[Tensor::randn(&[3, 16], 1)]).is_err(),
            "shape mismatch must error"
        );
    }

    #[test]
    fn introspection_reflects_the_loaded_plan() {
        let plan = ExecPlan::compile(&lower::stft(2, 320, 32, 16).unwrap()).unwrap();
        let prog = LinearProgram::load(&plan).unwrap();
        assert_eq!(prog.step_count(), plan.step_count());
        assert_eq!(prog.input_shapes(), plan.input_shapes());
        assert!(prog.state_bytes() > 0);
        assert_eq!(prog.output_shapes().len(), 2, "stft emits re + im");
    }
}
