//! Slice-level compute kernels for the planned executor.
//!
//! These are the same building-block semantics as [`crate::tina::layers`]
//! (identical loop nesting and accumulation order, so results agree with
//! the interpreter to rounding), restructured to
//!
//! * write into caller-provided arena buffers instead of allocating, and
//! * fan independent output rows out across threads via
//!   [`crate::util::threadpool::parallel_for`], gated on a work threshold
//!   so small fallback requests don't pay thread-spawn overhead.
//!
//! The `fused_ew` kernel evaluates a whole `Add`/`Sub` chain
//! (`±a ± b ± c ...`) in a single pass over memory — the planner collapses
//! single-consumer elementwise chains into one of these.

use crate::util::threadpool::{default_threads, parallel_for, SendPtr};

/// Below this many scalar multiply-adds, run single-threaded (spawn
/// overhead of scoped threads is tens of microseconds).
const PAR_THRESHOLD: usize = 64 * 1024;

fn threads_for(rows: usize, work: usize) -> usize {
    if work < PAR_THRESHOLD {
        1
    } else {
        default_threads().min(rows).max(1)
    }
}

/// Eq. (2): depthwise valid 1-D convolution.
/// x: (T, C, W), k: (C, M), b: (C,) -> out: (T, C, W - M + 1).
pub fn depthwise_conv(
    x: &[f32],
    (t, c, w): (usize, usize, usize),
    k: &[f32],
    m: usize,
    b: &[f32],
    out: &mut [f32],
) {
    let wout = w - m + 1;
    debug_assert_eq!(out.len(), t * c * wout);
    let rows = t * c;
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(rows, rows * wout * m), rows, |r0, r1| {
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(r0 * wout), (r1 - r0) * wout) };
        for r in r0..r1 {
            let ci = r % c;
            let xrow = &x[r * w..r * w + w];
            let krow = &k[ci * m..(ci + 1) * m];
            let orow = &mut o[(r - r0) * wout..(r - r0 + 1) * wout];
            orow.fill(0.0);
            for (i, &kv) in krow.iter().enumerate() {
                for (ov, &xv) in orow.iter_mut().zip(&xrow[i..i + wout]) {
                    *ov += kv * xv;
                }
            }
            let bias = b[ci];
            for ov in orow.iter_mut() {
                *ov += bias;
            }
        }
    });
}

/// Eq. (1): standard valid 1-D convolution with channel mixing.
/// x: (T, Cin, W), k: (Cout, Cin, N), b: (Cout,) -> out: (T, Cout, W - N + 1).
pub fn standard_conv(
    x: &[f32],
    (t, cin, w): (usize, usize, usize),
    k: &[f32],
    (cout, n): (usize, usize),
    b: &[f32],
    out: &mut [f32],
) {
    let wout = w - n + 1;
    debug_assert_eq!(out.len(), t * cout * wout);
    let rows = t * cout;
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(rows, rows * wout * cin * n), rows, |r0, r1| {
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(r0 * wout), (r1 - r0) * wout) };
        for r in r0..r1 {
            let (ti, co) = (r / cout, r % cout);
            let orow = &mut o[(r - r0) * wout..(r - r0 + 1) * wout];
            orow.fill(0.0);
            for ci in 0..cin {
                let xrow = &x[(ti * cin + ci) * w..(ti * cin + ci + 1) * w];
                let krow = &k[(co * cin + ci) * n..(co * cin + ci + 1) * n];
                for (i, &kv) in krow.iter().enumerate() {
                    if kv == 0.0 {
                        continue;
                    }
                    for (ov, &xv) in orow.iter_mut().zip(&xrow[i..i + wout]) {
                        *ov += kv * xv;
                    }
                }
            }
            let bias = b[co];
            for ov in orow.iter_mut() {
                *ov += bias;
            }
        }
    });
}

/// Eq. (3): pointwise (1x1) convolution mixing channels.
/// x: (T, Cin, S), k: (Cin, Cout), b: (Cout,) -> out: (T, Cout, S).
pub fn pointwise_conv(
    x: &[f32],
    (t, cin, s): (usize, usize, usize),
    k: &[f32],
    cout: usize,
    b: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), t * cout * s);
    let rows = t * cout;
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(rows, rows * s * cin), rows, |r0, r1| {
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(r0 * s), (r1 - r0) * s) };
        for r in r0..r1 {
            let (ti, co) = (r / cout, r % cout);
            let orow = &mut o[(r - r0) * s..(r - r0 + 1) * s];
            orow.fill(0.0);
            for ci in 0..cin {
                let kv = k[ci * cout + co];
                if kv == 0.0 {
                    continue;
                }
                let xrow = &x[(ti * cin + ci) * s..(ti * cin + ci + 1) * s];
                for (ov, &xv) in orow.iter_mut().zip(xrow) {
                    *ov += kv * xv;
                }
            }
            let bias = b[co];
            for ov in orow.iter_mut() {
                *ov += bias;
            }
        }
    });
}

/// Eq. (4): fully connected layer.
/// x: (B, Cin), k: (Cin, Cout), b: (Cout,) -> out: (B, Cout).
pub fn fully_connected(
    x: &[f32],
    (bsz, cin): (usize, usize),
    k: &[f32],
    cout: usize,
    b: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), bsz * cout);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(bsz, bsz * cin * cout), bsz, |b0, b1| {
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(b0 * cout), (b1 - b0) * cout) };
        for bi in b0..b1 {
            let orow = &mut o[(bi - b0) * cout..(bi - b0 + 1) * cout];
            orow.fill(0.0);
            for ci in 0..cin {
                let aik = x[bi * cin + ci];
                if aik == 0.0 {
                    continue;
                }
                let krow = &k[ci * cout..(ci + 1) * cout];
                for (ov, &kv) in orow.iter_mut().zip(krow) {
                    *ov += aik * kv;
                }
            }
            for (ov, &bv) in orow.iter_mut().zip(b) {
                *ov += bv;
            }
        }
    });
}

/// 2-D transpose: x (R, C) -> out (C, R).
pub fn transpose2(x: &[f32], (r, c): (usize, usize), out: &mut [f32]) {
    debug_assert_eq!(out.len(), r * c);
    for i in 0..r {
        for j in 0..c {
            out[j * r + i] = x[i * c + j];
        }
    }
}

/// Rank-3 axis permutation (same index math as `Tensor::permute3`).
pub fn permute3(x: &[f32], s: (usize, usize, usize), perm: [usize; 3], out: &mut [f32]) {
    let s = [s.0, s.1, s.2];
    let os = [s[perm[0]], s[perm[1]], s[perm[2]]];
    debug_assert_eq!(out.len(), s[0] * s[1] * s[2]);
    for i in 0..s[0] {
        for j in 0..s[1] {
            for k in 0..s[2] {
                let idx = [i, j, k];
                let o = [idx[perm[0]], idx[perm[1]], idx[perm[2]]];
                out[(o[0] * os[1] + o[1]) * os[2] + o[2]] = x[(i * s[1] + j) * s[2] + k];
            }
        }
    }
}

/// Strided slice along `axis`: keep indices 0, stride, ..., (count-1)*stride.
pub fn strided_slice(
    x: &[f32],
    shape: &[usize],
    axis: usize,
    stride: usize,
    count: usize,
    out: &mut [f32],
) {
    let outer: usize = shape[..axis].iter().product();
    let inner: usize = shape[axis + 1..].iter().product();
    let extent = shape[axis];
    debug_assert_eq!(out.len(), outer * count * inner);
    for o in 0..outer {
        for i in 0..count {
            let src = (o * extent + i * stride) * inner;
            let dst = (o * count + i) * inner;
            out[dst..dst + inner].copy_from_slice(&x[src..src + inner]);
        }
    }
}

/// Fused elementwise chain: out[i] = sum_k signs[k] * terms[k][i], one pass
/// over memory, accumulated left to right (matching the rounding order of
/// the equivalent Add/Sub node chain).
pub fn fused_ew(terms: &[(f32, &[f32])], out: &mut [f32]) {
    assert!(!terms.is_empty(), "fused_ew needs at least one term");
    let n = out.len();
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(n, n * terms.len()), n, |i0, i1| {
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(i0), i1 - i0) };
        let (s0, t0) = terms[0];
        if s0 == 1.0 {
            o.copy_from_slice(&t0[i0..i1]);
        } else {
            for (ov, &v) in o.iter_mut().zip(&t0[i0..i1]) {
                *ov = s0 * v;
            }
        }
        for &(s, t) in &terms[1..] {
            if s == 1.0 {
                for (ov, &v) in o.iter_mut().zip(&t[i0..i1]) {
                    *ov += v;
                }
            } else {
                for (ov, &v) in o.iter_mut().zip(&t[i0..i1]) {
                    *ov += s * v;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::tina::layers;

    #[test]
    fn depthwise_matches_layers() {
        let x = Tensor::randn(&[3, 5, 20], 1);
        let k = Tensor::randn(&[5, 4], 2);
        let b = Tensor::randn(&[5], 3);
        let want = layers::depthwise_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        depthwise_conv(x.data(), (3, 5, 20), k.data(), 4, b.data(), &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn standard_matches_layers() {
        let x = Tensor::randn(&[2, 3, 30], 4);
        let k = Tensor::randn(&[6, 3, 5], 5);
        let b = Tensor::randn(&[6], 6);
        let want = layers::standard_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        standard_conv(x.data(), (2, 3, 30), k.data(), (6, 5), b.data(), &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn pointwise_matches_layers() {
        let x = Tensor::randn(&[2, 7, 9], 7);
        let k = Tensor::randn(&[7, 4], 8);
        let b = Tensor::randn(&[4], 9);
        let want = layers::pointwise_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        pointwise_conv(x.data(), (2, 7, 9), k.data(), 4, b.data(), &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn fully_connected_matches_layers() {
        let x = Tensor::randn(&[5, 11], 10);
        let k = Tensor::randn(&[11, 3], 11);
        let b = Tensor::randn(&[3], 12);
        let want = layers::fully_connected(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        fully_connected(x.data(), (5, 11), k.data(), 3, b.data(), &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn movement_kernels_match_tensor_ops() {
        let x = Tensor::randn(&[4, 6], 13);
        let mut out = vec![0.0f32; 24];
        transpose2(x.data(), (4, 6), &mut out);
        assert_eq!(out, x.transpose2().unwrap().data());

        let y = Tensor::randn(&[2, 3, 4], 14);
        let mut out = vec![0.0f32; 24];
        permute3(y.data(), (2, 3, 4), [2, 0, 1], &mut out);
        assert_eq!(out, y.permute3([2, 0, 1]).unwrap().data());

        let z = Tensor::randn(&[2, 8, 3], 15);
        let want = z.stride_axis(1, 3, 3).unwrap();
        let mut out = vec![0.0f32; want.len()];
        strided_slice(z.data(), &[2, 8, 3], 1, 3, 3, &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn fused_chain_matches_sequential_adds() {
        let a = Tensor::randn(&[100], 16);
        let b = Tensor::randn(&[100], 17);
        let c = Tensor::randn(&[100], 18);
        let mut out = vec![0.0f32; 100];
        fused_ew(&[(1.0, a.data()), (-1.0, b.data()), (1.0, c.data())], &mut out);
        // identical rounding to (a - b) + c evaluated node by node
        let ab = crate::tensor::sub(&a, &b).unwrap();
        let want = crate::tensor::add(&ab, &c).unwrap();
        assert_eq!(out, want.data());
    }

    #[test]
    fn parallel_path_consistent_with_serial() {
        // large enough to cross PAR_THRESHOLD and engage the thread pool
        let t = 32;
        let x = Tensor::randn(&[t, 16, 260], 19);
        let k = Tensor::randn(&[16, 5], 20);
        let b = Tensor::randn(&[16], 21);
        let want = layers::depthwise_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        depthwise_conv(x.data(), (t, 16, 260), k.data(), 5, b.data(), &mut out);
        assert_eq!(out, want.data());
    }
}
