//! Slice-level compute kernels for the planned executor.
//!
//! These are the same building-block semantics as [`crate::tina::layers`]
//! (identical per-element accumulation order, so results agree with the
//! interpreter bitwise), restructured to
//!
//! * write into caller-provided arena buffers instead of allocating,
//! * read their activation input through a **strided view** ([`X3`]/[`X2`])
//!   so upstream `Transpose2`/`Permute3`/`StridedSlice`/`Reshape` nodes
//!   never have to copy, and
//! * fan independent output rows out across threads via
//!   [`crate::util::threadpool::parallel_for`], gated on a work threshold
//!   so small fallback requests don't pay thread-spawn overhead.
//!
//! # Tiling preserves rounding
//!
//! The packed [`fully_connected_packed`] / [`pointwise_conv_packed`]
//! microkernels block over **output columns only** ([`NR`]-wide panels of
//! pre-packed constant weights) and, for pointwise, over the spatial axis.
//! Both axes are *independent* output coordinates: the reduction over
//! `cin` still runs in ascending order for every output element, with the
//! same `kv == 0.0` / `aik == 0.0` skips as [`crate::tina::layers`] and
//! [`crate::tensor::matmul`].  Each output element therefore sees exactly
//! the f32 operation sequence the interpreter oracle performs — tiling
//! changes memory traffic, never rounding.  Keep that rule when touching
//! these loops: never reassociate the `cin` reduction.
//!
//! Each kernel family *declares* its blocking and reduction order
//! ([`declared_blocking`]); the static verifier checks the declarations
//! against the oracle contract fixed in [`crate::tina::lower`], so a
//! future microkernel that vectorizes the wrong axis fails verification
//! rather than a fuzzer lottery.
//!
//! The `fused_ew` kernel evaluates a whole `Add`/`Sub` chain
//! (`±a ± b ± c ...`) in a single pass over memory — the planner collapses
//! single-consumer elementwise chains into one of these.

use crate::util::threadpool::{default_threads, parallel_for, SendPtr};

/// Register-tile width over output columns for the packed microkernels.
/// Eight f32 lanes = one AVX2 vector; the compiler autovectorizes the
/// fixed-size inner loops.
pub const NR: usize = 8;

/// Spatial tile of the pointwise microkernel (NR x SR f32 accumulators
/// live on the stack).
const SR: usize = 16;

/// Cache tile (elements per side) of the [`materialize`] gather kernel.
const TILE: usize = 32;

/// Below this many scalar multiply-adds, run single-threaded (spawn
/// overhead of scoped threads is tens of microseconds).
const PAR_THRESHOLD: usize = 64 * 1024;

fn threads_for(rows: usize, work: usize) -> usize {
    if work < PAR_THRESHOLD {
        1
    } else {
        default_threads().min(rows).max(1)
    }
}

// ---------------------------------------------------------------------------
// Reduction-order certificates
// ---------------------------------------------------------------------------

/// Loop axes a kernel may block (tile / parallelize) over or reduce
/// along.  Referenced by the [`Blocking`] declarations below and by the
/// oracle contract tables in [`crate::tina::lower`]; the static verifier
/// compares the two.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// Leading output axis (batch / time rows).
    T,
    /// Output channel axis.
    Cout,
    /// Depthwise channel axis (an input *and* output coordinate — no
    /// mixing happens along it).
    C,
    /// Spatial (within-row) output axis.
    Spatial,
    /// Input-channel reduction axis.
    Cin,
    /// Convolution tap reduction axis.
    Tap,
    /// Elementwise-chain term axis (accumulated left to right).
    Term,
    /// Flat element axis of a copy / elementwise kernel.
    Elem,
}

/// Kernel families of the planned executor, mirroring the plan IR's
/// kernel variants.  Packed and unpacked weight paths declare separately
/// — they tile differently.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelFamily {
    /// [`standard_conv`].
    StandardConv,
    /// [`depthwise_conv`].
    DepthwiseConv,
    /// [`pointwise_conv`].
    PointwiseConv,
    /// [`pointwise_conv_packed`].
    PointwiseConvPacked,
    /// [`fully_connected`].
    FullyConnected,
    /// [`fully_connected_packed`].
    FullyConnectedPacked,
    /// [`materialize`].
    Materialize,
    /// [`fused_ew`].
    FusedEw,
}

/// What a microkernel implementation declares about its loop structure:
/// the axes it blocks, tiles or fans across threads, and the exact
/// per-output-element order of its reduction axes.  The static verifier
/// checks every declaration against the oracle contract
/// ([`crate::tina::lower::oracle_reduction_order`] /
/// [`crate::tina::lower::oracle_output_axes`]): the reduction order must
/// match the oracle exactly, and blocking may only touch independent
/// output coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Blocking {
    /// Axes the kernel blocks, tiles, or fans across threads.  Must all
    /// be independent output coordinates — blocking a reduction axis
    /// would reassociate the f32 accumulation.
    pub blocked: &'static [Axis],
    /// Reduction axes per output element, outermost loop first.
    pub reduction: &'static [Axis],
}

/// Declared blocking of each kernel family's implementation in this
/// module.  Keep these in sync with the loops: the declarations are what
/// the static verifier certifies, so an implementation change that
/// re-tiles a reduction axis must update its declaration here — and will
/// then be rejected by the verifier's oracle comparison.
pub fn declared_blocking(f: KernelFamily) -> Blocking {
    match f {
        // parallel_for over t*cout output rows; per element: ci outer,
        // taps inner, both ascending, with the oracle's kv == 0.0 skip
        KernelFamily::StandardConv => Blocking {
            blocked: &[Axis::T, Axis::Cout],
            reduction: &[Axis::Cin, Axis::Tap],
        },
        // parallel_for over t*c rows; taps accumulate in ascending order
        KernelFamily::DepthwiseConv => Blocking {
            blocked: &[Axis::T, Axis::C],
            reduction: &[Axis::Tap],
        },
        // parallel_for over t*cout rows; cin ascending per element
        KernelFamily::PointwiseConv => Blocking {
            blocked: &[Axis::T, Axis::Cout],
            reduction: &[Axis::Cin],
        },
        // NR-wide cout panels x SR-wide spatial tiles (both output
        // coordinates); cin streams ascending through the packed panel
        KernelFamily::PointwiseConvPacked => Blocking {
            blocked: &[Axis::T, Axis::Cout, Axis::Spatial],
            reduction: &[Axis::Cin],
        },
        // parallel_for over batch rows, cout streamed within; cin
        // ascending per element
        KernelFamily::FullyConnected => Blocking {
            blocked: &[Axis::T, Axis::Cout],
            reduction: &[Axis::Cin],
        },
        // NR-wide cout panels per batch row; cin ascending
        KernelFamily::FullyConnectedPacked => Blocking {
            blocked: &[Axis::T, Axis::Cout],
            reduction: &[Axis::Cin],
        },
        // pure gather: TILE x TILE cache blocks over output elements
        KernelFamily::Materialize => Blocking {
            blocked: &[Axis::Elem],
            reduction: &[],
        },
        // chain terms accumulate left to right over disjoint index spans
        KernelFamily::FusedEw => Blocking {
            blocked: &[Axis::Elem],
            reduction: &[Axis::Term],
        },
    }
}

/// Borrowed rank-3 strided input: backing slice + element offset + per-axis
/// element strides.  `at(i, j, k) = d[off + i*s[0] + j*s[1] + k*s[2]]`.
///
/// `split0` optionally decomposes the leading axis into two levels —
/// logical row `i` contributes `(i / inner) * outer_stride +
/// (i % inner) * s[0]` instead of `i * s[0]`.  This is how the planner's
/// fusion pass expresses a merged-axis regrouping (batched STFT's
/// `(B, F, nfft) -> (B*F, nfft)` framing) without a copy: the kernels
/// pay one divide/modulo per output *row*, not per element.
#[derive(Clone, Copy)]
pub struct X3<'a> {
    /// Backing slice.
    pub d: &'a [f32],
    /// Element offset of the view's origin.
    pub off: usize,
    /// Per-axis element strides.
    pub s: [usize; 3],
    /// Optional `(inner extent, outer stride)` split of the leading axis.
    pub split0: Option<(usize, usize)>,
}

impl<'a> X3<'a> {
    /// Dense row-major view of `d` shaped `(t, c, w)`.
    pub fn contiguous(d: &'a [f32], (_t, c, w): (usize, usize, usize)) -> X3<'a> {
        X3 {
            d,
            off: 0,
            s: [c * w, w, 1],
            split0: None,
        }
    }

    /// Leading-axis contribution of logical row `i` (split-aware).
    #[inline(always)]
    fn row(&self, i: usize) -> usize {
        match self.split0 {
            Some((inner, outer)) => (i / inner) * outer + (i % inner) * self.s[0],
            None => i * self.s[0],
        }
    }

    #[inline(always)]
    fn base(&self, i: usize, j: usize) -> usize {
        self.off + self.row(i) + j * self.s[1]
    }

    #[inline(always)]
    fn is_dense(&self, c: usize, w: usize) -> bool {
        self.split0.is_none() && self.s[2] == 1 && self.s[1] == w && self.s[0] == c * w
    }
}

/// Borrowed rank-2 strided input.
#[derive(Clone, Copy)]
pub struct X2<'a> {
    /// Backing slice.
    pub d: &'a [f32],
    /// Element offset of the view's origin.
    pub off: usize,
    /// Per-axis element strides.
    pub s: [usize; 2],
}

impl<'a> X2<'a> {
    /// Dense row-major view of `d` with `cols` columns.
    pub fn contiguous(d: &'a [f32], cols: usize) -> X2<'a> {
        X2 {
            d,
            off: 0,
            s: [cols, 1],
        }
    }

    #[inline(always)]
    fn at(&self, i: usize, j: usize) -> f32 {
        self.d[self.off + i * self.s[0] + j * self.s[1]]
    }
}

/// Pack a row-major (Cin, Cout) weight matrix into [`NR`]-wide column
/// panels: `panels[(jb*cin + ci)*NR + j] = k[ci*cout + jb*NR + j]`, zero
/// padded past `cout`.  Panel `jb` streams contiguously while the
/// microkernel walks `ci`, so constant weights are read cache-line-dense.
pub fn pack_k(k: &[f32], cin: usize, cout: usize) -> Vec<f32> {
    debug_assert_eq!(k.len(), cin * cout);
    let nblk = cout.div_ceil(NR);
    let mut p = vec![0.0f32; nblk * cin * NR];
    for jb in 0..nblk {
        for ci in 0..cin {
            for j in 0..NR {
                let co = jb * NR + j;
                if co < cout {
                    p[(jb * cin + ci) * NR + j] = k[ci * cout + co];
                }
            }
        }
    }
    p
}

/// Eq. (2): depthwise valid 1-D convolution.
/// x: (T, C, W) view, k: (C, M), b: (C,) -> out: (T, C, W - M + 1).
pub fn depthwise_conv(
    x: X3,
    (t, c, w): (usize, usize, usize),
    k: &[f32],
    m: usize,
    b: &[f32],
    out: &mut [f32],
) {
    let wout = w - m + 1;
    debug_assert_eq!(out.len(), t * c * wout);
    let rows = t * c;
    let dense = x.is_dense(c, w);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(rows, rows * wout * m), rows, |r0, r1| {
        // SAFETY: parallel_for hands each worker a disjoint row range
        // [r0, r1); rows map to disjoint spans [r0*wout, r1*wout) of
        // `out`, which is borrowed mutably for the whole scoped-thread
        // region and outlives it.
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(r0 * wout), (r1 - r0) * wout) };
        for r in r0..r1 {
            let (ti, ci) = (r / c, r % c);
            let krow = &k[ci * m..(ci + 1) * m];
            let orow = &mut o[(r - r0) * wout..(r - r0 + 1) * wout];
            orow.fill(0.0);
            if dense {
                let base = x.off + r * w;
                let xrow = &x.d[base..base + w];
                for (i, &kv) in krow.iter().enumerate() {
                    for (ov, &xv) in orow.iter_mut().zip(&xrow[i..i + wout]) {
                        *ov += kv * xv;
                    }
                }
            } else {
                let (base, s2) = (x.base(ti, ci), x.s[2]);
                for (i, &kv) in krow.iter().enumerate() {
                    for (j, ov) in orow.iter_mut().enumerate() {
                        *ov += kv * x.d[base + (i + j) * s2];
                    }
                }
            }
            let bias = b[ci];
            for ov in orow.iter_mut() {
                *ov += bias;
            }
        }
    });
}

/// Eq. (1): standard valid 1-D convolution with channel mixing.
/// x: (T, Cin, W) view, k: (Cout, Cin, N), b: (Cout,) -> out: (T, Cout, W - N + 1).
pub fn standard_conv(
    x: X3,
    (t, cin, w): (usize, usize, usize),
    k: &[f32],
    (cout, n): (usize, usize),
    b: &[f32],
    out: &mut [f32],
) {
    let wout = w - n + 1;
    debug_assert_eq!(out.len(), t * cout * wout);
    let rows = t * cout;
    let dense = x.is_dense(cin, w);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(rows, rows * wout * cin * n), rows, |r0, r1| {
        // SAFETY: parallel_for hands each worker a disjoint row range
        // [r0, r1); rows map to disjoint spans [r0*wout, r1*wout) of
        // `out`, which is borrowed mutably for the whole scoped-thread
        // region and outlives it.
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(r0 * wout), (r1 - r0) * wout) };
        for r in r0..r1 {
            let (ti, co) = (r / cout, r % cout);
            let orow = &mut o[(r - r0) * wout..(r - r0 + 1) * wout];
            orow.fill(0.0);
            for ci in 0..cin {
                let krow = &k[(co * cin + ci) * n..(co * cin + ci + 1) * n];
                if dense {
                    let base = x.off + (ti * cin + ci) * w;
                    let xrow = &x.d[base..base + w];
                    for (i, &kv) in krow.iter().enumerate() {
                        if kv == 0.0 {
                            continue;
                        }
                        for (ov, &xv) in orow.iter_mut().zip(&xrow[i..i + wout]) {
                            *ov += kv * xv;
                        }
                    }
                } else {
                    let (base, s2) = (x.base(ti, ci), x.s[2]);
                    for (i, &kv) in krow.iter().enumerate() {
                        if kv == 0.0 {
                            continue;
                        }
                        for (j, ov) in orow.iter_mut().enumerate() {
                            *ov += kv * x.d[base + (i + j) * s2];
                        }
                    }
                }
            }
            let bias = b[co];
            for ov in orow.iter_mut() {
                *ov += bias;
            }
        }
    });
}

/// Eq. (3): pointwise (1x1) convolution mixing channels (runtime weights).
/// x: (T, Cin, S) view, k: (Cin, Cout), b: (Cout,) -> out: (T, Cout, S).
pub fn pointwise_conv(
    x: X3,
    (t, cin, s): (usize, usize, usize),
    k: &[f32],
    cout: usize,
    b: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), t * cout * s);
    let rows = t * cout;
    let dense = x.is_dense(cin, s);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(rows, rows * s * cin), rows, |r0, r1| {
        // SAFETY: parallel_for hands each worker a disjoint row range
        // [r0, r1); rows map to disjoint spans [r0*s, r1*s) of `out`,
        // which outlives the scoped threads.
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(r0 * s), (r1 - r0) * s) };
        for r in r0..r1 {
            let (ti, co) = (r / cout, r % cout);
            let orow = &mut o[(r - r0) * s..(r - r0 + 1) * s];
            orow.fill(0.0);
            for ci in 0..cin {
                let kv = k[ci * cout + co];
                if kv == 0.0 {
                    continue;
                }
                if dense {
                    let base = x.off + (ti * cin + ci) * s;
                    let xrow = &x.d[base..base + s];
                    for (ov, &xv) in orow.iter_mut().zip(xrow) {
                        *ov += kv * xv;
                    }
                } else {
                    let (base, s2) = (x.base(ti, ci), x.s[2]);
                    for (sv, ov) in orow.iter_mut().enumerate() {
                        *ov += kv * x.d[base + sv * s2];
                    }
                }
            }
            let bias = b[co];
            for ov in orow.iter_mut() {
                *ov += bias;
            }
        }
    });
}

/// Eq. (3) with plan-compile-time pre-packed constant weights: a
/// register-tiled microkernel holding an NR x SR f32 accumulator block.
/// Output columns are tiled NR wide and the spatial axis SR wide; the
/// `cin` reduction per output element is untouched (see module docs).
pub fn pointwise_conv_packed(
    x: X3,
    (t, cin, s): (usize, usize, usize),
    panels: &[f32],
    cout: usize,
    b: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), t * cout * s);
    let nblk = cout.div_ceil(NR);
    debug_assert_eq!(panels.len(), nblk * cin * NR);
    let units = t * nblk;
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(units, t * cout * s * cin), units, |u0, u1| {
        for u in u0..u1 {
            let (ti, jb) = (u / nblk, u % nblk);
            let co0 = jb * NR;
            let jn = NR.min(cout - co0);
            let panel = &panels[jb * cin * NR..(jb + 1) * cin * NR];
            let (s1, s2) = (x.s[1], x.s[2]);
            let tbase = x.off + x.row(ti);
            let mut sv = 0;
            while sv < s {
                let sl = SR.min(s - sv);
                let mut acc = [0.0f32; NR * SR];
                for ci in 0..cin {
                    let krow = &panel[ci * NR..ci * NR + NR];
                    let xbase = tbase + ci * s1 + sv * s2;
                    for (j, &kv) in krow[..jn].iter().enumerate() {
                        if kv == 0.0 {
                            continue;
                        }
                        let accj = &mut acc[j * SR..j * SR + sl];
                        if s2 == 1 {
                            for (a, &xv) in accj.iter_mut().zip(&x.d[xbase..xbase + sl]) {
                                *a += kv * xv;
                            }
                        } else {
                            for (v, a) in accj.iter_mut().enumerate() {
                                *a += kv * x.d[xbase + v * s2];
                            }
                        }
                    }
                }
                for j in 0..jn {
                    let bias = b[co0 + j];
                    // SAFETY: each unit u = (ti, jb) is owned by exactly
                    // one worker (parallel_for chunks [u0, u1) disjointly),
                    // and a unit exclusively owns output rows
                    // ti*cout+co0 .. ti*cout+co0+jn.  Spatial tiles
                    // [sv, sv+sl) within a row are visited serially, so
                    // no two writes to `out` ever overlap.
                    let o = unsafe {
                        std::slice::from_raw_parts_mut(
                            ptr.at((ti * cout + co0 + j) * s + sv),
                            sl,
                        )
                    };
                    for (ov, &av) in o.iter_mut().zip(&acc[j * SR..j * SR + sl]) {
                        *ov = av + bias;
                    }
                }
                sv += sl;
            }
        }
    });
}

/// Eq. (4): fully connected layer (runtime weights).
/// x: (B, Cin) view, k: (Cin, Cout), b: (Cout,) -> out: (B, Cout).
pub fn fully_connected(
    x: X2,
    (bsz, cin): (usize, usize),
    k: &[f32],
    cout: usize,
    b: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), bsz * cout);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(bsz, bsz * cin * cout), bsz, |b0, b1| {
        // SAFETY: parallel_for hands each worker a disjoint batch range
        // [b0, b1); batch rows map to disjoint spans [b0*cout, b1*cout)
        // of `out`, which outlives the scoped threads.
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(b0 * cout), (b1 - b0) * cout) };
        for bi in b0..b1 {
            let orow = &mut o[(bi - b0) * cout..(bi - b0 + 1) * cout];
            orow.fill(0.0);
            for ci in 0..cin {
                let aik = x.at(bi, ci);
                if aik == 0.0 {
                    continue;
                }
                let krow = &k[ci * cout..(ci + 1) * cout];
                for (ov, &kv) in orow.iter_mut().zip(krow) {
                    *ov += aik * kv;
                }
            }
            for (ov, &bv) in orow.iter_mut().zip(b) {
                *ov += bv;
            }
        }
    });
}

/// Eq. (4) with pre-packed constant weights: NR output columns accumulate
/// in registers while one pass streams the packed panel over `cin`.
pub fn fully_connected_packed(
    x: X2,
    (bsz, cin): (usize, usize),
    panels: &[f32],
    cout: usize,
    b: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), bsz * cout);
    let nblk = cout.div_ceil(NR);
    debug_assert_eq!(panels.len(), nblk * cin * NR);
    let units = bsz * nblk;
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(units, bsz * cin * cout), units, |u0, u1| {
        for u in u0..u1 {
            let (bi, jb) = (u / nblk, u % nblk);
            let co0 = jb * NR;
            let jn = NR.min(cout - co0);
            let panel = &panels[jb * cin * NR..(jb + 1) * cin * NR];
            let mut acc = [0.0f32; NR];
            for ci in 0..cin {
                let aik = x.at(bi, ci);
                if aik == 0.0 {
                    continue;
                }
                let krow = &panel[ci * NR..ci * NR + NR];
                for (a, &kv) in acc.iter_mut().zip(krow) {
                    *a += aik * kv;
                }
            }
            // SAFETY: each unit u = (bi, jb) is owned by exactly one
            // worker, and distinct units write distinct spans
            // [bi*cout+co0, bi*cout+co0+jn) of `out` (jn <= NR panels
            // never overlap), so all writes are disjoint.
            let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(bi * cout + co0), jn) };
            for (j, ov) in o.iter_mut().enumerate() {
                *ov = acc[j] + b[co0 + j];
            }
        }
    });
}

/// Gather an arbitrary strided view into a dense row-major buffer — the
/// planner's explicit `Materialize` step, and the output-copy primitive
/// for view-shaped plan outputs.
pub fn materialize(d: &[f32], off: usize, shape: &[usize], strides: &[usize], out: &mut [f32]) {
    debug_assert_eq!(shape.len(), strides.len());
    let n: usize = shape.iter().product();
    debug_assert_eq!(out.len(), n);
    if n == 0 {
        return;
    }
    match shape.len() {
        0 => out[0] = d[off],
        1 => {
            if strides[0] == 1 {
                out.copy_from_slice(&d[off..off + n]);
            } else {
                for (i, ov) in out.iter_mut().enumerate() {
                    *ov = d[off + i * strides[0]];
                }
            }
        }
        2 => materialize2(d, off, (shape[0], shape[1]), (strides[0], strides[1]), out),
        3 => {
            // one parallel_for over (slab, row-tile) units: a single thread
            // spawn covers the whole gather, slabs overlap in time
            let (d0, r, c) = (shape[0], shape[1], shape[2]);
            let (s0, s1, s2) = (strides[0], strides[1], strides[2]);
            let slab = r * c;
            let rblocks = r.div_ceil(TILE);
            let units = d0 * rblocks;
            let ptr = SendPtr(out.as_mut_ptr());
            parallel_for(threads_for(units, n), units, |u0, u1| {
                for u in u0..u1 {
                    let (i, bi) = (u / rblocks, u % rblocks);
                    materialize2_rows(
                        d,
                        off + i * s0,
                        bi * TILE,
                        (bi * TILE + TILE).min(r),
                        c,
                        (s1, s2),
                        SendPtr(ptr.at(i * slab)),
                    );
                }
            });
        }
        _ => {
            let inner = n / shape[0];
            for (i, orow) in out.chunks_mut(inner).enumerate() {
                materialize(d, off + i * strides[0], &shape[1..], &strides[1..], orow);
            }
        }
    }
}

/// Rank-2 strided gather into a dense (r, c) buffer: TILE x TILE cache
/// blocks (the classic blocked transpose, so a column-striding read never
/// thrashes), row-tile blocks fanned across threads.
fn materialize2(
    d: &[f32],
    off: usize,
    (r, c): (usize, usize),
    (s0, s1): (usize, usize),
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), r * c);
    if s1 == 1 && (s0 == c || r == 1) {
        out.copy_from_slice(&d[off..off + r * c]);
        return;
    }
    let rblocks = r.div_ceil(TILE);
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(rblocks, r * c), rblocks, |b0, b1| {
        for bi in b0..b1 {
            materialize2_rows(
                d,
                off,
                bi * TILE,
                (bi * TILE + TILE).min(r),
                c,
                (s0, s1),
                ptr,
            );
        }
    });
}

/// Serial body of one row-tile of a rank-2 gather: rows [i0, i1) of a
/// (_, c) destination whose base pointer is `ptr`, walking TILE-wide
/// column blocks.  Callers guarantee disjoint row ranges across threads.
fn materialize2_rows(
    d: &[f32],
    off: usize,
    i0: usize,
    i1: usize,
    c: usize,
    (s0, s1): (usize, usize),
    ptr: SendPtr,
) {
    let mut j0 = 0;
    while j0 < c {
        let j1 = (j0 + TILE).min(c);
        for i in i0..i1 {
            // SAFETY: callers guarantee disjoint row ranges [i0, i1)
            // across threads (see fn doc); within this serial body each
            // (i, column block) pair is visited once, so the spans
            // [i*c+j0, i*c+j1) written here never overlap.
            let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(i * c + j0), j1 - j0) };
            let base = off + i * s0 + j0 * s1;
            if s1 == 1 {
                o.copy_from_slice(&d[base..base + (j1 - j0)]);
            } else {
                for (v, ov) in o.iter_mut().enumerate() {
                    *ov = d[base + v * s1];
                }
            }
        }
        j0 = j1;
    }
}

/// Fused elementwise chain: out[i] = sum_k signs[k] * terms[k][i], one pass
/// over memory, accumulated left to right (matching the rounding order of
/// the equivalent Add/Sub node chain).
pub fn fused_ew(terms: &[(f32, &[f32])], out: &mut [f32]) {
    assert!(!terms.is_empty(), "fused_ew needs at least one term");
    let n = out.len();
    let ptr = SendPtr(out.as_mut_ptr());
    parallel_for(threads_for(n, n * terms.len()), n, |i0, i1| {
        // SAFETY: parallel_for hands each worker a disjoint index range
        // [i0, i1) of `out`, which outlives the scoped threads.
        let o = unsafe { std::slice::from_raw_parts_mut(ptr.at(i0), i1 - i0) };
        let (s0, t0) = terms[0];
        if s0 == 1.0 {
            o.copy_from_slice(&t0[i0..i1]);
        } else {
            for (ov, &v) in o.iter_mut().zip(&t0[i0..i1]) {
                *ov = s0 * v;
            }
        }
        for &(s, t) in &terms[1..] {
            if s == 1.0 {
                for (ov, &v) in o.iter_mut().zip(&t[i0..i1]) {
                    *ov += v;
                }
            } else {
                for (ov, &v) in o.iter_mut().zip(&t[i0..i1]) {
                    *ov += s * v;
                }
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::tina::layers;

    #[test]
    fn depthwise_matches_layers() {
        let x = Tensor::randn(&[3, 5, 20], 1);
        let k = Tensor::randn(&[5, 4], 2);
        let b = Tensor::randn(&[5], 3);
        let want = layers::depthwise_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        depthwise_conv(
            X3::contiguous(x.data(), (3, 5, 20)),
            (3, 5, 20),
            k.data(),
            4,
            b.data(),
            &mut out,
        );
        assert_eq!(out, want.data());
    }

    #[test]
    fn depthwise_strided_input_matches_dense() {
        // feed (T, C, W) through a permuted view of a (T, W, C) buffer —
        // the PFB pattern — and require bitwise-equal results
        let (t, c, w) = (2, 6, 17);
        let base = Tensor::randn(&[t, w, c], 31);
        let x = base.permute3([0, 2, 1]).unwrap(); // (t, c, w) dense copy
        let k = Tensor::randn(&[c, 4], 32);
        let b = Tensor::randn(&[c], 33);
        let want = layers::depthwise_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        let xv = X3 {
            d: base.data(),
            off: 0,
            s: [w * c, 1, c], // strided (t, c, w) window on the (t, w, c) buffer
            split0: None,
        };
        depthwise_conv(xv, (t, c, w), k.data(), 4, b.data(), &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn standard_matches_layers() {
        let x = Tensor::randn(&[2, 3, 30], 4);
        let k = Tensor::randn(&[6, 3, 5], 5);
        let b = Tensor::randn(&[6], 6);
        let want = layers::standard_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        standard_conv(
            X3::contiguous(x.data(), (2, 3, 30)),
            (2, 3, 30),
            k.data(),
            (6, 5),
            b.data(),
            &mut out,
        );
        assert_eq!(out, want.data());
    }

    #[test]
    fn standard_strided_input_matches_dense() {
        let (t, cin, w) = (2, 3, 21);
        let base = Tensor::randn(&[t, w, cin], 41);
        let x = base.permute3([0, 2, 1]).unwrap();
        let k = Tensor::randn(&[4, cin, 5], 42);
        let b = Tensor::randn(&[4], 43);
        let want = layers::standard_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        let xv = X3 {
            d: base.data(),
            off: 0,
            s: [w * cin, 1, cin],
            split0: None,
        };
        standard_conv(xv, (t, cin, w), k.data(), (4, 5), b.data(), &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn pointwise_matches_layers() {
        let x = Tensor::randn(&[2, 7, 9], 7);
        let k = Tensor::randn(&[7, 4], 8);
        let b = Tensor::randn(&[4], 9);
        let want = layers::pointwise_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        pointwise_conv(
            X3::contiguous(x.data(), (2, 7, 9)),
            (2, 7, 9),
            k.data(),
            4,
            b.data(),
            &mut out,
        );
        assert_eq!(out, want.data());
    }

    #[test]
    fn pointwise_packed_matches_unpacked_bitwise() {
        // cout = 13 exercises the partial last panel; s = 37 the SR tail;
        // zeros in k exercise the oracle's skip in the packed path too
        let (t, cin, cout, s) = (3, 5, 13, 37);
        let x = Tensor::randn(&[t, cin, s], 10);
        let mut k = Tensor::randn(&[cin, cout], 11);
        {
            let kd = k.data_mut();
            kd[0] = 0.0;
            kd[cin * cout / 2] = 0.0;
        }
        let b = Tensor::randn(&[cout], 12);
        let want = layers::pointwise_conv(&x, &k, &b).unwrap();
        let packed = pack_k(k.data(), cin, cout);
        let mut out = vec![0.0f32; want.len()];
        pointwise_conv_packed(
            X3::contiguous(x.data(), (t, cin, s)),
            (t, cin, s),
            &packed,
            cout,
            b.data(),
            &mut out,
        );
        assert_eq!(out, want.data());
    }

    #[test]
    fn pointwise_packed_strided_input() {
        let (t, cin, s) = (2, 4, 19);
        let base = Tensor::randn(&[t, s, cin], 51);
        let x = base.permute3([0, 2, 1]).unwrap();
        let k = Tensor::randn(&[cin, 6], 52);
        let b = Tensor::randn(&[6], 53);
        let want = layers::pointwise_conv(&x, &k, &b).unwrap();
        let packed = pack_k(k.data(), cin, 6);
        let mut out = vec![0.0f32; want.len()];
        let xv = X3 {
            d: base.data(),
            off: 0,
            s: [s * cin, 1, cin],
            split0: None,
        };
        pointwise_conv_packed(xv, (t, cin, s), &packed, 6, b.data(), &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn fully_connected_matches_layers() {
        let x = Tensor::randn(&[5, 11], 10);
        let k = Tensor::randn(&[11, 3], 11);
        let b = Tensor::randn(&[3], 12);
        let want = layers::fully_connected(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        fully_connected(
            X2::contiguous(x.data(), 11),
            (5, 11),
            k.data(),
            3,
            b.data(),
            &mut out,
        );
        assert_eq!(out, want.data());
    }

    #[test]
    fn fully_connected_packed_matches_layers_bitwise() {
        // cout = 11 exercises the padded last panel; a zero x element
        // exercises the aik == 0 skip both paths share
        let (bsz, cin, cout) = (4, 7, 11);
        let mut x = Tensor::randn(&[bsz, cin], 13);
        x.data_mut()[3] = 0.0;
        let k = Tensor::randn(&[cin, cout], 14);
        let b = Tensor::randn(&[cout], 15);
        let want = layers::fully_connected(&x, &k, &b).unwrap();
        let packed = pack_k(k.data(), cin, cout);
        let mut out = vec![0.0f32; want.len()];
        fully_connected_packed(
            X2::contiguous(x.data(), cin),
            (bsz, cin),
            &packed,
            cout,
            b.data(),
            &mut out,
        );
        assert_eq!(out, want.data());
    }

    #[test]
    fn materialize_matches_tensor_movement_ops() {
        // transpose2 as a strided rank-2 gather
        let x = Tensor::randn(&[4, 6], 13);
        let mut out = vec![0.0f32; 24];
        materialize(x.data(), 0, &[6, 4], &[1, 6], &mut out);
        assert_eq!(out, x.transpose2().unwrap().data());

        // permute3 as a strided rank-3 gather
        let y = Tensor::randn(&[2, 3, 4], 14);
        let mut out = vec![0.0f32; 24];
        // perm [2,0,1]: out shape (4,2,3); out[i,j,k] = y[j,k,i]
        materialize(y.data(), 0, &[4, 2, 3], &[1, 12, 4], &mut out);
        assert_eq!(out, y.permute3([2, 0, 1]).unwrap().data());

        // strided slice along axis 1 of (2, 8, 3)
        let z = Tensor::randn(&[2, 8, 3], 15);
        let want = z.stride_axis(1, 3, 3).unwrap();
        let mut out = vec![0.0f32; want.len()];
        materialize(z.data(), 0, &[2, 3, 3], &[24, 9, 1], &mut out);
        assert_eq!(out, want.data());
    }

    #[test]
    fn materialize_tiled_path_covers_large_transposes() {
        // bigger than one TILE in both axes, odd remainders on purpose
        let (r, c) = (67, 41);
        let x = Tensor::randn(&[c, r], 16);
        let mut out = vec![0.0f32; r * c];
        materialize(x.data(), 0, &[r, c], &[1, r], &mut out);
        assert_eq!(out, x.transpose2().unwrap().data());
    }

    #[test]
    fn materialize_respects_offset() {
        // a view starting mid-buffer: row 1 of a (3, 5) matrix
        let x = Tensor::randn(&[3, 5], 17);
        let mut out = vec![0.0f32; 5];
        materialize(x.data(), 5, &[5], &[1], &mut out);
        assert_eq!(out, &x.data()[5..10]);
    }

    #[test]
    fn fused_chain_matches_sequential_adds() {
        let a = Tensor::randn(&[100], 16);
        let b = Tensor::randn(&[100], 17);
        let c = Tensor::randn(&[100], 18);
        let mut out = vec![0.0f32; 100];
        fused_ew(&[(1.0, a.data()), (-1.0, b.data()), (1.0, c.data())], &mut out);
        // identical rounding to (a - b) + c evaluated node by node
        let ab = crate::tensor::sub(&a, &b).unwrap();
        let want = crate::tensor::add(&ab, &c).unwrap();
        assert_eq!(out, want.data());
    }

    #[test]
    fn parallel_path_consistent_with_serial() {
        // large enough to cross PAR_THRESHOLD and engage the thread pool
        let t = 32;
        let x = Tensor::randn(&[t, 16, 260], 19);
        let k = Tensor::randn(&[16, 5], 20);
        let b = Tensor::randn(&[16], 21);
        let want = layers::depthwise_conv(&x, &k, &b).unwrap();
        let mut out = vec![0.0f32; want.len()];
        depthwise_conv(
            X3::contiguous(x.data(), (t, 16, 260)),
            (t, 16, 260),
            k.data(),
            5,
            b.data(),
            &mut out,
        );
        assert_eq!(out, want.data());
    }

    #[test]
    fn packed_parallel_path_consistent_with_serial() {
        // units * work above PAR_THRESHOLD: threads engage on the packed path
        let (t, cin, cout, s) = (8, 32, 32, 505);
        let x = Tensor::randn(&[t, cin, s], 22);
        let k = Tensor::randn(&[cin, cout], 23);
        let b = Tensor::randn(&[cout], 24);
        let want = layers::pointwise_conv(&x, &k, &b).unwrap();
        let packed = pack_k(k.data(), cin, cout);
        let mut out = vec![0.0f32; want.len()];
        pointwise_conv_packed(
            X3::contiguous(x.data(), (t, cin, s)),
            (t, cin, s),
            &packed,
            cout,
            b.data(),
            &mut out,
        );
        assert_eq!(out, want.data());
    }
}
