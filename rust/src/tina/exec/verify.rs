//! Independent static verifier over compiled [`ExecPlan`]s — "verify the
//! artifact, don't trust the compiler".
//!
//! [`ExecPlan::verify`] is an abstract interpreter over the compiled step
//! list that re-derives, from scratch and sharing no code with
//! `ExecPlan::compile`, four proof obligations:
//!
//! 1. **Extent typing** — every [`View`] (including [`Split0`] reindexed
//!    leading axes) is bounds-checked against its backing buffer with the
//!    verifier's *own* max-address computation (it enumerates outer split
//!    blocks rather than reusing `View::end`'s two-candidate argument),
//!    using checked arithmetic so overflow cannot forge an in-bounds
//!    address.  Out-of-bounds reads and writes are proven impossible per
//!    step, for external inputs and plan constants as well as arena slots.
//! 2. **Def-use / aliasing** — a forward walk proves no step reads a slot
//!    before it is written or after it has been recycled for another
//!    value, no step writes the slot of one of its own arguments (kernels
//!    never run in place), and a slot is only overwritten once its current
//!    value has no remaining consumers and is not pinned for a plan
//!    output.  This subsumes (and replaced) the old `validate_liveness`.
//! 3. **Reduction-order certificates** — each kernel family's declared
//!    blocking ([`fused::declared_blocking`]) is checked against the
//!    oracle contract the lowering layer owns
//!    ([`crate::tina::lower::oracle_reduction_order`] /
//!    [`crate::tina::lower::oracle_output_axes`]): the per-element
//!    reduction order must match the oracle exactly and blocking may only
//!    touch independent output coordinates, so a future SIMD microkernel
//!    that vectorizes the wrong axis fails verification rather than a
//!    fuzzer lottery.
//! 4. **Fusion-legality audit** — every fold recorded by the fusion pass
//!    carries a [`FoldAudit`] certificate tagged with its
//!    [`FoldKind`]; the verifier re-proves on the *final* plan that the
//!    pre-scaled kernel is exactly the audited structure (one-hot ±1 rows
//!    scaled by the window for framing folds; ±1-signed original gains
//!    for scale-chain folds), the adopted bias matches (all-zero original
//!    bias for framing folds, sign × original bias for chain folds), the
//!    rewritten step has the kernel family the kind demands, the
//!    activation view maps every element onto its own conv output
//!    channel, and the folded-away value never resurfaces.
//!
//! Wiring: [`super::plan::CompileOptions::verify`] runs the verifier at
//! the end of every compile — on by default under `debug_assertions`
//! (every plan the test suite, property tests and fuzzer build is
//! verified) and opt-in + metered in release via the coordinator router
//! (`plans_verified` / `verify_ns`).  See ARCHITECTURE.md's
//! "Verification layers" section for where this sits between the oracle
//! tests and the sanitizer CI jobs.

use super::fused::{self, Blocking, KernelFamily};
use super::plan::{ArgRef, ExecPlan, FoldKind, Kernel, Loc, View};
use crate::tina::lower::{oracle_output_axes, oracle_reduction_order};
use std::collections::{HashMap, HashSet};
use std::fmt;

/// Upper bound on the fold audit's exhaustive channel-correspondence scan.
/// `compile` never records a fold larger than its own scan cap, so any
/// audit above this bound cannot have come from the compiler.
const AUDIT_SCAN_CAP: usize = 1 << 22;

/// A proof obligation the static verifier could not discharge.  Each
/// variant is a distinct, hand-testable failure class; `Display` renders
/// a one-line diagnostic.
#[derive(Debug, Clone)]
pub enum VerifyError {
    /// A step has the wrong number of arguments for its kernel.
    ArityMismatch {
        /// Offending step index.
        step: usize,
        /// Argument count the kernel family requires.
        expected: usize,
        /// Argument count the step actually carries.
        got: usize,
    },
    /// An argument references an external input or plan constant that
    /// does not exist.
    BadLocIndex {
        /// Offending step index.
        step: usize,
        /// Which table the index missed ("external" or "const").
        what: &'static str,
        /// The out-of-range index.
        idx: usize,
    },
    /// A step writes, or an argument reads, an arena slot index that is
    /// out of range (`steps.len()` denotes the output gather).
    BadSlotIndex {
        /// Offending step index.
        step: usize,
        /// The out-of-range slot.
        slot: usize,
    },
    /// Address arithmetic for a view overflowed `usize`.
    AddressOverflow {
        /// Offending step index.
        step: usize,
        /// What overflowed.
        detail: String,
    },
    /// A view can touch an element past the end of its backing buffer.
    OobRead {
        /// Offending step index.
        step: usize,
        /// Offending argument index.
        arg: usize,
        /// One past the largest address the view can reach.
        end: usize,
        /// Backing buffer extent.
        extent: usize,
    },
    /// A step's dense output does not fit its arena slot.
    OobWrite {
        /// Offending step index.
        step: usize,
        /// Output element count.
        len: usize,
        /// Assigned slot capacity.
        slot_size: usize,
    },
    /// Re-derived output/operand shapes disagree with the recorded ones.
    ShapeMismatch {
        /// Offending step index.
        step: usize,
        /// What disagreed.
        detail: String,
    },
    /// A split leading axis appears on an argument position that cannot
    /// reindex it (only conv-family activations may carry one).
    SplitOnNonActivation {
        /// Offending step index.
        step: usize,
        /// Offending argument index.
        arg: usize,
    },
    /// A split view's leading extent is not divisible by its inner
    /// factor (or the inner factor is zero).
    SplitNotDivisible {
        /// Offending step index.
        step: usize,
        /// Offending argument index.
        arg: usize,
    },
    /// A fully connected activation carries a split view (the `X2` read
    /// path cannot reindex a split leading axis).
    FcSplitActivation {
        /// Offending step index.
        step: usize,
    },
    /// A kernel operand that must stream dense memory has a
    /// non-contiguous view.
    NonContiguousOperand {
        /// Offending step index.
        step: usize,
        /// Offending argument index.
        arg: usize,
    },
    /// A fused elementwise sign is not exactly `+1.0` or `-1.0`.
    BadSign {
        /// Offending step index.
        step: usize,
        /// Offending term index.
        term: usize,
    },
    /// A pre-packed weight panel set disagrees with its source constant.
    PackedPanelMismatch {
        /// Offending step index.
        step: usize,
        /// What disagreed.
        detail: String,
    },
    /// A kernel family's declared blocking violates the oracle contract.
    ReductionOrderViolation {
        /// The kernel family name.
        family: String,
        /// What the declaration got wrong.
        detail: String,
    },
    /// A step reads an arena slot no earlier step has written.
    ReadBeforeWrite {
        /// Offending step index.
        step: usize,
        /// The unwritten slot.
        slot: usize,
    },
    /// A step reads a slot whose buffer has been recycled for another
    /// value since the expected producer ran.
    StaleRead {
        /// Offending step index.
        step: usize,
        /// The recycled slot.
        slot: usize,
        /// Value id the argument expects in the slot.
        expected_root: usize,
        /// Value id actually occupying the slot.
        found_root: usize,
    },
    /// A step writes the same slot as one of its own arguments (kernels
    /// never run in place).
    OutputAliasesInput {
        /// Offending step index.
        step: usize,
        /// The shared slot.
        slot: usize,
    },
    /// A step overwrites a slot whose current value still has unread
    /// consumers.
    OverwriteLive {
        /// Offending step index.
        step: usize,
        /// The overwritten slot.
        slot: usize,
        /// Value id still awaiting readers.
        live_root: usize,
    },
    /// A step overwrites a slot pinned for a plan output.
    OverwritePinned {
        /// Offending step index.
        step: usize,
        /// The overwritten slot.
        slot: usize,
        /// Pinned value id.
        root: usize,
    },
    /// After the last step, a plan output's slot no longer holds the
    /// output's value.
    OutputClobbered {
        /// Offending output index.
        output: usize,
        /// The clobbered slot.
        slot: usize,
    },
    /// A plan output carries a split view the output gather cannot read.
    OutputSplitView {
        /// Offending output index.
        output: usize,
    },
    /// A plan output's view escapes its backing buffer.
    OutputOob {
        /// Offending output index.
        output: usize,
        /// One past the largest address the view can reach.
        end: usize,
        /// Backing buffer extent.
        extent: usize,
    },
    /// `fused_steps` does not match the number of recorded fold audits.
    FoldCountMismatch {
        /// The plan's fused-step counter.
        fused_steps: usize,
        /// The number of recorded audits.
        audits: usize,
    },
    /// The pre-scaled conv kernel is not the audited one-hot ±1
    /// structure scaled by the audited window.
    FoldScaleMismatch {
        /// Offending audit index.
        audit: usize,
        /// What disagreed.
        detail: String,
    },
    /// The adopted bias constant disagrees with the audited window bias.
    FoldBiasMismatch {
        /// Offending audit index.
        audit: usize,
        /// What disagreed.
        detail: String,
    },
    /// The folded conv's original bias was not all-zero.
    FoldNonZeroOrigBias {
        /// Offending audit index.
        audit: usize,
    },
    /// The audited activation view does not land every element on its
    /// own conv output channel.
    FoldBadChannelMap {
        /// Offending audit index.
        audit: usize,
        /// What disagreed.
        detail: String,
    },
    /// The audited conv step's kernel family does not match the audit
    /// kind (framing-conv folds rewrite standard convs; framing-depthwise
    /// and scale-chain folds rewrite depthwise convs).
    FoldWrongKernelFamily {
        /// Offending audit index.
        audit: usize,
        /// What disagreed.
        detail: String,
    },
    /// A scale-chain audit's per-channel sign is not ±1, or its recorded
    /// pre-signed bias disagrees with sign × original producer bias.
    FoldChainSignMismatch {
        /// Offending audit index.
        audit: usize,
        /// What disagreed.
        detail: String,
    },
    /// The folded-away window value reappears in the final plan.
    FoldValueResurfaced {
        /// Offending audit index.
        audit: usize,
        /// The resurfaced value id.
        root: usize,
    },
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use VerifyError::*;
        match self {
            ArityMismatch {
                step,
                expected,
                got,
            } => write!(f, "step {step}: expected {expected} args, got {got}"),
            BadLocIndex { step, what, idx } => {
                write!(f, "step {step}: {what} index {idx} out of range")
            }
            BadSlotIndex { step, slot } => {
                write!(f, "step {step}: arena slot {slot} out of range")
            }
            AddressOverflow { step, detail } => {
                write!(f, "step {step}: address arithmetic overflow ({detail})")
            }
            OobRead {
                step,
                arg,
                end,
                extent,
            } => write!(
                f,
                "step {step} arg {arg}: view reaches {end} past backing extent {extent}"
            ),
            OobWrite {
                step,
                len,
                slot_size,
            } => write!(
                f,
                "step {step}: output of {len} elements exceeds slot capacity {slot_size}"
            ),
            ShapeMismatch { step, detail } => write!(f, "step {step}: shape mismatch ({detail})"),
            SplitOnNonActivation { step, arg } => write!(
                f,
                "step {step} arg {arg}: split view on a non-activation operand"
            ),
            SplitNotDivisible { step, arg } => write!(
                f,
                "step {step} arg {arg}: split leading axis not divisible by inner factor"
            ),
            FcSplitActivation { step } => write!(
                f,
                "step {step}: fully connected activation carries a split view"
            ),
            NonContiguousOperand { step, arg } => write!(
                f,
                "step {step} arg {arg}: dense-stream operand has a non-contiguous view"
            ),
            BadSign { step, term } => {
                write!(f, "step {step} term {term}: fused elementwise sign not ±1.0")
            }
            PackedPanelMismatch { step, detail } => {
                write!(f, "step {step}: packed panel mismatch ({detail})")
            }
            ReductionOrderViolation { family, detail } => {
                write!(f, "kernel family {family}: {detail}")
            }
            ReadBeforeWrite { step, slot } => {
                write!(f, "step {step}: reads slot {slot} before any write")
            }
            StaleRead {
                step,
                slot,
                expected_root,
                found_root,
            } => write!(
                f,
                "step {step}: slot {slot} holds value {found_root}, expected {expected_root}"
            ),
            OutputAliasesInput { step, slot } => {
                write!(f, "step {step}: output slot {slot} aliases an argument")
            }
            OverwriteLive {
                step,
                slot,
                live_root,
            } => write!(
                f,
                "step {step}: overwrites slot {slot} while value {live_root} still has readers"
            ),
            OverwritePinned { step, slot, root } => write!(
                f,
                "step {step}: overwrites slot {slot} pinned for output value {root}"
            ),
            OutputClobbered { output, slot } => {
                write!(f, "output {output}: slot {slot} no longer holds its value")
            }
            OutputSplitView { output } => {
                write!(f, "output {output}: gather cannot read a split view")
            }
            OutputOob {
                output,
                end,
                extent,
            } => write!(
                f,
                "output {output}: view reaches {end} past backing extent {extent}"
            ),
            FoldCountMismatch {
                fused_steps,
                audits,
            } => write!(
                f,
                "fused_steps = {fused_steps} but {audits} fold audits recorded"
            ),
            FoldScaleMismatch { audit, detail } => {
                write!(f, "fold audit {audit}: scaled kernel mismatch ({detail})")
            }
            FoldBiasMismatch { audit, detail } => {
                write!(f, "fold audit {audit}: bias mismatch ({detail})")
            }
            FoldNonZeroOrigBias { audit } => {
                write!(f, "fold audit {audit}: original conv bias not all-zero")
            }
            FoldBadChannelMap { audit, detail } => {
                write!(f, "fold audit {audit}: bad channel correspondence ({detail})")
            }
            FoldWrongKernelFamily { audit, detail } => {
                write!(f, "fold audit {audit}: wrong kernel family ({detail})")
            }
            FoldChainSignMismatch { audit, detail } => {
                write!(f, "fold audit {audit}: chain sign mismatch ({detail})")
            }
            FoldValueResurfaced { audit, root } => {
                write!(f, "fold audit {audit}: folded value {root} resurfaced")
            }
        }
    }
}

impl std::error::Error for VerifyError {}

/// Check one kernel family's declared [`Blocking`] against the oracle
/// contract: the declared reduction order must equal
/// [`oracle_reduction_order`] exactly, and every blocked axis must be one
/// of [`oracle_output_axes`] (blocking a reduction axis would reassociate
/// the f32 accumulation).  Exposed so tests can feed hostile declarations
/// directly.
pub fn check_blocking(family: KernelFamily, b: &Blocking) -> Result<(), VerifyError> {
    let want = oracle_reduction_order(family);
    if b.reduction != want {
        return Err(VerifyError::ReductionOrderViolation {
            family: format!("{family:?}"),
            detail: format!(
                "declared reduction order {:?} != oracle order {:?}",
                b.reduction, want
            ),
        });
    }
    let outs = oracle_output_axes(family);
    for ax in b.blocked {
        if !outs.contains(ax) {
            return Err(VerifyError::ReductionOrderViolation {
                family: format!("{family:?}"),
                detail: format!("blocks non-output axis {ax:?} (output axes: {outs:?})"),
            });
        }
    }
    Ok(())
}

/// Kernel family of a plan step (packed and unpacked paths certify
/// separately).
fn family_of(k: &Kernel) -> KernelFamily {
    match k {
        Kernel::StandardConv1d => KernelFamily::StandardConv,
        Kernel::DepthwiseConv1d => KernelFamily::DepthwiseConv,
        Kernel::PointwiseConv { packed: Some(_) } => KernelFamily::PointwiseConvPacked,
        Kernel::PointwiseConv { packed: None } => KernelFamily::PointwiseConv,
        Kernel::FullyConnected { packed: Some(_) } => KernelFamily::FullyConnectedPacked,
        Kernel::FullyConnected { packed: None } => KernelFamily::FullyConnected,
        Kernel::Materialize { .. } => KernelFamily::Materialize,
        Kernel::FusedEw { .. } => KernelFamily::FusedEw,
    }
}

/// One past the largest element address `view` can touch, computed with
/// checked arithmetic and — deliberately — a different algorithm from
/// `View::end`: split leading axes are resolved by enumerating every
/// outer block instead of the two-candidate maximum, so a bug in either
/// derivation is caught by the other.  Returns 0 for empty views.
fn max_end(step: usize, view: &View) -> Result<usize, VerifyError> {
    if view.shape.len() != view.strides.len() {
        return Err(VerifyError::ShapeMismatch {
            step,
            detail: format!(
                "view rank {} != stride rank {}",
                view.shape.len(),
                view.strides.len()
            ),
        });
    }
    if view.shape.iter().any(|&d| d == 0) {
        return Ok(0);
    }
    let ovf = |what: &str| VerifyError::AddressOverflow {
        step,
        detail: what.to_string(),
    };
    let mut last = view.offset;
    for (i, (&d, &s)) in view.shape.iter().zip(&view.strides).enumerate() {
        let dm = d - 1;
        let contrib = match (i, view.split0) {
            (0, Some(sp)) => {
                if sp.inner == 0 {
                    return Err(VerifyError::SplitNotDivisible { step, arg: 0 });
                }
                // walk every outer block; the in-block row index is
                // capped by both the inner extent and the axis extent
                let mut best = 0usize;
                for q in 0..=dm / sp.inner {
                    let r = (sp.inner - 1).min(dm - q * sp.inner);
                    let c = q
                        .checked_mul(sp.outer_stride)
                        .and_then(|v| r.checked_mul(s).and_then(|w| v.checked_add(w)))
                        .ok_or_else(|| ovf("split block address"))?;
                    best = best.max(c);
                }
                best
            }
            _ => dm.checked_mul(s).ok_or_else(|| ovf("axis extent"))?,
        };
        last = last.checked_add(contrib).ok_or_else(|| ovf("view address"))?;
    }
    last.checked_add(1).ok_or_else(|| ovf("view end"))
}

/// Product of a shape with overflow detection.
fn checked_numel(step: usize, shape: &[usize]) -> Result<usize, VerifyError> {
    shape
        .iter()
        .try_fold(1usize, |a, &d| a.checked_mul(d))
        .ok_or_else(|| VerifyError::AddressOverflow {
            step,
            detail: "shape product".to_string(),
        })
}

/// Dense row-major check re-derived locally (strides of size-1 axes are
/// irrelevant; split views are never dense).
fn dense(view: &View) -> bool {
    if view.split0.is_some() {
        return false;
    }
    let mut expect = 1usize;
    for (&d, &s) in view.shape.iter().zip(&view.strides).rev() {
        if d != 1 && s != expect {
            return false;
        }
        expect *= d;
    }
    true
}

impl ExecPlan {
    /// Statically verify this compiled plan: extent typing, def-use /
    /// aliasing, reduction-order certificates, and fusion-legality
    /// audits.  See the [module docs](self) for the full obligation list.
    /// Returns the first violated obligation.
    pub fn verify(&self) -> Result<(), VerifyError> {
        // step-read counts per value id (output gathers tracked via the
        // pinned set, not as reads, so OverwritePinned is reachable)
        let mut remaining: HashMap<usize, usize> = HashMap::new();
        for s in &self.steps {
            for a in &s.args {
                if matches!(a.loc, Loc::Slot(_)) {
                    *remaining.entry(a.root).or_default() += 1;
                }
            }
        }
        let pinned: HashSet<usize> = self
            .outputs
            .iter()
            .filter(|o| matches!(o.loc, Loc::Slot(_)))
            .map(|o| o.root)
            .collect();

        // forward walk: slot -> (occupying value id, its dense extent)
        let mut owner: Vec<Option<(usize, usize)>> = vec![None; self.slot_sizes.len()];
        for (si, step) in self.steps.iter().enumerate() {
            self.check_step_typing(si, step)?;
            for (ai, a) in step.args.iter().enumerate() {
                let extent = self.arg_extent(si, a, &owner)?;
                let end = max_end(si, &a.view)?;
                if end > extent {
                    return Err(VerifyError::OobRead {
                        step: si,
                        arg: ai,
                        end,
                        extent,
                    });
                }
            }
            let os = step.out_slot;
            if os >= self.slot_sizes.len() {
                return Err(VerifyError::BadSlotIndex { step: si, slot: os });
            }
            if step.args.iter().any(|a| a.loc == Loc::Slot(os)) {
                return Err(VerifyError::OutputAliasesInput { step: si, slot: os });
            }
            for a in &step.args {
                if matches!(a.loc, Loc::Slot(_)) {
                    *remaining.get_mut(&a.root).expect("counted above") -= 1;
                }
            }
            let out_len = checked_numel(si, &step.out_shape)?;
            if out_len > self.slot_sizes[os] {
                return Err(VerifyError::OobWrite {
                    step: si,
                    len: out_len,
                    slot_size: self.slot_sizes[os],
                });
            }
            if let Some((r, _)) = owner[os] {
                let live = remaining.get(&r).copied().unwrap_or(0);
                if live > 0 {
                    return Err(VerifyError::OverwriteLive {
                        step: si,
                        slot: os,
                        live_root: r,
                    });
                }
                if pinned.contains(&r) {
                    return Err(VerifyError::OverwritePinned {
                        step: si,
                        slot: os,
                        root: r,
                    });
                }
            }
            owner[os] = Some((step.out_root, out_len));
        }

        // plan outputs: gatherable, in bounds, and still owning their slot
        let gather = self.steps.len();
        for (oi, o) in self.outputs.iter().enumerate() {
            if o.view.split0.is_some() {
                return Err(VerifyError::OutputSplitView { output: oi });
            }
            let extent = match o.loc {
                Loc::External(i) => {
                    if i >= self.input_shapes.len() {
                        return Err(VerifyError::BadLocIndex {
                            step: gather,
                            what: "external",
                            idx: i,
                        });
                    }
                    checked_numel(gather, &self.input_shapes[i])?
                }
                Loc::Const(k) => {
                    if k >= self.constants.len() {
                        return Err(VerifyError::BadLocIndex {
                            step: gather,
                            what: "const",
                            idx: k,
                        });
                    }
                    self.constants[k].len()
                }
                Loc::Slot(s) => {
                    if s >= self.slot_sizes.len() {
                        return Err(VerifyError::BadSlotIndex {
                            step: gather,
                            slot: s,
                        });
                    }
                    match owner[s] {
                        Some((r, len)) if r == o.root => len,
                        _ => return Err(VerifyError::OutputClobbered { output: oi, slot: s }),
                    }
                }
            };
            let end = max_end(gather, &o.view)?;
            if end > extent {
                return Err(VerifyError::OutputOob {
                    output: oi,
                    end,
                    extent,
                });
            }
        }

        self.check_fold_audits()
    }

    /// Extent of an argument's backing buffer, enforcing the def-use
    /// rules for arena slot reads along the way.
    fn arg_extent(
        &self,
        si: usize,
        a: &ArgRef,
        owner: &[Option<(usize, usize)>],
    ) -> Result<usize, VerifyError> {
        match a.loc {
            Loc::External(i) => {
                if i >= self.input_shapes.len() {
                    return Err(VerifyError::BadLocIndex {
                        step: si,
                        what: "external",
                        idx: i,
                    });
                }
                checked_numel(si, &self.input_shapes[i])
            }
            Loc::Const(k) => {
                if k >= self.constants.len() {
                    return Err(VerifyError::BadLocIndex {
                        step: si,
                        what: "const",
                        idx: k,
                    });
                }
                Ok(self.constants[k].len())
            }
            Loc::Slot(s) => {
                if s >= self.slot_sizes.len() {
                    return Err(VerifyError::BadSlotIndex { step: si, slot: s });
                }
                match owner[s] {
                    None => Err(VerifyError::ReadBeforeWrite { step: si, slot: s }),
                    Some((r, _)) if r != a.root => Err(VerifyError::StaleRead {
                        step: si,
                        slot: s,
                        expected_root: a.root,
                        found_root: r,
                    }),
                    Some((_, len)) => Ok(len),
                }
            }
        }
    }

    /// Per-step typing: arity, re-derived operand/output shapes, operand
    /// contiguity, split-view legality, packed-panel content, and the
    /// reduction-order certificate.
    fn check_step_typing(&self, si: usize, step: &super::plan::Step) -> Result<(), VerifyError> {
        let mismatch = |detail: String| VerifyError::ShapeMismatch { step: si, detail };
        let arity = |expected: usize| {
            if step.args.len() != expected {
                Err(VerifyError::ArityMismatch {
                    step: si,
                    expected,
                    got: step.args.len(),
                })
            } else {
                Ok(())
            }
        };
        for (ai, a) in step.args.iter().enumerate() {
            if a.view.shape.len() != a.view.strides.len() {
                return Err(mismatch(format!(
                    "arg {ai} view rank {} != stride rank {}",
                    a.view.shape.len(),
                    a.view.strides.len()
                )));
            }
            if let Some(sp) = a.view.split0 {
                let split_ok = ai == 0
                    && matches!(
                        step.kernel,
                        Kernel::StandardConv1d
                            | Kernel::DepthwiseConv1d
                            | Kernel::PointwiseConv { .. }
                    );
                if !split_ok {
                    if ai == 0 && matches!(step.kernel, Kernel::FullyConnected { .. }) {
                        return Err(VerifyError::FcSplitActivation { step: si });
                    }
                    return Err(VerifyError::SplitOnNonActivation { step: si, arg: ai });
                }
                if sp.inner == 0 || a.view.shape.is_empty() || a.view.shape[0] % sp.inner != 0 {
                    return Err(VerifyError::SplitNotDivisible { step: si, arg: ai });
                }
            }
        }
        let contig = |ai: usize| {
            if dense(&step.args[ai].view) {
                Ok(())
            } else {
                Err(VerifyError::NonContiguousOperand { step: si, arg: ai })
            }
        };
        match &step.kernel {
            Kernel::DepthwiseConv1d => {
                arity(3)?;
                let xs = &step.args[0].view.shape;
                let ks = &step.args[1].view.shape;
                let bs = &step.args[2].view.shape;
                let [t, c, w] = xs[..] else {
                    return Err(mismatch(format!("depthwise activation rank {}", xs.len())));
                };
                if ks.len() != 2 || ks[0] != c || ks[1] == 0 || ks[1] > w {
                    return Err(mismatch(format!(
                        "depthwise kernel {ks:?} vs activation {xs:?}"
                    )));
                }
                if bs != &[c] {
                    return Err(mismatch(format!("depthwise bias {bs:?}, channels {c}")));
                }
                contig(1)?;
                contig(2)?;
                let want = [t, c, w - ks[1] + 1];
                if step.out_shape != want {
                    return Err(mismatch(format!(
                        "depthwise out {:?}, derived {want:?}",
                        step.out_shape
                    )));
                }
            }
            Kernel::StandardConv1d => {
                arity(3)?;
                let xs = &step.args[0].view.shape;
                let ks = &step.args[1].view.shape;
                let bs = &step.args[2].view.shape;
                let [t, cin, w] = xs[..] else {
                    return Err(mismatch(format!("standard activation rank {}", xs.len())));
                };
                if ks.len() != 3 || ks[1] != cin || ks[2] == 0 || ks[2] > w {
                    return Err(mismatch(format!(
                        "standard kernel {ks:?} vs activation {xs:?}"
                    )));
                }
                let cout = ks[0];
                if bs != &[cout] {
                    return Err(mismatch(format!("standard bias {bs:?}, cout {cout}")));
                }
                contig(1)?;
                contig(2)?;
                let want = [t, cout, w - ks[2] + 1];
                if step.out_shape != want {
                    return Err(mismatch(format!(
                        "standard out {:?}, derived {want:?}",
                        step.out_shape
                    )));
                }
            }
            Kernel::PointwiseConv { packed } => {
                arity(3)?;
                let xs = &step.args[0].view.shape;
                let ks = &step.args[1].view.shape;
                let bs = &step.args[2].view.shape;
                let [t, c, s] = xs[..] else {
                    return Err(mismatch(format!("pointwise activation rank {}", xs.len())));
                };
                if ks.len() != 2 || ks[0] != c {
                    return Err(mismatch(format!(
                        "pointwise kernel {ks:?} vs activation {xs:?}"
                    )));
                }
                let cout = ks[1];
                if bs != &[cout] {
                    return Err(mismatch(format!("pointwise bias {bs:?}, cout {cout}")));
                }
                contig(1)?;
                contig(2)?;
                let want = [t, cout, s];
                if step.out_shape != want {
                    return Err(mismatch(format!(
                        "pointwise out {:?}, derived {want:?}",
                        step.out_shape
                    )));
                }
                if let Some(pi) = packed {
                    self.check_packed(si, *pi, &step.args[1])?;
                }
            }
            Kernel::FullyConnected { packed } => {
                arity(3)?;
                let xs = &step.args[0].view.shape;
                let ks = &step.args[1].view.shape;
                let bs = &step.args[2].view.shape;
                let [bsz, cin] = xs[..] else {
                    return Err(mismatch(format!("fc activation rank {}", xs.len())));
                };
                if ks.len() != 2 || ks[0] != cin {
                    return Err(mismatch(format!("fc kernel {ks:?} vs activation {xs:?}")));
                }
                let cout = ks[1];
                if bs != &[cout] {
                    return Err(mismatch(format!("fc bias {bs:?}, cout {cout}")));
                }
                contig(1)?;
                contig(2)?;
                let want = [bsz, cout];
                if step.out_shape != want {
                    return Err(mismatch(format!(
                        "fc out {:?}, derived {want:?}",
                        step.out_shape
                    )));
                }
                if let Some(pi) = packed {
                    self.check_packed(si, *pi, &step.args[1])?;
                }
            }
            Kernel::Materialize { .. } => {
                arity(1)?;
                if step.out_shape != step.args[0].view.shape {
                    return Err(mismatch(format!(
                        "materialize out {:?} != view shape {:?}",
                        step.out_shape, step.args[0].view.shape
                    )));
                }
            }
            Kernel::FusedEw { signs } => {
                if step.args.is_empty() || step.args.len() != signs.len() {
                    return Err(VerifyError::ArityMismatch {
                        step: si,
                        expected: signs.len().max(1),
                        got: step.args.len(),
                    });
                }
                let n = checked_numel(si, &step.out_shape)?;
                for (ti, a) in step.args.iter().enumerate() {
                    contig(ti)?;
                    let an = checked_numel(si, &a.view.shape)?;
                    if an != n {
                        return Err(mismatch(format!("fused term {ti} numel {an} != out {n}")));
                    }
                }
                for (ti, &s) in signs.iter().enumerate() {
                    if s != 1.0 && s != -1.0 {
                        return Err(VerifyError::BadSign { step: si, term: ti });
                    }
                }
            }
        }
        let fam = family_of(&step.kernel);
        check_blocking(fam, &fused::declared_blocking(fam))
    }

    /// Re-verify a pre-packed NR-panel set against its source constant
    /// with the verifier's own panel index math.
    fn check_packed(&self, si: usize, pi: usize, ka: &ArgRef) -> Result<(), VerifyError> {
        let ppm = |detail: String| VerifyError::PackedPanelMismatch { step: si, detail };
        let Some(panels) = self.packed.get(pi) else {
            return Err(ppm(format!("panel index {pi} out of range")));
        };
        let Loc::Const(kc) = ka.loc else {
            return Err(ppm("packed weight is not a plan constant".to_string()));
        };
        let Some(kt) = self.constants.get(kc) else {
            return Err(VerifyError::BadLocIndex {
                step: si,
                what: "const",
                idx: kc,
            });
        };
        let kd = kt.data();
        if ka.view.offset != 0 || !dense(&ka.view) || ka.view.numel_checked() != Some(kd.len()) {
            return Err(ppm("packed weight view is not the whole constant".to_string()));
        }
        let [cin, cout] = ka.view.shape[..] else {
            return Err(ppm(format!("packed weight rank {}", ka.view.shape.len())));
        };
        let nr = fused::NR;
        let nblk = cout.div_ceil(nr);
        if panels.len() != nblk * cin * nr {
            return Err(ppm(format!(
                "panel len {} != {nblk} blocks * {cin} cin * {nr}",
                panels.len()
            )));
        }
        for jb in 0..nblk {
            for ci in 0..cin {
                for j in 0..nr {
                    let co = jb * nr + j;
                    let want = if co < cout { kd[ci * cout + co] } else { 0.0 };
                    let got = panels[(jb * cin + ci) * nr + j];
                    if got != want {
                        return Err(ppm(format!(
                            "panel ({jb},{ci},{j}) = {got}, constant says {want}"
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Re-prove every recorded window fold on the final plan.
    fn check_fold_audits(&self) -> Result<(), VerifyError> {
        if self.fused_steps != self.fold_audits.len() {
            return Err(VerifyError::FoldCountMismatch {
                fused_steps: self.fused_steps,
                audits: self.fold_audits.len(),
            });
        }
        for (ai, a) in self.fold_audits.iter().enumerate() {
            let scale = |detail: String| VerifyError::FoldScaleMismatch { audit: ai, detail };
            let bias = |detail: String| VerifyError::FoldBiasMismatch { audit: ai, detail };
            let chan = |detail: String| VerifyError::FoldBadChannelMap { audit: ai, detail };
            let c = a.win.len();
            if c == 0 || a.hot.len() != c {
                return Err(scale(format!("{c} channels, {} hot taps", a.hot.len())));
            }
            if a.wbias.len() != c || a.orig_bias.len() != c {
                return Err(bias(format!(
                    "{c} channels, window bias {} / conv bias {}",
                    a.wbias.len(),
                    a.orig_bias.len()
                )));
            }
            match a.kind {
                // framing folds absorbed a window that assumed the conv
                // added nothing: the original bias must have been zero
                FoldKind::FramingConv | FoldKind::FramingDepthwise => {
                    if a.orig_bias.iter().any(|&v| v != 0.0) {
                        return Err(VerifyError::FoldNonZeroOrigBias { audit: ai });
                    }
                }
                // chain folds pre-sign a (possibly nonzero) producer bias
                // instead; exactness rests on every sign being ±1 and the
                // recorded bias being exactly sign × original
                FoldKind::ScaleChain => {
                    for ch in 0..c {
                        let s = a.win[ch];
                        if s != 1.0 && s != -1.0 {
                            return Err(VerifyError::FoldChainSignMismatch {
                                audit: ai,
                                detail: format!("channel {ch}: sign {s}"),
                            });
                        }
                        let want = s * a.orig_bias[ch];
                        if a.wbias[ch] != want {
                            return Err(VerifyError::FoldChainSignMismatch {
                                audit: ai,
                                detail: format!(
                                    "channel {ch}: pre-signed bias {} != {want}",
                                    a.wbias[ch]
                                ),
                            });
                        }
                    }
                }
            }
            // the pre-scaled kernel: one-hot ±1 rows scaled by the window
            let Some(sc) = self.constants.get(a.scaled_const) else {
                return Err(scale(format!("scaled const {} missing", a.scaled_const)));
            };
            let sd = sc.data();
            if sd.len() % c != 0 {
                return Err(scale(format!("kernel len {} not divisible by {c}", sd.len())));
            }
            let row_len = sd.len() / c;
            for (co, row) in sd.chunks(row_len).enumerate() {
                match a.hot[co] {
                    Some((idx, sign)) => {
                        // framing folds demand unit hot taps; a chain
                        // fold's "sign" slot carries the producer's
                        // arbitrary original gain instead
                        let unit =
                            matches!(a.kind, FoldKind::FramingConv | FoldKind::FramingDepthwise);
                        if idx >= row_len || (unit && sign != 1.0 && sign != -1.0) {
                            return Err(scale(format!("channel {co}: bad hot tap ({idx}, {sign})")));
                        }
                        for (p, &v) in row.iter().enumerate() {
                            let want = if p == idx { sign * a.win[co] } else { 0.0 };
                            if v != want {
                                return Err(scale(format!(
                                    "channel {co} tap {p} = {v}, expected {want}"
                                )));
                            }
                        }
                    }
                    None => {
                        if row.iter().any(|&v| v != 0.0) {
                            return Err(scale(format!("channel {co}: nonzero taps in zero row")));
                        }
                    }
                }
            }
            // the adopted bias must be the window's bias, verbatim
            let Some(bc) = self.constants.get(a.bias_const) else {
                return Err(bias(format!("bias const {} missing", a.bias_const)));
            };
            if bc.data() != a.wbias.as_slice() {
                return Err(bias("adopted bias != audited window bias".to_string()));
            }
            // the rewritten conv must actually read both constants
            let Some(conv) = self.steps.iter().find(|s| s.out_root == a.conv_root) else {
                return Err(chan(format!("conv value {} has no step", a.conv_root)));
            };
            let family_ok = match a.kind {
                FoldKind::FramingConv => matches!(conv.kernel, Kernel::StandardConv1d),
                FoldKind::FramingDepthwise | FoldKind::ScaleChain => {
                    matches!(conv.kernel, Kernel::DepthwiseConv1d)
                }
            };
            if !family_ok || conv.args.len() != 3 {
                return Err(VerifyError::FoldWrongKernelFamily {
                    audit: ai,
                    detail: format!("{:?} step rewritten by a {:?} fold", conv.kernel, a.kind),
                });
            }
            if conv.args[1].loc != Loc::Const(a.scaled_const) {
                return Err(scale("conv does not read the scaled kernel".to_string()));
            }
            if conv.args[2].loc != Loc::Const(a.bias_const) {
                return Err(bias("conv does not read the adopted bias".to_string()));
            }
            let cs = &conv.out_shape;
            if cs.len() != 3 || cs[1] != c {
                return Err(chan(format!("conv out {cs:?}, {c} window channels")));
            }
            let (wc, total) = (cs[2], cs[0] * cs[1] * cs[2]);
            // exhaustive re-scan: every element the window read must land
            // on the conv output's own channel (verifier's own address
            // math over the recorded activation view)
            let v = &a.act_view;
            if v.shape.len() != 3 || v.strides.len() != 3 || v.shape[1] != c {
                return Err(chan(format!("activation view shape {:?}", v.shape)));
            }
            let (tn, wn) = (v.shape[0], v.shape[2]);
            if tn.saturating_mul(c).saturating_mul(wn) > AUDIT_SCAN_CAP {
                return Err(chan("activation scan above compile-time cap".to_string()));
            }
            let (s0, s1, s2) = (v.strides[0], v.strides[1], v.strides[2]);
            for t in 0..tn {
                let base = v.offset
                    + match v.split0 {
                        Some(sp) => {
                            if sp.inner == 0 || tn % sp.inner != 0 {
                                return Err(chan("bad activation split".to_string()));
                            }
                            (t / sp.inner) * sp.outer_stride + (t % sp.inner) * s0
                        }
                        None => t * s0,
                    };
                for ch in 0..c {
                    for w in 0..wn {
                        let addr = base + ch * s1 + w * s2;
                        if addr >= total || (addr / wc) % c != ch {
                            return Err(chan(format!(
                                "element (t={t}, ch={ch}, w={w}) -> address {addr}"
                            )));
                        }
                    }
                }
            }
            // the folded-away window value must never resurface
            for s in &self.steps {
                if s.out_root == a.folded_root || s.args.iter().any(|x| x.root == a.folded_root) {
                    return Err(VerifyError::FoldValueResurfaced {
                        audit: ai,
                        root: a.folded_root,
                    });
                }
            }
            if self.outputs.iter().any(|o| o.root == a.folded_root) {
                return Err(VerifyError::FoldValueResurfaced {
                    audit: ai,
                    root: a.folded_root,
                });
            }
        }
        Ok(())
    }
}

impl View {
    /// Checked element count (`None` on overflow) — verifier-local helper.
    fn numel_checked(&self) -> Option<usize> {
        self.shape.iter().try_fold(1usize, |a, &d| a.checked_mul(d))
    }
}

#[cfg(test)]
mod tests {
    use super::super::plan::CompileOptions;
    use super::*;
    use crate::dsp;
    use crate::tensor::Tensor;
    use crate::tina::exec::fused::Axis;
    use crate::tina::graph::{Graph, NodeOp};
    use crate::tina::lower;

    fn compile(g: &Graph) -> ExecPlan {
        let plan = ExecPlan::compile_with(
            g,
            CompileOptions {
                fusion: true,
                verify: false,
            },
        )
        .unwrap();
        plan.verify().expect("pristine plan must verify");
        plan
    }

    /// Four independent rank-1 adds where the first result stays live
    /// across a later, unrelated step — FusedEw def-use fodder.
    fn add_graph(pin_first: bool) -> Graph {
        let mut g = Graph::new();
        let i0 = g.input(&[8]);
        let i1 = g.input(&[8]);
        let i2 = g.input(&[8]);
        let i3 = g.input(&[8]);
        let s1 = g.push(NodeOp::Add, &[i0, i1]);
        let s2 = g.push(NodeOp::Add, &[i2, i3]);
        if pin_first {
            g.set_outputs(&[s1, s2]);
        } else {
            let s3 = g.push(NodeOp::Add, &[s1, i2]);
            let s4 = g.push(NodeOp::Sub, &[s1, i3]);
            g.set_outputs(&[s2, s3, s4]);
        }
        g
    }

    // ---- negative plans: each distinct corruption, its distinct error ----

    #[test]
    fn corrupt_offset_is_oob_read() {
        let mut plan = compile(&lower::fir(2, 64, &[0.5; 8]).unwrap());
        plan.steps[0].args[0].view.offset += 1_000_000;
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::OobRead { step: 0, arg: 0, .. })
        ));
    }

    #[test]
    fn swapped_steps_read_before_write() {
        let mut plan = compile(&lower::stft(1, 64, 16, 16).unwrap());
        assert!(plan.steps.len() >= 2, "stft must compile to several steps");
        plan.steps.swap(0, 1);
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::ReadBeforeWrite { step: 0, .. })
        ));
    }

    #[test]
    fn output_slot_aliasing_an_argument_is_rejected() {
        let mut plan = compile(&lower::stft(1, 64, 16, 16).unwrap());
        let Loc::Slot(conv_slot) = plan.steps[1].args[0].loc else {
            panic!("DFT step must read the framing conv's slot");
        };
        plan.steps[1].out_slot = conv_slot;
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::OutputAliasesInput { step: 1, .. })
        ));
    }

    #[test]
    fn overwriting_a_live_slot_is_rejected() {
        let mut plan = compile(&add_graph(false));
        // steps: s1, s2, s3(reads s1), s4(reads s1); step 1 is independent
        assert!(plan.steps[1]
            .args
            .iter()
            .all(|a| matches!(a.loc, Loc::External(_))));
        plan.steps[1].out_slot = plan.steps[0].out_slot;
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::OverwriteLive { step: 1, .. })
        ));
    }

    #[test]
    fn overwriting_a_pinned_slot_is_rejected() {
        let mut plan = compile(&add_graph(true));
        plan.steps[1].out_slot = plan.steps[0].out_slot;
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::OverwritePinned { step: 1, .. })
        ));
    }

    #[test]
    fn corrupt_scaled_kernel_fails_fold_audit() {
        let mut plan = compile(&lower::stft(1, 64, 16, 16).unwrap());
        assert_eq!(plan.fold_audits.len(), 1, "window fold must have fired");
        let k = plan.fold_audits[0].scaled_const;
        let shape = plan.constants[k].shape().to_vec();
        let mut d = plan.constants[k].data().to_vec();
        d[0] += 1.5;
        plan.constants[k] = Tensor::new(&shape, d).unwrap();
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::FoldScaleMismatch { audit: 0, .. })
        ));
    }

    #[test]
    fn wrong_kernel_family_fails_fold_audit() {
        let g = lower::beamform(1, 4, 64, &[0, 1, 2, 3], &[1.0, 0.5, -0.5, 2.0]).unwrap();
        let mut plan = compile(&g);
        let ai = plan
            .fold_audits
            .iter()
            .position(|a| a.kind == FoldKind::FramingDepthwise)
            .expect("beamform must record a framing-depthwise fold");
        plan.fold_audits[ai].kind = FoldKind::FramingConv;
        let err = plan.verify().unwrap_err();
        assert!(
            matches!(err, VerifyError::FoldWrongKernelFamily { audit, .. } if audit == ai),
            "got {err}"
        );
    }

    #[test]
    fn corrupt_chain_sign_fails_fold_audit() {
        let gains: Vec<f32> = (0..16).map(|i| 0.25 + 0.1 * i as f32).collect();
        let mut plan = compile(&lower::fx_correlate(1, 128, 16, 8, &gains).unwrap());
        let ai = plan
            .fold_audits
            .iter()
            .position(|a| a.kind == FoldKind::ScaleChain)
            .expect("fx_correlate must record a scale-chain fold");
        plan.fold_audits[ai].win[0] = 2.0;
        let err = plan.verify().unwrap_err();
        assert!(
            matches!(err, VerifyError::FoldChainSignMismatch { audit, .. } if audit == ai),
            "got {err}"
        );
    }

    #[test]
    fn split_inner_must_divide_leading_axis() {
        let mut plan = compile(&lower::stft(2, 64, 16, 16).unwrap());
        let (si, step) = plan
            .steps
            .iter_mut()
            .enumerate()
            .find(|(_, s)| s.args[0].view.split0.is_some())
            .expect("batched stft must produce a split activation");
        let sp = step.args[0].view.split0.as_mut().unwrap();
        sp.inner += 1; // 8 rows, inner 5: not a divisor
        let err = plan.verify().unwrap_err();
        assert!(
            matches!(err, VerifyError::SplitNotDivisible { step, .. } if step == si),
            "got {err}"
        );
    }

    #[test]
    fn shrunken_slot_is_oob_write() {
        let mut plan = compile(&lower::fir(2, 64, &[0.5; 8]).unwrap());
        plan.slot_sizes[plan.steps[0].out_slot] = 1;
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::OobWrite { step: 0, .. })
        ));
    }

    #[test]
    fn non_unit_fused_sign_is_rejected() {
        let mut plan = compile(&add_graph(true));
        let Kernel::FusedEw { signs } = &mut plan.steps[0].kernel else {
            panic!("Add must compile to a fused elementwise step");
        };
        signs[0] = 2.0;
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::BadSign { step: 0, term: 0 })
        ));
    }

    #[test]
    fn inflated_out_shape_is_shape_mismatch() {
        let mut plan = compile(&lower::fir(2, 64, &[0.5; 8]).unwrap());
        plan.steps[0].out_shape[2] += 1;
        assert!(matches!(
            plan.verify(),
            Err(VerifyError::ShapeMismatch { step: 0, .. })
        ));
    }

    // ---- reduction-order certificates ----

    #[test]
    fn every_declared_blocking_satisfies_the_oracle() {
        for f in [
            KernelFamily::StandardConv,
            KernelFamily::DepthwiseConv,
            KernelFamily::PointwiseConv,
            KernelFamily::PointwiseConvPacked,
            KernelFamily::FullyConnected,
            KernelFamily::FullyConnectedPacked,
            KernelFamily::Materialize,
            KernelFamily::FusedEw,
        ] {
            check_blocking(f, &fused::declared_blocking(f))
                .unwrap_or_else(|e| panic!("{f:?}: {e}"));
        }
    }

    #[test]
    fn hostile_blockings_are_rejected() {
        // vectorizing the cin reduction axis (blocking it) must fail
        let err = check_blocking(
            KernelFamily::StandardConv,
            &Blocking {
                blocked: &[Axis::T, Axis::Cin],
                reduction: &[Axis::Cin, Axis::Tap],
            },
        )
        .unwrap_err();
        assert!(matches!(err, VerifyError::ReductionOrderViolation { .. }));
        // reordering the reduction (taps outer, cin inner) must fail too
        let err = check_blocking(
            KernelFamily::StandardConv,
            &Blocking {
                blocked: &[Axis::T, Axis::Cout],
                reduction: &[Axis::Tap, Axis::Cin],
            },
        )
        .unwrap_err();
        assert!(matches!(err, VerifyError::ReductionOrderViolation { .. }));
    }

    // ---- single-field mutation fuzzer ----

    /// xorshift64 — deterministic, dependency-free.
    struct Rng(u64);
    impl Rng {
        fn next(&mut self) -> u64 {
            let mut x = self.0;
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            self.0 = x;
            x
        }
        fn pick(&mut self, n: usize) -> usize {
            (self.next() % n as u64) as usize
        }
    }

    /// Corrupt exactly one field of a freshly compiled, verified plan and
    /// assert the verifier catches it.  Every mutation in the catalog is
    /// guaranteed-illegal by construction.
    #[test]
    fn mutation_fuzzer_catches_single_field_corruptions() {
        type Mk = Box<dyn Fn() -> Graph>;
        let corpus: Vec<Mk> = vec![
            Box::new(|| lower::ewmult(4, 4)),
            Box::new(|| lower::ewadd(3, 5)),
            Box::new(|| lower::dft(2, 8)),
            Box::new(|| lower::idft(2, 8)),
            Box::new(|| lower::matmul(3, 4, 5)),
            Box::new(|| lower::fir(2, 64, &[0.5; 8]).unwrap()),
            Box::new(|| lower::stft(2, 64, 16, 16).unwrap()),
            Box::new(|| lower::pfb(1, 64, dsp::PfbConfig::new(8, 4)).unwrap()),
            Box::new(|| lower::complex_mul(2, 8)),
            Box::new(|| lower::magnitude_sq(2, 8)),
            Box::new(|| lower::iir(2, 64, &[0.5, 0.25], &[0.3], 3).unwrap()),
            Box::new(|| lower::xcorr(2, 48, 7).unwrap()),
            Box::new(|| lower::beamform(2, 4, 32, &[0, 2, 1, 3], &[1.0, 0.5, -0.5, 2.0]).unwrap()),
            Box::new(|| lower::fx_correlate(1, 96, 16, 8, &[0.5; 16]).unwrap()),
            Box::new(|| lower::spectrometer(1, 128, dsp::PfbConfig::new(8, 4)).unwrap()),
        ];
        let mut rng = Rng(0x5eed_cafe_f00d_1234);
        let mut tally = [0usize; 7];
        for it in 0..64 {
            let g = corpus[rng.pick(corpus.len())]();
            let mut plan = compile(&g);
            let nsteps = plan.steps.len();
            let mutation = rng.pick(7);
            // fall back to the always-applicable offset bump when a
            // mutation has no target in this plan
            let applied = match mutation {
                1 => {
                    plan.steps[rng.pick(nsteps)].out_slot = plan.slot_sizes.len() + 7;
                    1
                }
                2 => {
                    let s = plan.steps[rng.pick(nsteps)].out_slot;
                    plan.slot_sizes[s] = 0;
                    2
                }
                3 => {
                    let mut dep = None;
                    'outer: for j in 1..nsteps {
                        for i in 0..j {
                            let prod = plan.steps[i].out_root;
                            if plan.steps[j]
                                .args
                                .iter()
                                .any(|a| matches!(a.loc, Loc::Slot(_)) && a.root == prod)
                            {
                                dep = Some((i, j));
                                break 'outer;
                            }
                        }
                    }
                    match dep {
                        Some((i, j)) => {
                            plan.steps.swap(i, j);
                            3
                        }
                        None => {
                            plan.steps[0].args[0].view.offset += 1_000_000;
                            0
                        }
                    }
                }
                4 => {
                    plan.steps[rng.pick(nsteps)].out_shape[0] += 1;
                    4
                }
                5 => {
                    let s = rng.pick(nsteps);
                    if plan.steps[s].args.len() > 1 {
                        plan.steps[s].args.pop();
                        5
                    } else {
                        plan.steps[s].args[0].view.offset += 1_000_000;
                        0
                    }
                }
                6 => {
                    let o = rng.pick(plan.outputs.len());
                    plan.outputs[o].view.offset += 1_000_000;
                    6
                }
                _ => {
                    let s = rng.pick(nsteps);
                    let a = rng.pick(plan.steps[s].args.len());
                    plan.steps[s].args[a].view.offset += 1_000_000;
                    0
                }
            };
            tally[applied] += 1;
            assert!(
                plan.verify().is_err(),
                "iteration {it}: mutation {applied} survived verification"
            );
        }
        // the catalog must actually exercise more than the fallback
        assert!(
            tally.iter().filter(|&&c| c > 0).count() >= 5,
            "mutation coverage too thin: {tally:?}"
        );
    }

    // ---- positive coverage (the full corpus sweep lives in
    // rust/tests/properties.rs) ----

    #[test]
    fn verifier_accepts_fused_and_unfused_stft() {
        for fusion in [true, false] {
            let plan = ExecPlan::compile_with(
                &lower::stft(2, 64, 16, 16).unwrap(),
                CompileOptions {
                    fusion,
                    verify: false,
                },
            )
            .unwrap();
            plan.verify()
                .unwrap_or_else(|e| panic!("fusion={fusion}: {e}"));
        }
    }

    #[test]
    fn verifier_accepts_every_new_lowering_fused_and_unfused() {
        let gains: Vec<f32> = (0..16).map(|i| 0.5 + 0.05 * i as f32).collect();
        let graphs = [
            lower::iir(2, 64, &[0.5, 0.25], &[0.3, 0.1], 3).unwrap(),
            lower::xcorr(2, 48, 7).unwrap(),
            lower::fx_correlate(2, 128, 16, 8, &gains).unwrap(),
            lower::beamform(2, 4, 64, &[0, 3, 1, 2], &[1.0, 0.8, -0.6, 0.4]).unwrap(),
            lower::spectrometer(2, 256, dsp::PfbConfig::new(8, 4)).unwrap(),
        ];
        for (gi, g) in graphs.iter().enumerate() {
            for fusion in [true, false] {
                let plan = ExecPlan::compile_with(
                    g,
                    CompileOptions {
                        fusion,
                        verify: false,
                    },
                )
                .unwrap();
                plan.verify()
                    .unwrap_or_else(|e| panic!("graph {gi}, fusion={fusion}: {e}"));
            }
        }
    }
}
