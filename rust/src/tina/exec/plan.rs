//! Graph -> [`ExecPlan`] compilation and plan execution.
//!
//! `ExecPlan::compile` runs once per (op, shape signature) and does all the
//! work the naive interpreter repeats on every request:
//!
//! * **constant baking** — `Constant` nodes are cloned into the plan once
//!   (the interpreter clones every weight tensor on every run), and
//!   constant weight matrices of `FullyConnected`/`PointwiseConv` steps
//!   are additionally pre-packed into [`fused::NR`]-wide column panels the
//!   register-tiled microkernels stream;
//! * **view propagation** — every value is a strided [`View`] over a
//!   backing buffer.  `Reshape`, `Transpose2`, `Permute3` and
//!   `StridedSlice` compile to metadata-only stride rewrites; the kernels
//!   read activations through the strides, so permute→conv chains (PFB,
//!   STFT framing) execute with **zero copies**.  An explicit
//!   [`Kernel::Materialize`] step is inserted only when contiguity is
//!   unavoidable: a `Reshape` whose strided source cannot be re-grouped
//!   without copying, or a weight/bias/elementwise operand (those kernels
//!   require dense layout);
//! * **elementwise fusion** — single-consumer `Add`/`Sub` chains collapse
//!   into one [`fused::fused_ew`] pass, and `Add`/`Sub` of a layer output
//!   with a per-channel-uniform constant folds into that layer's bias;
//! * **plan-level fusion pass** — after view propagation and before
//!   liveness, adjacent compiled steps are rewritten (`fuse_protos`):
//!   a merged-axis `Materialize` (batched STFT's `(B, F, nfft) ->
//!   (B*F, nfft)` frame regrouping) becomes a `Split0` loop-nest
//!   reindex its conv-family consumers read directly; a
//!   [`FusionHint::Window`]-tagged M=1 depthwise window over a one-hot
//!   ±1 framing producer (standard conv — STFT — or depthwise conv —
//!   beamform delays) folds into the producer by pre-scaling its taps;
//!   and a [`FusionHint::Chain`]-tagged all-±1 depthwise link over an
//!   M=1 depthwise scale (the FX correlator's conjugation over its gain
//!   calibration) folds into the scale by pre-signing its taps and
//!   bias.  All rewrites preserve **bit-for-bit** interpreter equality
//!   (the fold's skip rules reject any candidate whose rewrite would
//!   reassociate or re-round a float operation); with them, every
//!   shipped lowering compiles with `materialize_count() == 0` at every
//!   batch size.
//!   [`ExecPlan::fused_steps`] / [`ExecPlan::fusion_eliminated_copies`]
//!   introspect the pass, and [`CompileOptions`] can switch it off
//!   (ablation 8);
//! * **liveness analysis** — every materialized value gets a slot in a
//!   slab [`Arena`] via linear-scan allocation over the topological
//!   schedule; slot sizes derive from *materialized* extents (views add
//!   nothing), and because a view shares its backing value's root, the
//!   backing slot is provably not recycled or overwritten before the
//!   view's last consumer — the independent static verifier
//!   ([`ExecPlan::verify`], see [`super::verify`]) re-proves this
//!   symbolically from the compiled artifact, including for view-shaped
//!   plan outputs;
//! * **threaded execution** — the kernels in [`fused`] fan independent
//!   output rows across the thread pool.
//!
//! Plans are immutable and shareable (`Send + Sync`); the arena is the
//! only mutable run state, so one plan serves many concurrent requests
//! (see [`super::Planned`]).

use super::arena::Arena;
use super::fused;
use crate::tensor::Tensor;
use crate::tina::graph::{FusionHint, Graph, NodeOp, ValueId};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, HashSet};

/// Where a value's bytes live at execution time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum Loc {
    /// Caller-provided input tensor (never copied).
    External(usize),
    /// Plan-owned constant (baked at compile time).
    Const(usize),
    /// Arena slot (recycled across values with disjoint lifetimes).
    Slot(usize),
}

/// Row-major strides for a dense shape.
fn row_major(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Two-level decomposition of a view's leading axis: logical row `r`
/// contributes `(r / inner) * outer_stride + (r % inner) * strides[0]`
/// to the element address.  This expresses the one index mapping plain
/// strides cannot — merging two axes that are not dense with respect to
/// each other (batched STFT's `(B, F, nfft) -> (B*F, nfft)` frame
/// regrouping).  Produced only by the fusion pass, which re-expresses
/// such a `Materialize` copy as this loop-nest reindex; consumed only by
/// the conv-family kernels (their row loop applies the split per output
/// row, a divide/modulo per row, not per element).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) struct Split0 {
    /// Extent of the inner (faster-varying) factor of the leading axis.
    pub(super) inner: usize,
    /// Element stride of the outer factor.
    pub(super) outer_stride: usize,
}

/// A strided window onto a backing buffer: `elem(idx) = backing[offset +
/// dot(idx, strides)]`.  Movement ops rewrite only this metadata.  The
/// optional [`Split0`] generalizes the leading axis to a two-level
/// (outer, inner) decomposition; see its docs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(super) struct View {
    pub(super) offset: usize,
    pub(super) shape: Vec<usize>,
    pub(super) strides: Vec<usize>,
    pub(super) split0: Option<Split0>,
}

impl View {
    fn contiguous(shape: &[usize]) -> View {
        View {
            offset: 0,
            strides: row_major(shape),
            shape: shape.to_vec(),
            split0: None,
        }
    }

    pub(super) fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Dense row-major layout (strides of size-1 axes are irrelevant).
    /// Split views are never treated as dense — the whole point of the
    /// split is that the leading axis is *not* affine.
    pub(super) fn is_contiguous(&self) -> bool {
        if self.split0.is_some() {
            return false;
        }
        let mut expect = 1usize;
        for (&d, &s) in self.shape.iter().zip(&self.strides).rev() {
            if d != 1 && s != expect {
                return false;
            }
            expect *= d;
        }
        true
    }

    /// One past the largest element index the view can touch, relative to
    /// the backing buffer's start.
    fn end(&self) -> usize {
        let mut last = self.offset;
        for (i, (&d, &s)) in self.shape.iter().zip(&self.strides).enumerate() {
            let dm = d.max(1) - 1;
            last += match (i, self.split0) {
                (0, Some(sp)) => {
                    // the maximum of (r/inner)*outer + (r%inner)*s over
                    // r <= dm is reached either at r = dm itself or at
                    // the last row of the second-to-last outer block
                    let (q, r) = (dm / sp.inner, dm % sp.inner);
                    let c1 = q * sp.outer_stride + r * s;
                    let c2 = if q > 0 {
                        (q - 1) * sp.outer_stride + (sp.inner - 1) * s
                    } else {
                        0
                    };
                    c1.max(c2)
                }
                _ => dm * s,
            };
        }
        last + 1
    }

    fn transpose2(&self) -> View {
        debug_assert!(self.split0.is_none(), "movement over a split view");
        View {
            offset: self.offset,
            shape: vec![self.shape[1], self.shape[0]],
            strides: vec![self.strides[1], self.strides[0]],
            split0: None,
        }
    }

    fn permute3(&self, p: [usize; 3]) -> View {
        debug_assert!(self.split0.is_none(), "movement over a split view");
        View {
            offset: self.offset,
            shape: p.iter().map(|&i| self.shape[i]).collect(),
            strides: p.iter().map(|&i| self.strides[i]).collect(),
            split0: None,
        }
    }

    fn stride_axis(&self, axis: usize, step: usize, count: usize) -> View {
        debug_assert!(self.split0.is_none(), "movement over a split view");
        let mut v = self.clone();
        v.shape[axis] = count;
        v.strides[axis] *= step;
        v
    }

    /// Try to express a reshape as a pure stride rewrite (the classic
    /// no-copy reshape: axes may merge only where the view is dense across
    /// the merged group).  Returns `None` when a copy is unavoidable.
    fn reshape(&self, new_shape: &[usize]) -> Option<View> {
        debug_assert_eq!(self.numel(), new_shape.iter().product::<usize>());
        if self.split0.is_some() {
            return None;
        }
        // size-1 axes carry no layout information: drop them first
        let mut olddims: Vec<usize> = Vec::with_capacity(self.shape.len());
        let mut oldstrides: Vec<usize> = Vec::with_capacity(self.shape.len());
        for (&d, &s) in self.shape.iter().zip(&self.strides) {
            if d != 1 {
                olddims.push(d);
                oldstrides.push(s);
            }
        }
        let (oldnd, newnd) = (olddims.len(), new_shape.len());
        let mut newstrides = vec![0usize; newnd];
        let (mut oi, mut oj, mut ni, mut nj) = (0usize, 1usize, 0usize, 1usize);
        while ni < newnd && oi < oldnd {
            let mut np = new_shape[ni];
            let mut op = olddims[oi];
            while np != op {
                if np < op {
                    np *= new_shape[nj];
                    nj += 1;
                } else {
                    op *= olddims[oj];
                    oj += 1;
                }
            }
            // merging [oi, oj) demands density across the group
            for ok in oi..oj - 1 {
                if oldstrides[ok] != olddims[ok + 1] * oldstrides[ok + 1] {
                    return None;
                }
            }
            newstrides[nj - 1] = oldstrides[oj - 1];
            for nk in (ni + 1..nj).rev() {
                newstrides[nk - 1] = newstrides[nk] * new_shape[nk];
            }
            ni = nj;
            nj += 1;
            oi = oj;
            oj += 1;
        }
        // any remaining new axes are size 1; give them the innermost stride
        let tail = if ni > 0 { newstrides[ni - 1] } else { 1 };
        for nk in ni..newnd {
            debug_assert_eq!(new_shape[nk], 1);
            newstrides[nk] = tail;
        }
        Some(View {
            offset: self.offset,
            shape: new_shape.to_vec(),
            strides: newstrides,
            split0: None,
        })
    }
}

/// One resolved kernel argument: a strided view over a located backing.
#[derive(Debug, Clone)]
pub(super) struct ArgRef {
    pub(super) loc: Loc,
    pub(super) view: View,
    /// Value id of the backing buffer (diagnostics + liveness validation).
    pub(super) root: usize,
}

/// Backing slice a view indexes into (full extent; the kernels apply the
/// view's offset and strides themselves).
fn backing<'a>(
    a: &ArgRef,
    inputs: &'a [Tensor],
    constants: &'a [Tensor],
    arena: &'a Arena,
) -> &'a [f32] {
    match a.loc {
        Loc::External(i) => inputs[i].data(),
        Loc::Const(k) => constants[k].data(),
        Loc::Slot(s) => arena.slot(s),
    }
}

#[derive(Debug, Clone)]
pub(super) enum Kernel {
    StandardConv1d,
    DepthwiseConv1d,
    /// `packed` indexes [`ExecPlan::packed`] when the weight is a plan
    /// constant pre-packed into NR panels.
    PointwiseConv { packed: Option<usize> },
    FullyConnected { packed: Option<usize> },
    /// Copy a strided view into a dense buffer.  `origin` names the graph
    /// op that made the copy unavoidable and `movement` records whether it
    /// was one of the transpose/permute/slice ops (plan introspection —
    /// those must normally stay metadata-only).
    Materialize {
        origin: &'static str,
        movement: bool,
    },
    /// Collapsed Add/Sub chain; `signs[i]` applies to `args[i]`.
    FusedEw { signs: Vec<f32> },
}

#[derive(Debug, Clone)]
pub(super) struct Step {
    pub(super) kernel: Kernel,
    pub(super) args: Vec<ArgRef>,
    pub(super) out_slot: usize,
    pub(super) out_shape: Vec<usize>,
    /// Value id this step produces (liveness validation).
    pub(super) out_root: usize,
}

/// Compile-time switches for [`ExecPlan::compile_with`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompileOptions {
    /// Run the plan-level fusion pass (window-into-framing-conv constant
    /// folding plus merged-axis materialize elimination).  On by default —
    /// the serving configuration; the ablation bench switches it off to
    /// measure what the pass buys.
    pub fusion: bool,
    /// Run the independent static verifier ([`ExecPlan::verify`]) over the
    /// freshly compiled plan and fail compilation if any proof obligation
    /// does not hold.  Defaults to on under `debug_assertions` (so every
    /// plan the test suite, property tests and fuzzer compile is verified)
    /// and off in release, where the router offers an opt-in metered path
    /// instead.
    pub verify: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            fusion: true,
            verify: cfg!(debug_assertions),
        }
    }
}

/// A compiled, immutable execution plan for one graph.
#[derive(Debug)]
pub struct ExecPlan {
    pub(super) input_shapes: Vec<Vec<usize>>,
    pub(super) constants: Vec<Tensor>,
    /// Pre-packed NR-panel copies of constant weight matrices.
    pub(super) packed: Vec<Vec<f32>>,
    pub(super) steps: Vec<Step>,
    pub(super) slot_sizes: Vec<usize>,
    pub(super) outputs: Vec<ArgRef>,
    /// Kernel steps removed by the fusion pass's window fold.
    pub(super) fused_steps: usize,
    /// `Materialize` copies the fusion pass re-expressed as split-view
    /// reads.
    pub(super) fusion_eliminated_copies: usize,
    /// One certificate per window fold, recorded at fold time so the
    /// static verifier can re-prove each fold's legality on the final
    /// plan (see [`FoldAudit`]).
    pub(super) fold_audits: Vec<FoldAudit>,
}

/// Compile-time storage class of a value (pass-A bookkeeping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Storage {
    External(usize),
    Const(usize),
    /// Produced by an emitted step; slot assigned in the liveness pass.
    Owned,
}

/// Compile-time resolution of a value: storage class + backing root +
/// strided view.  Doubles as a proto-step argument.
#[derive(Debug, Clone)]
struct ValInfo {
    st: Storage,
    root: usize,
    view: View,
}

#[derive(Debug)]
struct ProtoStep {
    kernel: Kernel,
    args: Vec<ValInfo>,
    out_vid: usize,
    out_shape: Vec<usize>,
}

/// If `t` (shaped like a layer output, channel axis 1) is constant along
/// every non-channel coordinate, return the per-channel values.
fn per_channel_uniform(t: &Tensor, out_shape: &[usize]) -> Option<Vec<f32>> {
    let (outer, c, inner) = match *out_shape {
        [a, b, w] => (a, b, w),
        [a, b] => (a, b, 1),
        _ => return None,
    };
    if t.shape() != out_shape {
        return None;
    }
    let d = t.data();
    let vals: Vec<f32> = (0..c).map(|ch| d[ch * inner]).collect();
    for o in 0..outer {
        for (ch, &v) in vals.iter().enumerate() {
            for i in 0..inner {
                if d[(o * c + ch) * inner + i] != v {
                    return None;
                }
            }
        }
    }
    Some(vals)
}

/// Flatten an Add/Sub chain rooted at node `j` into signed terms, left to
/// right.  Only first operands are ever marked inlined (see the fusion
/// decision pass), so the flattened sequence reproduces the chain's f32
/// rounding exactly.
fn expand_terms(
    g: &Graph,
    inlined: &[bool],
    n_inputs: usize,
    j: usize,
    sign: f32,
    out: &mut Vec<(f32, usize)>,
) {
    let node = &g.nodes[j];
    let (sa, sb) = match node.op {
        NodeOp::Add => (sign, sign),
        NodeOp::Sub => (sign, -sign),
        _ => unreachable!("expand_terms on non-elementwise node"),
    };
    for (v, s) in [(node.inputs[0], sa), (node.inputs[1], sb)] {
        match v.0.checked_sub(n_inputs) {
            Some(cj) if inlined[cj] => expand_terms(g, inlined, n_inputs, cj, s, out),
            _ => out.push((s, v.0)),
        }
    }
}

/// Outcome of the plan-level fusion pass: counters plus one audit
/// certificate per window fold for the static verifier.
#[derive(Debug, Default)]
struct FusionOutcome {
    fused_steps: usize,
    eliminated_copies: usize,
    fold_audits: Vec<FoldAudit>,
}

/// Upper bound on the window fold's compile-time index-correspondence
/// scan (elements of the window's activation view); larger candidates
/// are skipped — never wrong, just left unfused.
const FOLD_SCAN_CAP: usize = 1 << 22;

/// True when `arg_idx` of `kernel` is an activation read through
/// [`fused::X3`] strides — the only argument position that may carry a
/// [`Split0`] (weights, biases and elementwise terms stream dense memory).
fn is_x3_activation(kernel: &Kernel, arg_idx: usize) -> bool {
    arg_idx == 0
        && matches!(
            kernel,
            Kernel::StandardConv1d | Kernel::DepthwiseConv1d | Kernel::PointwiseConv { .. }
        )
}

/// The plan constant index behind `a`, when `a` reads constant storage as
/// a dense offset-0 view covering every element (view order == data
/// order, so the fold may reason about the raw data).
fn whole_const(a: &ValInfo, constants: &[Tensor]) -> Option<usize> {
    let Storage::Const(k) = a.st else { return None };
    if a.view.offset == 0 && a.view.is_contiguous() && a.view.numel() == constants[k].len() {
        Some(k)
    } else {
        None
    }
}

/// Identity view over a value's dense extent with exactly `shape`
/// (element i of the view is element i of the backing value).
fn is_identity_view(v: &View, shape: &[usize]) -> bool {
    v.offset == 0 && v.shape == shape && v.is_contiguous()
}

/// Check whether the `Materialize` proto at `i` merely merges a rank-3
/// view's two leading axes — the `(A, B, C) -> (A*B, C, 1)` regrouping
/// batched STFT framing produces — and every consumer reads the copy as
/// a rank-3 identity activation of a conv-family kernel.  If so, return
/// the [`Split0`] view those consumers can read *instead* of the copy:
/// the non-affine regrouping becomes a per-output-row reindex inside the
/// kernel loop nest, and the copy disappears.
fn try_merge_reindex(
    protos: &[ProtoStep],
    i: usize,
    output_roots: &HashSet<usize>,
) -> Option<ValInfo> {
    let p = &protos[i];
    if !matches!(p.kernel, Kernel::Materialize { .. }) {
        return None;
    }
    let a = &p.args[0];
    if a.view.split0.is_some() || a.view.shape.len() != 3 {
        return None;
    }
    let (da, db, dc) = (a.view.shape[0], a.view.shape[1], a.view.shape[2]);
    if da * db * dc == 0 || p.out_shape != [da * db, dc, 1] {
        return None;
    }
    // a plan output must stay a dense buffer (the output gather does not
    // know split views)
    if output_roots.contains(&p.out_vid) {
        return None;
    }
    for q in &protos[i + 1..] {
        for (ai, qa) in q.args.iter().enumerate() {
            if qa.root != p.out_vid {
                continue;
            }
            if !is_x3_activation(&q.kernel, ai) || !is_identity_view(&qa.view, &p.out_shape) {
                return None;
            }
        }
    }
    Some(ValInfo {
        st: a.st,
        root: a.root,
        view: View {
            offset: a.view.offset,
            shape: p.out_shape.clone(),
            strides: vec![a.view.strides[1], a.view.strides[2], a.view.strides[2]],
            split0: Some(Split0 {
                inner: db,
                outer_stride: a.view.strides[0],
            }),
        },
    })
}

/// Which fusion rewrite produced a [`FoldAudit`] — the verifier re-proves
/// a different set of obligations per kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum FoldKind {
    /// M=1 depthwise window folded into a one-hot ±1 `StandardConv1d`
    /// framing producer (the STFT window fold).
    FramingConv,
    /// M=1 depthwise window folded into a one-hot ±1 `DepthwiseConv1d`
    /// framing producer (beamform's gains into its delay taps).
    FramingDepthwise,
    /// All-±1 depthwise chain link folded into an M=1 depthwise scale
    /// producer by pre-signing its taps and bias (the FX correlator's
    /// conjugation into its gain calibration).
    ScaleChain,
}

/// The window fold's verified rewrite: which conv proto absorbs the
/// window, its pre-scaled replacement kernel, and the evidence the fold
/// decision rested on (kept for the verifier's audit certificate).
struct WindowFold {
    kind: FoldKind,
    conv: usize,
    scaled_kernel: Tensor,
    /// Per conv output channel: flat index + sign of the original
    /// one-hot ±1 tap, or `None` for an all-zero row.
    hot: Vec<Option<(usize, f32)>>,
    /// The conv's original bias (proven all-zero).
    orig_bias: Vec<f32>,
    /// The window's per-channel scale factors.
    win: Vec<f32>,
}

/// Compile-time certificate of one fold, recorded by [`fuse_protos`] so
/// the static verifier ([`ExecPlan::verify`]) can independently re-prove
/// the fold's legality on the *final* plan.  For the window kinds: the
/// pre-scaled kernel must be exactly the recorded one-hot ±1 structure
/// scaled by the recorded window, the adopted bias must be the window's
/// bias, the original conv bias must have been all-zero, the recorded
/// activation view must land every element on the matching conv output
/// channel, and the folded-away window value must never resurface.  For
/// [`FoldKind::ScaleChain`]: the recorded per-channel factors (`win`)
/// must all be ±1, the pre-signed kernel must be exactly the recorded
/// producer gains times those signs, and the adopted bias exactly the
/// recorded producer bias times those signs.
#[derive(Debug, Clone)]
pub(super) struct FoldAudit {
    /// Which rewrite this audit certifies.
    pub(super) kind: FoldKind,
    /// Value id of the producer step the folded value merged into.
    pub(super) conv_root: usize,
    /// Value id of the eliminated step (must not resurface).
    pub(super) folded_root: usize,
    /// Plan-constant index of the pre-scaled producer kernel.
    pub(super) scaled_const: usize,
    /// Plan-constant index of the adopted producer bias.
    pub(super) bias_const: usize,
    /// Per-channel factors of the folded step (the window's scales, or
    /// the chain link's ±1 signs — copied at fold time).
    pub(super) win: Vec<f32>,
    /// Adopted bias values (the window's bias, or the pre-signed
    /// producer bias for [`FoldKind::ScaleChain`]).
    pub(super) wbias: Vec<f32>,
    /// Original producer taps: per output channel, the one-hot tap's
    /// flat index within its row and its ±1 sign for the window kinds,
    /// or `Some((0, gain))` for [`FoldKind::ScaleChain`]'s M = 1 rows;
    /// `None` for an all-zero row.
    pub(super) hot: Vec<Option<(usize, f32)>>,
    /// Original producer bias (all-zero for the window kinds; the
    /// pre-sign gain-stage bias for [`FoldKind::ScaleChain`]).
    pub(super) orig_bias: Vec<f32>,
    /// The folded step's activation view — the view through which its
    /// consumers now read the re-scaled producer output.
    pub(super) act_view: View,
}

/// Check whether the depthwise proto at `j` is a foldable window multiply
/// (graph node tagged [`FusionHint::Window`]) over a framing producer —
/// a `StandardConv1d` (STFT framing) or a `DepthwiseConv1d` (beamform
/// delays) — and build the pre-scaled producer kernel if so.
///
/// Every precondition is re-proved here — the hint only nominates
/// candidates:
///
/// * window kernel is a whole-tensor constant of shape `(C, 1)` (M = 1:
///   a pure per-channel scale) and the window bias a whole-tensor
///   constant `(C,)`;
/// * the activation is a rank-3 view of a `StandardConv1d` or
///   `DepthwiseConv1d` proto whose weights are a whole-tensor constant
///   with **one-hot ±1 rows** (at most one nonzero tap per output
///   channel, and that tap exactly `±1.0`) and whose bias is exactly
///   zero — so each producer output element is a single `±x` with no
///   f32 rounding of its own, and pre-scaling the tap to `±win[c]`
///   performs the window's multiply with the interpreter's exact
///   rounding (`(x * ±1) * w == x * ±w` bitwise; general taps would
///   reassociate `(x*t)*w` into `x*(t*w)`, which rounds differently, so
///   they are skipped);
/// * the conv output has no other reader and is not a plan output
///   (anything else would observe pre-window values);
/// * every consumer of the window output is a rank-3 identity
///   conv-family activation (it will read the re-scaled conv output
///   through the window's own — possibly split — view instead);
/// * an exhaustive compile-time scan proves every element the window
///   reads lands on the conv output's channel axis at the window's own
///   channel, so the per-channel scale factors line up.
fn try_window_fold(
    g: &Graph,
    n_inputs: usize,
    protos: &[ProtoStep],
    j: usize,
    output_roots: &HashSet<usize>,
    constants: &[Tensor],
) -> Option<WindowFold> {
    let p = &protos[j];
    if !matches!(p.kernel, Kernel::DepthwiseConv1d) {
        return None;
    }
    let node = g.nodes.get(p.out_vid.checked_sub(n_inputs)?)?;
    if node.hint != FusionHint::Window {
        return None;
    }
    let [x, k, b] = p.args.as_slice() else {
        return None;
    };
    let kc = whole_const(k, constants)?;
    if k.view.shape.len() != 2 || k.view.shape[1] != 1 {
        return None;
    }
    let c = k.view.shape[0];
    // the window bias must be a whole-tensor constant (C,): its ValInfo
    // moves to the conv verbatim
    whole_const(b, constants)?;
    if b.view.shape != [c] {
        return None;
    }
    if x.st != Storage::Owned || x.view.shape.len() != 3 || x.view.shape[1] != c {
        return None;
    }
    let conv_i = protos[..j].iter().position(|q| {
        q.out_vid == x.root
            && matches!(q.kernel, Kernel::StandardConv1d | Kernel::DepthwiseConv1d)
    })?;
    let conv = &protos[conv_i];
    let kind = match conv.kernel {
        Kernel::StandardConv1d => FoldKind::FramingConv,
        _ => FoldKind::FramingDepthwise,
    };
    let ckc = whole_const(&conv.args[1], constants)?;
    let ks = &conv.args[1].view.shape;
    // standard framing kernel is (C, cin, ntaps); depthwise is (C, M)
    let row_len = match kind {
        FoldKind::FramingConv if ks.len() == 3 && ks[0] == c => ks[1] * ks[2],
        FoldKind::FramingDepthwise if ks.len() == 2 && ks[0] == c => ks[1],
        _ => return None,
    };
    let kdata = constants[ckc].data();
    let mut hot: Vec<Option<(usize, f32)>> = Vec::with_capacity(c);
    for row in kdata.chunks(row_len) {
        let mut tap: Option<(usize, f32)> = None;
        for (pos, &v) in row.iter().enumerate() {
            if v != 0.0 {
                if (v != 1.0 && v != -1.0) || tap.is_some() {
                    return None;
                }
                tap = Some((pos, v));
            }
        }
        hot.push(tap);
    }
    let cbc = whole_const(&conv.args[2], constants)?;
    if constants[cbc].data().iter().any(|&v| v != 0.0) {
        return None;
    }
    let conv_reads = protos
        .iter()
        .flat_map(|q| q.args.iter())
        .filter(|a| a.root == x.root)
        .count();
    if conv_reads != 1 || output_roots.contains(&x.root) {
        return None;
    }
    if output_roots.contains(&p.out_vid) {
        return None;
    }
    for q in &protos[j + 1..] {
        for (ai, qa) in q.args.iter().enumerate() {
            if qa.root != p.out_vid {
                continue;
            }
            if !is_x3_activation(&q.kernel, ai) || !is_identity_view(&qa.view, &p.out_shape) {
                return None;
            }
        }
    }
    let cs = &conv.out_shape;
    if cs.len() != 3 {
        return None;
    }
    let (wc, total) = (cs[2], cs[0] * cs[1] * cs[2]);
    let (t_n, w_n) = (x.view.shape[0], x.view.shape[2]);
    if t_n * c * w_n > FOLD_SCAN_CAP {
        return None;
    }
    let (s0, s1, s2) = (x.view.strides[0], x.view.strides[1], x.view.strides[2]);
    for t in 0..t_n {
        let base = x.view.offset
            + match x.view.split0 {
                Some(sp) => (t / sp.inner) * sp.outer_stride + (t % sp.inner) * s0,
                None => t * s0,
            };
        for ch in 0..c {
            for w in 0..w_n {
                let addr = base + ch * s1 + w * s2;
                if addr >= total || (addr / wc) % c != ch {
                    return None;
                }
            }
        }
    }
    let win = constants[kc].data();
    let mut scaled = kdata.to_vec();
    for (co, row) in scaled.chunks_mut(row_len).enumerate() {
        for v in row {
            *v *= win[co];
        }
    }
    let scaled_kernel = Tensor::new(constants[ckc].shape(), scaled).ok()?;
    Some(WindowFold {
        kind,
        conv: conv_i,
        scaled_kernel,
        hot,
        orig_bias: constants[cbc].data().to_vec(),
        win: win.to_vec(),
    })
}

/// The scale-chain fold's verified rewrite: which M = 1 depthwise scale
/// proto absorbs the tagged chain link, its pre-signed replacement
/// kernel and bias, and the evidence the decision rested on.
struct ChainFold {
    producer: usize,
    scaled_kernel: Tensor,
    scaled_bias: Tensor,
    /// The chain link's per-channel ±1 signs.
    signs: Vec<f32>,
    /// The producer's original per-channel gains.
    gains: Vec<f32>,
    /// The producer's original bias.
    orig_bias: Vec<f32>,
    channels: usize,
}

/// Check whether the depthwise proto at `j` is a foldable M = 1 scale
/// chain link (graph node tagged [`FusionHint::Chain`]) over an M = 1
/// depthwise scale producer, and build the pre-signed kernel/bias if so.
///
/// Every precondition is re-proved here — the hint only nominates
/// candidates:
///
/// * link kernel is a whole-tensor constant `(C, 1)` with every tap
///   exactly `±1.0` and link bias a whole-tensor all-zero constant
///   `(C,)` — the link computes `±y + 0.0` per element, and pre-signing
///   the producer (`(±g)·x` then `+ (±pb)`) reproduces it exactly:
///   negation commutes bitwise with IEEE multiply and add (sign
///   symmetry of round-to-nearest), so no f32 operation is reassociated
///   or re-rounded.  A general link tap would turn `t·(g·x)` into
///   `(t·g)·x`, which rounds differently — skipped;
/// * the activation is the whole output of an earlier `DepthwiseConv1d`
///   proto read through an identity view, and that producer has a
///   whole-constant `(C, 1)` kernel (M = 1: a pure per-channel scale)
///   and a whole-constant `(C,)` bias;
/// * the producer output has no other reader, neither value is a plan
///   output, and neither value is already involved in another fold
///   (folds never cascade — a second rewrite of the same step would
///   invalidate the first fold's audit certificate).
///
/// Later readers of the link output keep their views and simply read
/// the producer's output instead: both values are dense buffers of the
/// same shape, so every downstream view stays valid.
fn try_chain_fold(
    g: &Graph,
    n_inputs: usize,
    protos: &[ProtoStep],
    j: usize,
    output_roots: &HashSet<usize>,
    constants: &[Tensor],
    involved: &HashSet<usize>,
) -> Option<ChainFold> {
    let p = &protos[j];
    if !matches!(p.kernel, Kernel::DepthwiseConv1d) {
        return None;
    }
    let node = g.nodes.get(p.out_vid.checked_sub(n_inputs)?)?;
    if node.hint != FusionHint::Chain {
        return None;
    }
    let [x, k, b] = p.args.as_slice() else {
        return None;
    };
    let kc = whole_const(k, constants)?;
    if k.view.shape.len() != 2 || k.view.shape[1] != 1 {
        return None;
    }
    let c = k.view.shape[0];
    let signs = constants[kc].data();
    if signs.iter().any(|&v| v != 1.0 && v != -1.0) {
        return None;
    }
    let bc = whole_const(b, constants)?;
    if b.view.shape != [c] || constants[bc].data().iter().any(|&v| v != 0.0) {
        return None;
    }
    if x.st != Storage::Owned || involved.contains(&x.root) || involved.contains(&p.out_vid) {
        return None;
    }
    let prod_i = protos[..j]
        .iter()
        .position(|q| q.out_vid == x.root && matches!(q.kernel, Kernel::DepthwiseConv1d))?;
    let prod = &protos[prod_i];
    if prod.out_shape.len() != 3
        || prod.out_shape[1] != c
        || prod.out_shape.iter().product::<usize>() > FOLD_SCAN_CAP
        || !is_identity_view(&x.view, &prod.out_shape)
    {
        return None;
    }
    let pkc = whole_const(&prod.args[1], constants)?;
    if prod.args[1].view.shape != [c, 1] {
        return None;
    }
    let pbc = whole_const(&prod.args[2], constants)?;
    if prod.args[2].view.shape != [c] {
        return None;
    }
    let prod_reads = protos
        .iter()
        .flat_map(|q| q.args.iter())
        .filter(|a| a.root == x.root)
        .count();
    if prod_reads != 1 || output_roots.contains(&x.root) || output_roots.contains(&p.out_vid) {
        return None;
    }
    let gains = constants[pkc].data();
    let orig_bias = constants[pbc].data();
    let scaled_k: Vec<f32> = gains.iter().zip(signs).map(|(&gn, &s)| s * gn).collect();
    let scaled_b: Vec<f32> = orig_bias.iter().zip(signs).map(|(&v, &s)| s * v).collect();
    Some(ChainFold {
        producer: prod_i,
        scaled_kernel: Tensor::new(&[c, 1], scaled_k).ok()?,
        scaled_bias: Tensor::new(&[c], scaled_b).ok()?,
        signs: signs.to_vec(),
        gains: gains.to_vec(),
        orig_bias: orig_bias.to_vec(),
        channels: c,
    })
}

/// Plan-level fusion over the proto schedule — runs after view
/// propagation (pass A) and before read counting / liveness, so the
/// linear scan allocates slots for the *rewritten* steps.  Two rewrites,
/// each verified from scratch ([`FusionHint`]s are advisory) and each
/// preserving the interpreter oracle's per-element f32 operation
/// sequence exactly — a candidate that cannot keep bit-for-bit equality
/// is skipped, never approximated:
///
/// 1. **Merged-axis materialize elimination** ([`try_merge_reindex`]):
///    a `(A, B, C) -> (A*B, C, 1)` regrouping copy becomes a [`Split0`]
///    view its conv-family consumers read directly (bitwise identical —
///    the same elements are read, just without the intermediate buffer);
/// 2. **Window fold** ([`try_window_fold`]): a tagged M=1 depthwise
///    window over a one-hot ±1 framing producer (standard *or*
///    depthwise conv) folds into the producer by pre-scaling its taps
///    and adopting the window's bias at compile time — one kernel step
///    instead of two;
/// 3. **Scale-chain fold** ([`try_chain_fold`]): a tagged all-±1
///    depthwise link over an M=1 depthwise scale folds into the scale
///    by pre-signing its taps and bias.
///
/// Folds never cascade: every value a fold touches goes into an
/// `involved` set later candidates must avoid, so no audit certificate
/// is invalidated by a second rewrite of the same step.
fn fuse_protos(
    g: &Graph,
    n_inputs: usize,
    output_roots: &HashSet<usize>,
    protos: &mut Vec<ProtoStep>,
    constants: &mut Vec<Tensor>,
) -> FusionOutcome {
    let mut out = FusionOutcome::default();
    let mut i = 0;
    while i < protos.len() {
        match try_merge_reindex(protos, i, output_roots) {
            Some(nv) => {
                let vid = protos[i].out_vid;
                protos.remove(i);
                for q in protos[i..].iter_mut() {
                    for a in q.args.iter_mut() {
                        if a.root == vid {
                            *a = nv.clone();
                        }
                    }
                }
                out.eliminated_copies += 1;
            }
            None => i += 1,
        }
    }
    let mut involved: HashSet<usize> = HashSet::new();
    let mut j = 0;
    while j < protos.len() {
        match try_window_fold(g, n_inputs, protos, j, output_roots, constants) {
            Some(fold) => {
                let vid = protos[j].out_vid;
                let x = protos[j].args[0].clone();
                let bias = protos[j].args[2].clone();
                let kshape = fold.scaled_kernel.shape().to_vec();
                constants.push(fold.scaled_kernel);
                let Storage::Const(bias_const) = bias.st else {
                    unreachable!("fold bias proven whole-const");
                };
                out.fold_audits.push(FoldAudit {
                    kind: fold.kind,
                    conv_root: x.root,
                    folded_root: vid,
                    scaled_const: constants.len() - 1,
                    bias_const,
                    win: fold.win,
                    wbias: constants[bias_const].data().to_vec(),
                    hot: fold.hot,
                    orig_bias: fold.orig_bias,
                    act_view: x.view.clone(),
                });
                protos[fold.conv].args[1] = ValInfo {
                    st: Storage::Const(constants.len() - 1),
                    root: usize::MAX,
                    view: View::contiguous(&kshape),
                };
                protos[fold.conv].args[2] = bias;
                protos.remove(j);
                for q in protos[j..].iter_mut() {
                    for a in q.args.iter_mut() {
                        if a.root == vid {
                            *a = x.clone();
                        }
                    }
                }
                involved.insert(x.root);
                involved.insert(vid);
                out.fused_steps += 1;
            }
            None => j += 1,
        }
    }
    let mut j = 0;
    while j < protos.len() {
        match try_chain_fold(g, n_inputs, protos, j, output_roots, constants, &involved) {
            Some(fold) => {
                let vid = protos[j].out_vid;
                let x = protos[j].args[0].clone();
                let c = fold.channels;
                constants.push(fold.scaled_kernel);
                let scaled_const = constants.len() - 1;
                constants.push(fold.scaled_bias);
                let bias_const = constants.len() - 1;
                out.fold_audits.push(FoldAudit {
                    kind: FoldKind::ScaleChain,
                    conv_root: x.root,
                    folded_root: vid,
                    scaled_const,
                    bias_const,
                    win: fold.signs,
                    wbias: constants[bias_const].data().to_vec(),
                    hot: fold.gains.iter().map(|&gn| Some((0, gn))).collect(),
                    orig_bias: fold.orig_bias,
                    act_view: x.view.clone(),
                });
                protos[fold.producer].args[1] = ValInfo {
                    st: Storage::Const(scaled_const),
                    root: usize::MAX,
                    view: View::contiguous(&[c, 1]),
                };
                protos[fold.producer].args[2] = ValInfo {
                    st: Storage::Const(bias_const),
                    root: usize::MAX,
                    view: View::contiguous(&[c]),
                };
                protos.remove(j);
                // readers keep their own views: producer and link
                // outputs are dense buffers of the same shape
                for q in protos[j..].iter_mut() {
                    for a in q.args.iter_mut() {
                        if a.root == vid {
                            a.st = x.st;
                            a.root = x.root;
                        }
                    }
                }
                involved.insert(x.root);
                involved.insert(vid);
                out.fused_steps += 1;
            }
            None => j += 1,
        }
    }
    out
}

/// Pass-A state: resolves every graph value to a (storage, view) pair and
/// emits proto steps, inserting `Materialize` copies only on demand.
struct PassA<'g> {
    g: &'g Graph,
    n_inputs: usize,
    info: Vec<Option<ValInfo>>,
    constants: Vec<Tensor>,
    protos: Vec<ProtoStep>,
    /// Contiguous copies already emitted for non-contiguous views, by the
    /// viewed value's id — shared by every consumer that needs density.
    materialized: HashMap<usize, ValInfo>,
    /// Next synthetic value id (above every graph value id).
    next_vid: usize,
}

impl PassA<'_> {
    fn arg(&self, vid: usize) -> Result<ValInfo> {
        self.info[vid]
            .clone()
            .ok_or_else(|| anyhow!("value {vid} consumed before materialization"))
    }

    /// Like [`PassA::arg`], but guarantees a dense layout: a
    /// non-contiguous view is copied once into a synthetic owned value.
    fn contig_arg(&mut self, vid: usize) -> Result<ValInfo> {
        let a = self.arg(vid)?;
        if a.view.is_contiguous() {
            return Ok(a);
        }
        if let Some(m) = self.materialized.get(&vid) {
            return Ok(m.clone());
        }
        let (origin, movement) = self.origin_of(vid);
        let sv = self.next_vid;
        self.next_vid += 1;
        let shape = a.view.shape.clone();
        self.protos.push(ProtoStep {
            kernel: Kernel::Materialize { origin, movement },
            args: vec![a],
            out_vid: sv,
            out_shape: shape.clone(),
        });
        let m = ValInfo {
            st: Storage::Owned,
            root: sv,
            view: View::contiguous(&shape),
        };
        self.materialized.insert(vid, m.clone());
        Ok(m)
    }

    /// Name + movement-class of the op that produced `vid`
    /// (materialization attribution).
    fn origin_of(&self, vid: usize) -> (&'static str, bool) {
        match vid.checked_sub(self.n_inputs) {
            Some(j) => {
                let op = &self.g.nodes[j].op;
                (op.name(), op.is_strided_movement())
            }
            None => ("input", false),
        }
    }
}

impl ExecPlan {
    /// Compile a validated graph into an execution plan with the default
    /// options (fusion on — the serving configuration).
    pub fn compile(g: &Graph) -> Result<ExecPlan> {
        Self::compile_with(g, CompileOptions::default())
    }

    /// Compile a validated graph into an execution plan under explicit
    /// [`CompileOptions`].
    pub fn compile_with(g: &Graph, opts: CompileOptions) -> Result<ExecPlan> {
        g.validate()?;
        let n_inputs = g.inputs.len();
        let n_values = g.value_count();
        for (i, (id, _)) in g.inputs.iter().enumerate() {
            if id.0 != i {
                bail!("exec plans require graph inputs declared before any node");
            }
        }
        let shapes = g.infer_shapes()?;
        let n_nodes = g.nodes.len();
        let node_of = |v: ValueId| v.0.checked_sub(n_inputs);

        // ---- use counts + single-consumer map -----------------------------
        let mut uses = vec![0usize; n_values];
        let mut consumer: Vec<Option<usize>> = vec![None; n_values];
        for (j, node) in g.nodes.iter().enumerate() {
            for v in &node.inputs {
                uses[v.0] += 1;
                consumer[v.0] = Some(j);
            }
        }
        for v in &g.outputs {
            uses[v.0] += 1;
        }

        // ---- fusion decision 1: fold ew-with-constant into layer bias -----
        // Add(layer, c) / Add(c, layer) / Sub(layer, c) where `layer` has a
        // constant bias and no other consumer, and `c` is per-channel
        // uniform: rewrite the layer's bias, alias the ew node to the layer.
        let mut fold_alias: Vec<Option<ValueId>> = vec![None; n_nodes];
        let mut fused_bias: HashMap<usize, Tensor> = HashMap::new();
        for (j, node) in g.nodes.iter().enumerate() {
            let base_sign = match node.op {
                NodeOp::Add => 1.0f32,
                NodeOp::Sub => -1.0,
                _ => continue,
            };
            let (a, b) = (node.inputs[0], node.inputs[1]);
            let mut candidates = vec![(a, b, base_sign)];
            if matches!(node.op, NodeOp::Add) {
                candidates.push((b, a, 1.0));
            }
            for (lv, cv, csign) in candidates {
                let (Some(li), Some(ci)) = (node_of(lv), node_of(cv)) else {
                    continue;
                };
                if !g.nodes[li].op.is_layer() || uses[lv.0] != 1 || fused_bias.contains_key(&li)
                {
                    continue;
                }
                let NodeOp::Constant(cd) = &g.nodes[ci].op else {
                    continue;
                };
                let Some(bi) = node_of(g.nodes[li].inputs[2]) else {
                    continue;
                };
                let NodeOp::Constant(bias_t) = &g.nodes[bi].op else {
                    continue;
                };
                let Some(chan) = per_channel_uniform(cd, &shapes[lv.0]) else {
                    continue;
                };
                let mut nb = bias_t.data().to_vec();
                for (o, v) in nb.iter_mut().zip(&chan) {
                    *o += csign * v;
                }
                fused_bias.insert(li, Tensor::new(bias_t.shape(), nb)?);
                fold_alias[j] = Some(lv);
                break;
            }
        }

        // ---- fusion decision 2: collapse single-consumer Add/Sub chains ---
        // Only a consumer's FIRST operand is inlined: left-to-right
        // evaluation of the flattened terms then performs exactly the same
        // f32 additions in the same order as the node-by-node chain, so the
        // fused pass stays bit-identical to the interpreter oracle.
        // (Inlining the second operand would turn x + (y + z) into
        // (x + y) + z — a different rounding.)
        let mut inlined = vec![false; n_nodes];
        for (j, node) in g.nodes.iter().enumerate() {
            if !matches!(node.op, NodeOp::Add | NodeOp::Sub) || fold_alias[j].is_some() {
                continue;
            }
            let vid = n_inputs + j;
            if uses[vid] != 1 {
                continue;
            }
            let Some(cj) = consumer[vid] else { continue };
            if matches!(g.nodes[cj].op, NodeOp::Add | NodeOp::Sub)
                && fold_alias[cj].is_none()
                && g.nodes[cj].inputs[0] == ValueId(vid)
            {
                inlined[j] = true;
            }
        }

        // ---- pass A: propagate views, resolve storage, emit proto steps ---
        let mut pa = PassA {
            g,
            n_inputs,
            info: vec![None; n_values],
            constants: Vec::new(),
            protos: Vec::new(),
            materialized: HashMap::new(),
            next_vid: n_values,
        };
        for (i, (id, shape)) in g.inputs.iter().enumerate() {
            pa.info[id.0] = Some(ValInfo {
                st: Storage::External(i),
                root: id.0,
                view: View::contiguous(shape),
            });
        }
        for (j, node) in g.nodes.iter().enumerate() {
            let vid = n_inputs + j;
            match &node.op {
                NodeOp::Constant(t) => {
                    pa.constants.push(t.clone());
                    pa.info[vid] = Some(ValInfo {
                        st: Storage::Const(pa.constants.len() - 1),
                        root: vid,
                        view: View::contiguous(t.shape()),
                    });
                }
                NodeOp::Reshape(target) => {
                    let src = pa.info[node.inputs[0].0]
                        .clone()
                        .ok_or_else(|| anyhow!("reshape of unmaterialized value"))?;
                    match src.view.reshape(target) {
                        // metadata-only: same storage, re-grouped strides
                        Some(v) => pa.info[vid] = Some(ValInfo { view: v, ..src }),
                        None => {
                            // the strided view cannot be re-grouped: copy
                            // once, directly into the reshaped dense layout
                            // (a gather is element-order preserving, so the
                            // copy *is* the reshape)
                            let a = pa.arg(node.inputs[0].0)?;
                            pa.protos.push(ProtoStep {
                                kernel: Kernel::Materialize {
                                    origin: "reshape",
                                    movement: false,
                                },
                                args: vec![a],
                                out_vid: vid,
                                out_shape: target.clone(),
                            });
                            pa.info[vid] = Some(ValInfo {
                                st: Storage::Owned,
                                root: vid,
                                view: View::contiguous(target),
                            });
                        }
                    }
                }
                NodeOp::Transpose2 => {
                    let src = pa.info[node.inputs[0].0]
                        .clone()
                        .ok_or_else(|| anyhow!("transpose of unmaterialized value"))?;
                    let view = src.view.transpose2();
                    pa.info[vid] = Some(ValInfo { view, ..src });
                }
                NodeOp::Permute3(p) => {
                    let src = pa.info[node.inputs[0].0]
                        .clone()
                        .ok_or_else(|| anyhow!("permute of unmaterialized value"))?;
                    let view = src.view.permute3(*p);
                    pa.info[vid] = Some(ValInfo { view, ..src });
                }
                NodeOp::StridedSlice {
                    axis,
                    stride,
                    count,
                } => {
                    let src = pa.info[node.inputs[0].0]
                        .clone()
                        .ok_or_else(|| anyhow!("slice of unmaterialized value"))?;
                    let view = src.view.stride_axis(*axis, *stride, *count);
                    pa.info[vid] = Some(ValInfo { view, ..src });
                }
                NodeOp::Add | NodeOp::Sub => {
                    if let Some(lv) = fold_alias[j] {
                        // folded into the producing layer's bias
                        pa.info[vid] = Some(pa.info[lv.0].clone().expect("layer before fold"));
                    } else if inlined[j] {
                        // expanded inside the consuming chain; no value
                    } else {
                        let mut terms: Vec<(f32, usize)> = Vec::new();
                        expand_terms(g, &inlined, n_inputs, j, 1.0, &mut terms);
                        let signs: Vec<f32> = terms.iter().map(|t| t.0).collect();
                        // the single-pass kernel streams its terms linearly
                        let args = terms
                            .iter()
                            .map(|&(_, v)| pa.contig_arg(v))
                            .collect::<Result<Vec<_>>>()?;
                        pa.protos.push(ProtoStep {
                            kernel: Kernel::FusedEw { signs },
                            args,
                            out_vid: vid,
                            out_shape: shapes[vid].clone(),
                        });
                        pa.info[vid] = Some(ValInfo {
                            st: Storage::Owned,
                            root: vid,
                            view: View::contiguous(&shapes[vid]),
                        });
                    }
                }
                op => {
                    let kernel = match op {
                        NodeOp::StandardConv1d => Kernel::StandardConv1d,
                        NodeOp::DepthwiseConv1d => Kernel::DepthwiseConv1d,
                        NodeOp::PointwiseConv => Kernel::PointwiseConv { packed: None },
                        NodeOp::FullyConnected => Kernel::FullyConnected { packed: None },
                        _ => unreachable!("handled above"),
                    };
                    // the activation may be an arbitrary strided view (the
                    // kernels read through strides); weights and biases
                    // must be dense
                    let x = pa.arg(node.inputs[0].0)?;
                    let k = pa.contig_arg(node.inputs[1].0)?;
                    let b = if let Some(nb) = fused_bias.get(&j) {
                        pa.constants.push(nb.clone());
                        ValInfo {
                            st: Storage::Const(pa.constants.len() - 1),
                            root: usize::MAX,
                            view: View::contiguous(nb.shape()),
                        }
                    } else {
                        pa.contig_arg(node.inputs[2].0)?
                    };
                    pa.protos.push(ProtoStep {
                        kernel,
                        args: vec![x, k, b],
                        out_vid: vid,
                        out_shape: shapes[vid].clone(),
                    });
                    pa.info[vid] = Some(ValInfo {
                        st: Storage::Owned,
                        root: vid,
                        view: View::contiguous(&shapes[vid]),
                    });
                }
            }
        }
        let PassA {
            info,
            mut constants,
            mut protos,
            ..
        } = pa;

        // ---- plan-level fusion over the proto schedule --------------------
        // Runs before read counting and liveness so the linear scan
        // allocates slots for the rewritten steps; see `fuse_protos` for
        // the rewrite catalog and the bit-for-bit rounding contract.
        let mut output_roots: HashSet<usize> = HashSet::new();
        for v in &g.outputs {
            if let Some(vi) = &info[v.0] {
                output_roots.insert(vi.root);
            }
        }
        let fusion = if opts.fusion {
            fuse_protos(g, n_inputs, &output_roots, &mut protos, &mut constants)
        } else {
            FusionOutcome::default()
        };

        // ---- read counts over owned storages ------------------------------
        let mut reads: HashMap<usize, usize> = HashMap::new();
        for p in &protos {
            for a in &p.args {
                if a.st == Storage::Owned {
                    *reads.entry(a.root).or_default() += 1;
                }
            }
        }
        let mut pinned: HashSet<usize> = HashSet::new();
        for out in &g.outputs {
            let vi = info[out.0]
                .as_ref()
                .ok_or_else(|| anyhow!("graph output {out:?} never materialized"))?;
            if vi.st == Storage::Owned {
                pinned.insert(vi.root);
            }
        }

        // ---- pass B: linear-scan slot assignment --------------------------
        let mut slot_of: HashMap<usize, usize> = HashMap::new();
        let mut free: Vec<usize> = Vec::new();
        let mut slot_sizes: Vec<usize> = Vec::new();
        let mut remaining = reads.clone();
        let mut steps: Vec<Step> = Vec::with_capacity(protos.len());
        for p in protos {
            let out_len: usize = p.out_shape.iter().product();
            let slot = free.pop().unwrap_or_else(|| {
                slot_sizes.push(0);
                slot_sizes.len() - 1
            });
            slot_sizes[slot] = slot_sizes[slot].max(out_len);
            slot_of.insert(p.out_vid, slot);
            let args: Vec<ArgRef> = p
                .args
                .iter()
                .map(|a| ArgRef {
                    loc: match a.st {
                        Storage::External(i) => Loc::External(i),
                        Storage::Const(k) => Loc::Const(k),
                        Storage::Owned => Loc::Slot(slot_of[&a.root]),
                    },
                    view: a.view.clone(),
                    root: a.root,
                })
                .collect();
            // recycle inputs whose last consumer just ran
            for a in &p.args {
                if a.st == Storage::Owned {
                    let r = remaining.get_mut(&a.root).expect("counted");
                    *r -= 1;
                    if *r == 0 && !pinned.contains(&a.root) {
                        free.push(slot_of[&a.root]);
                    }
                }
            }
            // a value nobody reads (dead node) frees its slot immediately
            if reads.get(&p.out_vid).copied().unwrap_or(0) == 0 && !pinned.contains(&p.out_vid)
            {
                free.push(slot);
            }
            steps.push(Step {
                kernel: p.kernel,
                args,
                out_slot: slot,
                out_shape: p.out_shape,
                out_root: p.out_vid,
            });
        }

        let mut outputs: Vec<ArgRef> = g
            .outputs
            .iter()
            .map(|v| {
                let vi = info[v.0].as_ref().expect("checked above");
                ArgRef {
                    loc: match vi.st {
                        Storage::External(i) => Loc::External(i),
                        Storage::Const(k) => Loc::Const(k),
                        Storage::Owned => Loc::Slot(slot_of[&vi.root]),
                    },
                    view: vi.view.clone(),
                    root: vi.root,
                }
            })
            .collect();

        // ---- drop constants nothing references --------------------------
        // Fusion can orphan constants (a folded-away addend, a superseded
        // bias); plans live in the router cache for the process lifetime,
        // so compact them out instead of pinning dead tensors.
        let mut used = vec![false; constants.len()];
        for s in &steps {
            for a in &s.args {
                if let Loc::Const(k) = a.loc {
                    used[k] = true;
                }
            }
        }
        for o in &outputs {
            if let Loc::Const(k) = o.loc {
                used[k] = true;
            }
        }
        let mut remap = vec![usize::MAX; constants.len()];
        let mut compact: Vec<Tensor> = Vec::new();
        for (k, t) in constants.into_iter().enumerate() {
            if used[k] {
                remap[k] = compact.len();
                compact.push(t);
            }
        }
        let fix = |loc: &mut Loc| {
            if let Loc::Const(k) = *loc {
                *loc = Loc::Const(remap[k]);
            }
        };
        for s in &mut steps {
            for a in &mut s.args {
                fix(&mut a.loc);
            }
        }
        for o in &mut outputs {
            fix(&mut o.loc);
        }
        // fold audits reference plan constants by index: remap alongside
        // (both the scaled kernel and the adopted bias are step args, so
        // they always survive compaction)
        let mut fold_audits = fusion.fold_audits;
        for a in &mut fold_audits {
            a.scaled_const = remap[a.scaled_const];
            a.bias_const = remap[a.bias_const];
        }

        // ---- pre-pack constant weight matrices into NR panels -----------
        // FullyConnected/PointwiseConv steps whose kernel is a whole plan
        // constant get a column-blocked copy the register-tiled microkernels
        // stream; one panel set per constant, shared across steps.
        let mut packed: Vec<Vec<f32>> = Vec::new();
        // keyed by (constant, cin, cout): the same constant consumed under
        // two different 2-D views (e.g. through a reshape) needs two
        // differently-laid-out panel sets
        let mut pack_of: HashMap<(usize, usize, usize), usize> = HashMap::new();
        for s in &mut steps {
            let slot = match &mut s.kernel {
                Kernel::PointwiseConv { packed } | Kernel::FullyConnected { packed } => packed,
                _ => continue,
            };
            let ka = &s.args[1];
            let Loc::Const(kc) = ka.loc else { continue };
            if !ka.view.is_contiguous()
                || ka.view.offset != 0
                || ka.view.numel() != compact[kc].len()
            {
                continue;
            }
            let (cin, cout) = (ka.view.shape[0], ka.view.shape[1]);
            let idx = *pack_of.entry((kc, cin, cout)).or_insert_with(|| {
                packed.push(fused::pack_k(compact[kc].data(), cin, cout));
                packed.len() - 1
            });
            *slot = Some(idx);
        }

        let plan = ExecPlan {
            input_shapes: g.inputs.iter().map(|(_, s)| s.clone()).collect(),
            constants: compact,
            packed,
            steps,
            slot_sizes,
            outputs,
            fused_steps: fusion.fused_steps,
            fusion_eliminated_copies: fusion.eliminated_copies,
            fold_audits,
        };
        if opts.verify {
            plan.verify()
                .map_err(|e| anyhow!("compiled plan failed static verification: {e}"))?;
        }
        Ok(plan)
    }

    /// Execute with a throwaway arena (tests / one-shot callers).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut arena = Arena::new();
        self.run_in(&mut arena, inputs)
    }

    /// Execute reusing `arena`'s buffers (the serving hot path).
    pub fn run_in(&self, arena: &mut Arena, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.execute_steps(arena, inputs)?;
        self.outputs
            .iter()
            .map(|o| {
                let d = backing(o, inputs, &self.constants, arena);
                let n = o.view.numel();
                let data = if o.view.is_contiguous() {
                    d[o.view.offset..o.view.offset + n].to_vec()
                } else {
                    // view-shaped output: gather once, straight into the
                    // result tensor (what used to be a kernel step)
                    let mut v = vec![0.0f32; n];
                    fused::materialize(d, o.view.offset, &o.view.shape, &o.view.strides, &mut v);
                    v
                };
                Tensor::new(&o.view.shape, data)
            })
            .collect()
    }

    /// Execute a batched plan once, then scatter the first `rows` rows of
    /// every output into per-request tensors (each keeps a leading dim of
    /// 1) — the serving path for shape-bucketed fallback batches.
    ///
    /// Rows are gathered straight from the terminal output views, so a
    /// view-shaped output costs exactly the per-row copies the replies
    /// need; rows beyond `rows` — the bucket's zero padding — are never
    /// gathered at all, which is what masks padding out of the replies.
    pub fn run_rows_in(
        &self,
        arena: &mut Arena,
        inputs: &[Tensor],
        rows: usize,
    ) -> Result<Vec<Vec<Tensor>>> {
        if rows == 0 {
            bail!("run_rows_in needs at least one row");
        }
        for (oi, o) in self.outputs.iter().enumerate() {
            if o.view.shape.is_empty() || o.view.shape[0] < rows {
                bail!(
                    "output {oi} shape {:?} cannot scatter {rows} rows",
                    o.view.shape
                );
            }
        }
        self.execute_steps(arena, inputs)?;
        (0..rows)
            .map(|r| {
                self.outputs
                    .iter()
                    .map(|o| {
                        let d = backing(o, inputs, &self.constants, arena);
                        let off = o.view.offset + r * o.view.strides[0];
                        let rest_shape = &o.view.shape[1..];
                        let rest_strides = &o.view.strides[1..];
                        let n: usize = rest_shape.iter().product();
                        let mut v = vec![0.0f32; n];
                        fused::materialize(d, off, rest_shape, rest_strides, &mut v);
                        let mut shape = Vec::with_capacity(o.view.shape.len());
                        shape.push(1);
                        shape.extend_from_slice(rest_shape);
                        Tensor::new(&shape, v)
                    })
                    .collect::<Result<Vec<Tensor>>>()
            })
            .collect()
    }

    /// Validate inputs against the declared shapes and run the kernel
    /// schedule; on return the arena holds every live output backing.
    fn execute_steps(&self, arena: &mut Arena, inputs: &[Tensor]) -> Result<()> {
        // deterministic fault-injection site (no-op unless the
        // `fault-injection` feature armed it): the chaos suite makes
        // plan execution panic, stall, or error here to prove the
        // serving layer contains kernel faults
        crate::testing::faults::fire("plan.execute")?;
        if inputs.len() != self.input_shapes.len() {
            bail!(
                "expected {} inputs, got {}",
                self.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (t, shape)) in inputs.iter().zip(&self.input_shapes).enumerate() {
            if t.shape() != shape.as_slice() {
                bail!(
                    "input {i} shape {:?} != declared {:?}",
                    t.shape(),
                    shape
                );
            }
        }
        arena.prepare(&self.slot_sizes);

        // Dense args (weights, biases, elementwise terms) resolve straight
        // to their element range.
        fn contig<'a>(
            a: &ArgRef,
            inputs: &'a [Tensor],
            constants: &'a [Tensor],
            arena: &'a Arena,
        ) -> &'a [f32] {
            debug_assert!(a.view.is_contiguous());
            let d = backing(a, inputs, constants, arena);
            &d[a.view.offset..a.view.offset + a.view.numel()]
        }

        // Activation args travel as strided rank-3 windows (optionally
        // with a split leading axis — the fusion pass's loop-nest
        // reindex).
        fn x3<'a>(
            a: &ArgRef,
            inputs: &'a [Tensor],
            constants: &'a [Tensor],
            arena: &'a Arena,
        ) -> fused::X3<'a> {
            debug_assert_eq!(a.view.strides.len(), 3);
            fused::X3 {
                d: backing(a, inputs, constants, arena),
                off: a.view.offset,
                s: [a.view.strides[0], a.view.strides[1], a.view.strides[2]],
                split0: a.view.split0.map(|sp| (sp.inner, sp.outer_stride)),
            }
        }

        for step in &self.steps {
            let out_len: usize = step.out_shape.iter().product();
            let mut out_buf = arena.take(step.out_slot);
            debug_assert!(out_buf.len() >= out_len);
            {
                let out = &mut out_buf[..out_len];
                match &step.kernel {
                    Kernel::DepthwiseConv1d => {
                        let xs = &step.args[0].view.shape;
                        let m = step.args[1].view.shape[1];
                        fused::depthwise_conv(
                            x3(&step.args[0], inputs, &self.constants, arena),
                            (xs[0], xs[1], xs[2]),
                            contig(&step.args[1], inputs, &self.constants, arena),
                            m,
                            contig(&step.args[2], inputs, &self.constants, arena),
                            out,
                        );
                    }
                    Kernel::StandardConv1d => {
                        let xs = &step.args[0].view.shape;
                        let ks = &step.args[1].view.shape;
                        fused::standard_conv(
                            x3(&step.args[0], inputs, &self.constants, arena),
                            (xs[0], xs[1], xs[2]),
                            contig(&step.args[1], inputs, &self.constants, arena),
                            (ks[0], ks[2]),
                            contig(&step.args[2], inputs, &self.constants, arena),
                            out,
                        );
                    }
                    Kernel::PointwiseConv { packed } => {
                        let xs = &step.args[0].view.shape;
                        let cout = step.args[1].view.shape[1];
                        let x = x3(&step.args[0], inputs, &self.constants, arena);
                        let b = contig(&step.args[2], inputs, &self.constants, arena);
                        match packed {
                            Some(pi) => fused::pointwise_conv_packed(
                                x,
                                (xs[0], xs[1], xs[2]),
                                &self.packed[*pi],
                                cout,
                                b,
                                out,
                            ),
                            None => fused::pointwise_conv(
                                x,
                                (xs[0], xs[1], xs[2]),
                                contig(&step.args[1], inputs, &self.constants, arena),
                                cout,
                                b,
                                out,
                            ),
                        }
                    }
                    Kernel::FullyConnected { packed } => {
                        let a = &step.args[0];
                        // FC activations read through X2: the fusion pass
                        // never assigns them a split view
                        debug_assert!(a.view.split0.is_none());
                        let xs = &a.view.shape;
                        let cout = step.args[1].view.shape[1];
                        let x = fused::X2 {
                            d: backing(a, inputs, &self.constants, arena),
                            off: a.view.offset,
                            s: [a.view.strides[0], a.view.strides[1]],
                        };
                        let b = contig(&step.args[2], inputs, &self.constants, arena);
                        match packed {
                            Some(pi) => fused::fully_connected_packed(
                                x,
                                (xs[0], xs[1]),
                                &self.packed[*pi],
                                cout,
                                b,
                                out,
                            ),
                            None => fused::fully_connected(
                                x,
                                (xs[0], xs[1]),
                                contig(&step.args[1], inputs, &self.constants, arena),
                                cout,
                                b,
                                out,
                            ),
                        }
                    }
                    Kernel::Materialize { .. } => {
                        let a = &step.args[0];
                        fused::materialize(
                            backing(a, inputs, &self.constants, arena),
                            a.view.offset,
                            &a.view.shape,
                            &a.view.strides,
                            out,
                        );
                    }
                    Kernel::FusedEw { signs } => {
                        let terms: Vec<(f32, &[f32])> = signs
                            .iter()
                            .zip(&step.args)
                            .map(|(&s, a)| (s, contig(a, inputs, &self.constants, arena)))
                            .collect();
                        fused::fused_ew(&terms, out);
                    }
                }
            }
            arena.put(step.out_slot, out_buf);
        }
        Ok(())
    }

    /// Number of arena slots the plan needs (its peak live-buffer count).
    pub fn slot_count(&self) -> usize {
        self.slot_sizes.len()
    }

    /// Number of kernel steps after fusion/aliasing.
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// Number of explicit view-copy steps in the schedule.  Zero on every
    /// shipped lowering at every batch size: batched STFT's frame
    /// regrouping — the one case strides cannot express — is re-expressed
    /// by the fusion pass as a split-view reindex (see the module docs).
    pub fn materialize_count(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s.kernel, Kernel::Materialize { .. }))
            .count()
    }

    /// Materialize steps forced by a `Transpose2`/`Permute3`/`StridedSlice`
    /// view (classified via [`NodeOp::is_strided_movement`] at compile
    /// time).  The acceptance contract keeps these at zero on the shipped
    /// lowerings: pure data-movement ops must never copy.
    pub fn movement_materialize_count(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s.kernel, Kernel::Materialize { movement: true, .. }))
            .count()
    }

    /// Op names that forced each Materialize step, in schedule order —
    /// the diagnostic companion to [`ExecPlan::materialize_count`].
    pub fn materialize_origins(&self) -> Vec<&'static str> {
        self.steps
            .iter()
            .filter_map(|s| match s.kernel {
                Kernel::Materialize { origin, .. } => Some(origin),
                _ => None,
            })
            .collect()
    }

    /// Kernel steps the fusion pass removed by folding a tagged window
    /// multiply into its framing convolution (compile-time constant fold
    /// of the pre-scaled taps; see the module docs' fusion section).
    pub fn fused_steps(&self) -> usize {
        self.fused_steps
    }

    /// `Materialize` copies the fusion pass eliminated by re-expressing
    /// a merged-axis regrouping as a split-view loop-nest reindex in the
    /// consuming kernels.
    pub fn fusion_eliminated_copies(&self) -> usize {
        self.fusion_eliminated_copies
    }

    /// Steps whose constant weights were pre-packed into NR panels.
    pub fn packed_kernel_count(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| {
                matches!(
                    s.kernel,
                    Kernel::PointwiseConv { packed: Some(_) }
                        | Kernel::FullyConnected { packed: Some(_) }
                )
            })
            .count()
    }

    /// Bytes of arena the plan's slots occupy at their high-water sizes.
    pub fn arena_bytes(&self) -> usize {
        self.slot_sizes.iter().map(|&n| n * 4).sum()
    }

    /// Constants baked into the plan (after dead-constant compaction).
    pub fn constant_count(&self) -> usize {
        self.constants.len()
    }

    /// Declared input shapes, in call order.
    pub fn input_shapes(&self) -> &[Vec<usize>] {
        &self.input_shapes
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp;
    use crate::tina::lower;
    use crate::tina::Interpreter;

    fn check_against_interpreter(g: Graph, inputs: &[Tensor]) {
        let interp = Interpreter::new(g.clone()).unwrap();
        let plan = ExecPlan::compile(&g).unwrap();
        plan.verify().unwrap();
        let want = interp.run(inputs).unwrap();
        let got = plan.run(inputs).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.shape(), b.shape());
            assert!(
                a.allclose(b, 1e-5, 1e-6),
                "planned executor diverged (max diff {})",
                a.max_abs_diff(b).unwrap()
            );
        }
    }

    #[test]
    fn matches_interpreter_on_every_lowering() {
        let cfg = dsp::PfbConfig::new(8, 4);
        let taps = dsp::fir_lowpass(16, 0.2).unwrap();
        check_against_interpreter(
            lower::ewmult(5, 7),
            &[Tensor::randn(&[5, 7], 1), Tensor::randn(&[5, 7], 2)],
        );
        check_against_interpreter(
            lower::ewadd(3, 9),
            &[Tensor::randn(&[3, 9], 3), Tensor::randn(&[3, 9], 4)],
        );
        check_against_interpreter(
            lower::matmul(6, 10, 4),
            &[Tensor::randn(&[6, 10], 5), Tensor::randn(&[10, 4], 6)],
        );
        check_against_interpreter(lower::summation(500), &[Tensor::randn(&[500], 7)]);
        check_against_interpreter(lower::dft(2, 16), &[Tensor::randn(&[2, 16], 8)]);
        check_against_interpreter(
            lower::idft(2, 16),
            &[Tensor::randn(&[2, 16], 9), Tensor::randn(&[2, 16], 10)],
        );
        check_against_interpreter(
            lower::fir(2, 200, &taps).unwrap(),
            &[Tensor::randn(&[2, 200], 11)],
        );
        check_against_interpreter(
            lower::unfold(1, 50, 8).unwrap(),
            &[Tensor::randn(&[1, 50], 12)],
        );
        check_against_interpreter(
            lower::pfb_fir(2, 8 * 32, cfg).unwrap(),
            &[Tensor::randn(&[2, 8 * 32], 13)],
        );
        check_against_interpreter(
            lower::pfb(2, 8 * 32, cfg).unwrap(),
            &[Tensor::randn(&[2, 8 * 32], 14)],
        );
        check_against_interpreter(
            lower::stft(2, 600, 64, 32).unwrap(),
            &[Tensor::randn(&[2, 600], 15)],
        );
    }

    #[test]
    fn arena_slots_are_recycled() {
        // STFT has a long chain of intermediates; the linear-scan allocator
        // must map them onto fewer slots than steps.  Compiled with fusion
        // off so the full unfused chain exercises the allocator.
        let g = lower::stft(1, 1024, 64, 32).unwrap();
        let plan = ExecPlan::compile_with(
            &g,
            CompileOptions {
                fusion: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            plan.slot_count() < plan.step_count(),
            "no reuse: {} slots for {} steps",
            plan.slot_count(),
            plan.step_count()
        );
        plan.verify().unwrap();
    }

    #[test]
    fn reshape_is_metadata_only() {
        // ewmult lowers to reshape/reshape/depthwise/reshape: only the
        // depthwise conv should materialize a buffer.
        let g = lower::ewmult(4, 4);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 1, "reshapes must not become steps");
        assert_eq!(plan.slot_count(), 1);
    }

    #[test]
    fn movement_ops_are_metadata_only_on_lowerings() {
        // The tentpole contract: transpose/permute/slice views compile to
        // stride rewrites, so the PFB and STFT graphs run copy-free.
        let cfg = dsp::PfbConfig::new(8, 4);
        for (name, g, steps) in [
            // reshape + permute + depthwise: one kernel step, no copies
            ("pfb_fir", lower::pfb_fir(2, 8 * 32, cfg).unwrap(), 1),
            // depthwise + 2 pointwise; both output permutes become views
            ("pfb", lower::pfb(2, 8 * 32, cfg).unwrap(), 3),
            // framing conv (window folded in) + 2 DFT pointwise; the
            // strided-slice and both permutes are pure metadata at B=1
            ("stft", lower::stft(1, 600, 64, 32).unwrap(), 3),
            // standard conv; the trailing permute is a terminal view
            ("unfold", lower::unfold(2, 100, 8).unwrap(), 1),
        ] {
            let plan = ExecPlan::compile(&g).unwrap();
            assert_eq!(plan.materialize_count(), 0, "{name}: unexpected copy");
            assert_eq!(plan.movement_materialize_count(), 0, "{name}");
            assert_eq!(plan.step_count(), steps, "{name}: step count");
            plan.verify().unwrap();
        }
    }

    #[test]
    fn batched_stft_compiles_copy_free() {
        // At B > 1 the (B, F, nfft) -> (B*F, nfft, 1) frame regrouping is
        // not expressible as plain strides (the B and F axes are not dense
        // with respect to each other); the fusion pass re-expresses the
        // copy as a split-view loop-nest reindex and folds the window into
        // the framing conv, so the whole plan is copy-free: conv + two DFT
        // pointwise steps.
        let g = lower::stft(2, 600, 64, 32).unwrap();
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.materialize_count(), 0);
        assert_eq!(plan.movement_materialize_count(), 0);
        assert!(plan.materialize_origins().is_empty());
        assert_eq!(plan.step_count(), 3);
        assert_eq!(plan.fused_steps(), 1);
        assert_eq!(plan.fusion_eliminated_copies(), 1);
        check_against_interpreter(g, &[Tensor::randn(&[2, 600], 77)]);
        // with fusion off, the PR-2 behavior is preserved: exactly one
        // reshape-attributed copy, none from the movement ops themselves
        let plan = ExecPlan::compile_with(
            &g,
            CompileOptions {
                fusion: false,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(plan.materialize_count(), 1);
        assert_eq!(plan.movement_materialize_count(), 0);
        assert_eq!(plan.materialize_origins(), vec!["reshape"]);
        assert_eq!(plan.fused_steps(), 0);
        assert_eq!(plan.fusion_eliminated_copies(), 0);
    }

    #[test]
    fn const_weights_are_packed_for_layer_kernels() {
        // dft lowers to two pointwise convs with baked DFM constants: both
        // must get pre-packed panels.  summation's ones-kernel FC too.
        let plan = ExecPlan::compile(&lower::dft(2, 16)).unwrap();
        assert_eq!(plan.packed_kernel_count(), 2);
        let plan = ExecPlan::compile(&lower::summation(64)).unwrap();
        assert_eq!(plan.packed_kernel_count(), 1);
        // matmul's weight is a runtime input: nothing to pack
        let plan = ExecPlan::compile(&lower::matmul(4, 5, 6)).unwrap();
        assert_eq!(plan.packed_kernel_count(), 0);
    }

    #[test]
    fn shared_constant_under_two_shapes_packs_separately() {
        // one constant consumed as (6, 4) by FC1 and, through a reshape,
        // as (4, 6) by FC2: each view needs its own panel layout
        let mut g = Graph::new();
        let x1 = g.input(&[2, 6]);
        let x2 = g.input(&[3, 4]);
        let k = g.constant(Tensor::randn(&[6, 4], 90));
        let k2 = g.push(NodeOp::Reshape(vec![4, 6]), &[k]);
        let b1 = g.constant(Tensor::randn(&[4], 91));
        let b2 = g.constant(Tensor::randn(&[6], 92));
        let o1 = g.push(NodeOp::FullyConnected, &[x1, k, b1]);
        let o2 = g.push(NodeOp::FullyConnected, &[x2, k2, b2]);
        g.set_outputs(&[o1, o2]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.packed_kernel_count(), 2);
        let inputs = vec![Tensor::randn(&[2, 6], 93), Tensor::randn(&[3, 4], 94)];
        let want = Interpreter::new(g).unwrap().run(&inputs).unwrap();
        let got = plan.run(&inputs).unwrap();
        assert_eq!(got[0], want[0]);
        assert_eq!(got[1], want[1]);
    }

    #[test]
    fn packed_fc_matches_interpreter_bitwise() {
        // cout = 13 exercises the partial tail panel
        let mut g = Graph::new();
        let x = g.input(&[4, 9]);
        let k = g.constant(Tensor::randn(&[9, 13], 60));
        let b = g.constant(Tensor::randn(&[13], 61));
        let o = g.push(NodeOp::FullyConnected, &[x, k, b]);
        g.set_outputs(&[o]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.packed_kernel_count(), 1);
        let inputs = vec![Tensor::randn(&[4, 9], 62)];
        let want = Interpreter::new(g).unwrap().run(&inputs).unwrap();
        let got = plan.run(&inputs).unwrap();
        assert_eq!(got[0], want[0], "packed FC must stay bit-identical");
    }

    #[test]
    fn terminal_views_gather_without_steps() {
        // outputs that ARE views: no kernel runs at all for pure movement
        let mut g = Graph::new();
        let x = g.input(&[4, 6]);
        let t = g.push(NodeOp::Transpose2, &[x]);
        let s = g.push(
            NodeOp::StridedSlice {
                axis: 0,
                stride: 2,
                count: 2,
            },
            &[x],
        );
        g.set_outputs(&[t, s, x]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 0);
        let inputs = vec![Tensor::randn(&[4, 6], 70)];
        let want = Interpreter::new(g).unwrap().run(&inputs).unwrap();
        let got = plan.run(&inputs).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn diamond_view_and_materializing_consumer() {
        // one producer feeds both a terminal view and a consuming kernel:
        // the backing slot must stay pinned for the final gather
        let mut g = Graph::new();
        let a = g.input(&[3, 3]);
        let b = g.input(&[3, 3]);
        let s = g.push(NodeOp::Add, &[a, b]);
        let t = g.push(NodeOp::Transpose2, &[s]); // view of s
        let u = g.push(NodeOp::Sub, &[s, a]); // reads s directly
        g.set_outputs(&[t, u]);
        let plan = ExecPlan::compile(&g).unwrap();
        plan.verify().unwrap();
        let inputs = vec![Tensor::randn(&[3, 3], 71), Tensor::randn(&[3, 3], 72)];
        let want = Interpreter::new(g).unwrap().run(&inputs).unwrap();
        let got = plan.run(&inputs).unwrap();
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn transposed_const_weight_materializes_once() {
        // weights must be dense: a transposed constant kernel forces one
        // movement-attributed copy, shared even if consumed twice
        let mut g = Graph::new();
        let x = g.input(&[2, 3]);
        let kt = g.constant(Tensor::randn(&[4, 3], 80));
        let k = g.push(NodeOp::Transpose2, &[kt]); // (3, 4) strided view
        let b = g.constant(Tensor::zeros(&[4]));
        let o1 = g.push(NodeOp::FullyConnected, &[x, k, b]);
        let o2 = g.push(NodeOp::FullyConnected, &[x, k, b]);
        g.set_outputs(&[o1, o2]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.materialize_count(), 1, "copy shared across consumers");
        assert_eq!(plan.movement_materialize_count(), 1);
        assert_eq!(plan.materialize_origins(), vec!["transpose2"]);
        check_against_interpreter(
            g,
            &[Tensor::randn(&[2, 3], 81)],
        );
    }

    #[test]
    fn ew_chain_collapses_to_single_fused_pass() {
        // (a - b) + c with single consumers collapses into one FusedEw.
        let mut g = Graph::new();
        let a = g.input(&[4, 4]);
        let b = g.input(&[4, 4]);
        let c = g.input(&[4, 4]);
        let s = g.push(NodeOp::Sub, &[a, b]);
        let o = g.push(NodeOp::Add, &[s, c]);
        g.set_outputs(&[o]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 1, "chain must fuse into one pass");
        check_against_interpreter(
            g,
            &[
                Tensor::randn(&[4, 4], 20),
                Tensor::randn(&[4, 4], 21),
                Tensor::randn(&[4, 4], 22),
            ],
        );
    }

    #[test]
    fn constant_add_folds_into_layer_bias() {
        // FC output + per-channel-uniform constant folds into the bias.
        let mut g = Graph::new();
        let x = g.input(&[3, 5]);
        let k = g.constant(Tensor::randn(&[5, 4], 30));
        let bias = g.constant(Tensor::randn(&[4], 31));
        let fc = g.push(NodeOp::FullyConnected, &[x, k, bias]);
        // constant with each channel column uniform across the batch
        let chan = [0.5f32, -1.0, 2.0, 0.25];
        let mut cdata = Vec::new();
        for _ in 0..3 {
            cdata.extend_from_slice(&chan);
        }
        let c = g.constant(Tensor::new(&[3, 4], cdata).unwrap());
        let o = g.push(NodeOp::Add, &[fc, c]);
        g.set_outputs(&[o]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 1, "add must fold into the FC bias");
        // kernel + fused bias survive; the folded addend and the original
        // bias are compacted out of the plan
        assert_eq!(plan.constant_count(), 2, "dead constants must be dropped");
        check_against_interpreter(g, &[Tensor::randn(&[3, 5], 32)]);
    }

    #[test]
    fn non_uniform_constant_does_not_fold() {
        let mut g = Graph::new();
        let x = g.input(&[2, 3]);
        let k = g.constant(Tensor::randn(&[3, 3], 33));
        let bias = g.constant(Tensor::zeros(&[3]));
        let fc = g.push(NodeOp::FullyConnected, &[x, k, bias]);
        let c = g.constant(Tensor::randn(&[2, 3], 34)); // not per-channel uniform
        let o = g.push(NodeOp::Add, &[fc, c]);
        g.set_outputs(&[o]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 2, "must stay FC + FusedEw");
        check_against_interpreter(g, &[Tensor::randn(&[2, 3], 35)]);
    }

    #[test]
    fn shared_intermediate_is_not_inlined() {
        // d = a + b used twice: must materialize once, not be re-expanded.
        let mut g = Graph::new();
        let a = g.input(&[2, 2]);
        let b = g.input(&[2, 2]);
        let d = g.push(NodeOp::Add, &[a, b]);
        let e = g.push(NodeOp::Add, &[d, d]);
        let f = g.push(NodeOp::Sub, &[e, d]);
        g.set_outputs(&[f]);
        check_against_interpreter(
            g,
            &[Tensor::randn(&[2, 2], 40), Tensor::randn(&[2, 2], 41)],
        );
    }

    #[test]
    fn graph_input_passthrough_output() {
        // an output that is directly a graph input (External loc path)
        let mut g = Graph::new();
        let x = g.input(&[2, 3]);
        let r = g.push(NodeOp::Reshape(vec![3, 2]), &[x]);
        g.set_outputs(&[r, x]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 0);
        let t = Tensor::randn(&[2, 3], 50);
        let out = plan.run(&[t.clone()]).unwrap();
        assert_eq!(out[0].shape(), &[3, 2]);
        assert_eq!(out[0].data(), t.data());
        assert_eq!(out[1], t);
    }

    #[test]
    fn rejects_wrong_inputs_like_interpreter() {
        let plan = ExecPlan::compile(&lower::ewmult(2, 2)).unwrap();
        assert!(plan.run(&[Tensor::zeros(&[2, 2])]).is_err());
        assert!(plan
            .run(&[Tensor::zeros(&[2, 3]), Tensor::zeros(&[2, 2])])
            .is_err());
    }

    #[test]
    fn run_rows_scatters_real_rows_and_masks_padding() {
        // a bucketed B=4 plan serving 3 real rows: each scattered row must
        // be bit-identical to the solo B=1 interpreter run on that row,
        // and the poisoned padding row must never surface anywhere
        let taps = dsp::fir_lowpass(16, 0.2).unwrap();
        let l = 200;
        let (bucket, rows) = (4usize, 3usize);
        let plan = ExecPlan::compile(&lower::fir(bucket, l, &taps).unwrap()).unwrap();
        let per_row: Vec<Tensor> = (0..rows)
            .map(|r| Tensor::randn(&[1, l], 100 + r as u64))
            .collect();
        let mut data = Vec::with_capacity(bucket * l);
        for r in &per_row {
            data.extend_from_slice(r.data());
        }
        data.resize(bucket * l, 1.0e30); // poison, not the batcher's zeros
        let batched = Tensor::new(&[bucket, l], data).unwrap();
        let mut arena = Arena::new();
        let got = plan
            .run_rows_in(&mut arena, std::slice::from_ref(&batched), rows)
            .unwrap();
        assert_eq!(got.len(), rows);
        let solo = Interpreter::new(lower::fir(1, l, &taps).unwrap()).unwrap();
        for (r, row_in) in per_row.iter().enumerate() {
            let want = solo.run(std::slice::from_ref(row_in)).unwrap();
            assert_eq!(got[r].len(), want.len());
            for (a, b) in got[r].iter().zip(&want) {
                assert_eq!(a.shape(), b.shape());
                assert_eq!(a, b, "row {r}: bucketed run diverged or padding leaked");
            }
        }
        // a row count beyond the output's batch dim is rejected
        assert!(plan
            .run_rows_in(&mut arena, std::slice::from_ref(&batched), bucket + 1)
            .is_err());
    }

    #[test]
    fn repeat_runs_reuse_arena_without_corruption() {
        let g = lower::pfb(1, 8 * 32, dsp::PfbConfig::new(8, 4)).unwrap();
        let interp = Interpreter::new(g.clone()).unwrap();
        let plan = ExecPlan::compile(&g).unwrap();
        let mut arena = Arena::new();
        for seed in 0..4u64 {
            let x = Tensor::randn(&[1, 8 * 32], 60 + seed);
            let want = interp.run(std::slice::from_ref(&x)).unwrap();
            let got = plan.run_in(&mut arena, std::slice::from_ref(&x)).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!(a.allclose(b, 1e-5, 1e-6), "seed {seed}");
            }
        }
    }

    /// lower::stft's framing prefix (framing conv + strided slice +
    /// permute + regrouping reshape), returning the `(B*F, nfft, 1)` rows
    /// value and the frame count.  `kernel`/`conv_bias` let the fold
    /// tests break individual preconditions.
    fn framed_rows(
        g: &mut Graph,
        x: ValueId,
        (b, l, nfft, hop): (usize, usize, usize, usize),
        kernel: Tensor,
        conv_bias: Tensor,
    ) -> (ValueId, ValueId, usize) {
        let frames = (l - nfft) / hop + 1;
        let xi = g.push(NodeOp::Reshape(vec![b, 1, l]), &[x]);
        let k = g.constant(kernel);
        let bias0 = g.constant(conv_bias);
        let unfolded = g.push(NodeOp::StandardConv1d, &[xi, k, bias0]);
        let framed = g.push(
            NodeOp::StridedSlice {
                axis: 2,
                stride: hop,
                count: frames,
            },
            &[unfolded],
        );
        let framed = g.push(NodeOp::Permute3([0, 2, 1]), &[framed]);
        let rows = g.push(NodeOp::Reshape(vec![b * frames, nfft, 1]), &[framed]);
        (rows, framed, frames)
    }

    /// Hinted window + one pointwise consumer on top of `rows`.
    fn window_then_pointwise(
        g: &mut Graph,
        rows: ValueId,
        (bf, nfft): (usize, usize),
        hint: crate::tina::graph::FusionHint,
    ) -> (ValueId, ValueId) {
        let kwin = g.constant(Tensor::randn(&[nfft, 1], 501));
        let bias_w = g.constant(Tensor::randn(&[nfft], 502)); // nonzero: must carry over
        let xw = g.push_with_hint(NodeOp::DepthwiseConv1d, &[rows, kwin, bias_w], hint);
        let kd = g.constant(Tensor::randn(&[nfft, nfft], 503));
        let bias_d = g.constant(Tensor::zeros(&[nfft]));
        let pw = g.push(NodeOp::PointwiseConv, &[xw, kd, bias_d]); // (B*F, nfft, 1)
        let out = g.push(NodeOp::Reshape(vec![bf, nfft]), &[pw]);
        (xw, out)
    }

    fn check_bitwise(g: &Graph, inputs: &[Tensor]) {
        let want = Interpreter::new(g.clone()).unwrap().run(inputs).unwrap();
        let plan = ExecPlan::compile(g).unwrap();
        plan.verify().unwrap();
        let got = plan.run(inputs).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a, b, "fused plan must stay bit-identical to the oracle");
        }
    }

    fn eye_kernel(nfft: usize) -> Tensor {
        Tensor::eye(nfft).reshape(&[nfft, 1, nfft]).unwrap()
    }

    #[test]
    fn window_fold_fires_and_carries_window_bias() {
        // B=2 exercises both rewrites: the regrouping copy is eliminated
        // AND the (nonzero-bias) window folds into the framing conv.
        let (b, l, nfft, hop) = (2usize, 96usize, 8usize, 4usize);
        let mut g = Graph::new();
        let x = g.input(&[b, l]);
        let (rows, _, frames) =
            framed_rows(&mut g, x, (b, l, nfft, hop), eye_kernel(nfft), Tensor::zeros(&[nfft]));
        let (_, out) =
            window_then_pointwise(&mut g, rows, (b * frames, nfft), FusionHint::Window);
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 1, "window must fold into the conv");
        assert_eq!(plan.fusion_eliminated_copies(), 1, "regrouping copy gone");
        assert_eq!(plan.materialize_count(), 0);
        assert_eq!(plan.step_count(), 2, "conv + pointwise only");
        check_bitwise(&g, &[Tensor::randn(&[b, l], 510)]);
    }

    #[test]
    fn window_fold_handles_negated_one_hot_taps() {
        // framing taps of -1 stay foldable: x*(-1) then *w equals
        // x*(-w) bitwise (sign flips are exact)
        let (b, l, nfft, hop) = (1usize, 40usize, 4usize, 2usize);
        let mut eye = eye_kernel(nfft);
        for v in eye.data_mut().iter_mut() {
            *v = -*v;
        }
        let mut g = Graph::new();
        let x = g.input(&[b, l]);
        let (rows, _, frames) =
            framed_rows(&mut g, x, (b, l, nfft, hop), eye, Tensor::zeros(&[nfft]));
        let (_, out) =
            window_then_pointwise(&mut g, rows, (b * frames, nfft), FusionHint::Window);
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 1);
        check_bitwise(&g, &[Tensor::randn(&[b, l], 511)]);
    }

    #[test]
    fn window_fold_skips_non_unit_taps() {
        // a 2.0 framing tap would reassociate (x*t)*w into x*(t*w) —
        // different rounding, so the pass must leave the graph unfused
        let (b, l, nfft, hop) = (1usize, 40usize, 4usize, 2usize);
        let mut eye = eye_kernel(nfft);
        eye.data_mut()[0] = 2.0;
        let mut g = Graph::new();
        let x = g.input(&[b, l]);
        let (rows, _, frames) =
            framed_rows(&mut g, x, (b, l, nfft, hop), eye, Tensor::zeros(&[nfft]));
        let (_, out) =
            window_then_pointwise(&mut g, rows, (b * frames, nfft), FusionHint::Window);
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 0, "non-unit taps must not fold");
        check_bitwise(&g, &[Tensor::randn(&[b, l], 512)]);
    }

    #[test]
    fn window_fold_skips_nonzero_conv_bias() {
        // a nonzero framing bias changes where the +bias lands relative
        // to the window multiply: skip
        let (b, l, nfft, hop) = (1usize, 40usize, 4usize, 2usize);
        let mut g = Graph::new();
        let x = g.input(&[b, l]);
        let (rows, _, frames) = framed_rows(
            &mut g,
            x,
            (b, l, nfft, hop),
            eye_kernel(nfft),
            Tensor::randn(&[nfft], 513),
        );
        let (_, out) =
            window_then_pointwise(&mut g, rows, (b * frames, nfft), FusionHint::Window);
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 0, "nonzero conv bias must not fold");
        check_bitwise(&g, &[Tensor::randn(&[b, l], 514)]);
    }

    #[test]
    fn window_fold_skips_shared_framing_conv() {
        // the framed view is also a plan output: folding would scale the
        // values that output observes — skip, still bit-identical
        let (b, l, nfft, hop) = (2usize, 40usize, 4usize, 2usize);
        let mut g = Graph::new();
        let x = g.input(&[b, l]);
        let (rows, framed, frames) =
            framed_rows(&mut g, x, (b, l, nfft, hop), eye_kernel(nfft), Tensor::zeros(&[nfft]));
        let (_, out) =
            window_then_pointwise(&mut g, rows, (b * frames, nfft), FusionHint::Window);
        g.set_outputs(&[out, framed]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 0, "shared conv output must not fold");
        check_bitwise(&g, &[Tensor::randn(&[b, l], 515)]);
    }

    #[test]
    fn window_output_shared_by_second_consumer_skips_fold() {
        // the negative diamond: the window output feeds the DFT pointwise
        // AND an elementwise Add — the Add would read pre-assembled dense
        // values, so the fold must skip and everything still matches
        let (b, l, nfft, hop) = (2usize, 40usize, 4usize, 2usize);
        let mut g = Graph::new();
        let x = g.input(&[b, l]);
        let (rows, _, frames) =
            framed_rows(&mut g, x, (b, l, nfft, hop), eye_kernel(nfft), Tensor::zeros(&[nfft]));
        let (xw, out) =
            window_then_pointwise(&mut g, rows, (b * frames, nfft), FusionHint::Window);
        let doubled = g.push(NodeOp::Add, &[xw, xw]);
        g.set_outputs(&[out, doubled]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 0, "diamond window must not fold");
        // the regrouping copy is still eliminated (the window itself can
        // read the split view; elimination does not require the fold)
        assert_eq!(plan.fusion_eliminated_copies(), 1);
        assert_eq!(plan.materialize_count(), 0);
        check_bitwise(&g, &[Tensor::randn(&[b, l], 516)]);
    }

    #[test]
    fn unhinted_window_is_not_folded_but_copy_still_eliminated() {
        // without the lowering's hint the fold never fires (predictable
        // plans), but the movement rewrite is structural and still applies
        let (b, l, nfft, hop) = (2usize, 40usize, 4usize, 2usize);
        let mut g = Graph::new();
        let x = g.input(&[b, l]);
        let (rows, _, frames) =
            framed_rows(&mut g, x, (b, l, nfft, hop), eye_kernel(nfft), Tensor::zeros(&[nfft]));
        let (_, out) =
            window_then_pointwise(&mut g, rows, (b * frames, nfft), FusionHint::None);
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 0);
        assert_eq!(plan.fusion_eliminated_copies(), 1);
        assert_eq!(plan.materialize_count(), 0);
        check_bitwise(&g, &[Tensor::randn(&[b, l], 517)]);
    }

    #[test]
    fn stft_copy_free_and_fused_at_every_bucket() {
        // the acceptance contract: every shipped lowering compiles with
        // zero Materialize steps at every bucket B, and windowed STFT
        // reports fused steps
        for b in [1usize, 2, 4, 8] {
            let g = lower::stft(b, 600, 64, 32).unwrap();
            let plan = ExecPlan::compile(&g).unwrap();
            assert_eq!(plan.materialize_count(), 0, "B={b}");
            assert_eq!(plan.movement_materialize_count(), 0, "B={b}");
            assert_eq!(plan.fused_steps(), 1, "B={b}: window must fold");
            assert_eq!(
                plan.fusion_eliminated_copies(),
                usize::from(b > 1),
                "B={b}"
            );
            plan.verify().unwrap();
            check_bitwise(&g, &[Tensor::randn(&[b, 600], 600 + b as u64)]);
        }
    }

    /// An M = 1 depthwise gain stage over `(b, n)` rows plus a chain
    /// link on top (the FX correlator's gain→conjugate shape), followed
    /// by one pointwise consumer.  `link_taps`/`link_bias` let the fold
    /// tests break individual preconditions.  Outputs are NOT set.
    fn scale_chain_graph(
        (b, n): (usize, usize),
        link_taps: Tensor,
        link_bias: Tensor,
    ) -> (Graph, ValueId, ValueId) {
        let mut g = Graph::new();
        let x = g.input(&[b, n]);
        let xi = g.push(NodeOp::Reshape(vec![b, n, 1]), &[x]);
        let kg = g.constant(Tensor::randn(&[n, 1], 518));
        let pb = g.constant(Tensor::randn(&[n], 519)); // nonzero: must pre-sign
        let scaled = g.push(NodeOp::DepthwiseConv1d, &[xi, kg, pb]);
        let kl = g.constant(link_taps);
        let bl = g.constant(link_bias);
        let link = g.push_with_hint(NodeOp::DepthwiseConv1d, &[scaled, kl, bl], FusionHint::Chain);
        let kd = g.constant(Tensor::randn(&[n, n], 520));
        let bd = g.constant(Tensor::zeros(&[n]));
        let pw = g.push(NodeOp::PointwiseConv, &[link, kd, bd]); // (b, n, 1)
        let out = g.push(NodeOp::Reshape(vec![b, n]), &[pw]);
        (g, scaled, out)
    }

    fn alt_signs(n: usize) -> Tensor {
        let taps: Vec<f32> = (0..n)
            .map(|i| if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        Tensor::new(&[n, 1], taps).unwrap()
    }

    #[test]
    fn chain_fold_fires_and_presigns_gains_and_bias() {
        // mixed ±1 link over a nonzero-bias gain stage: the fold must
        // pre-sign both the gains and the bias, leaving scale + pointwise
        let (b, n) = (3usize, 8usize);
        let (mut g, _, out) = scale_chain_graph((b, n), alt_signs(n), Tensor::zeros(&[n]));
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 1, "chain link must fold into the scale");
        assert_eq!(plan.step_count(), 2, "scale + pointwise only");
        assert_eq!(plan.materialize_count(), 0);
        check_bitwise(&g, &[Tensor::randn(&[b, n], 521)]);
    }

    #[test]
    fn chain_fold_skips_non_unit_link_taps() {
        // a 0.5 link tap would reassociate t*(g*x) into (t*g)*x —
        // different rounding, so the pass must leave the graph unfused
        let (b, n) = (2usize, 8usize);
        let mut taps = alt_signs(n);
        taps.data_mut()[0] = 0.5;
        let (mut g, _, out) = scale_chain_graph((b, n), taps, Tensor::zeros(&[n]));
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 0, "non-unit link taps must not fold");
        check_bitwise(&g, &[Tensor::randn(&[b, n], 522)]);
    }

    #[test]
    fn chain_fold_skips_nonzero_link_bias() {
        // a nonzero link bias changes where the +bias lands relative to
        // the producer's own bias add: skip
        let (b, n) = (2usize, 8usize);
        let (mut g, _, out) = scale_chain_graph((b, n), alt_signs(n), Tensor::randn(&[n], 523));
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 0, "nonzero link bias must not fold");
        check_bitwise(&g, &[Tensor::randn(&[b, n], 524)]);
    }

    #[test]
    fn chain_fold_skips_shared_scale_output() {
        // the gain-stage output is also a plan output: folding would
        // re-sign the values that output observes — skip
        let (b, n) = (2usize, 8usize);
        let (mut g, scaled, out) = scale_chain_graph((b, n), alt_signs(n), Tensor::zeros(&[n]));
        g.set_outputs(&[out, scaled]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 0, "shared scale output must not fold");
        check_bitwise(&g, &[Tensor::randn(&[b, n], 525)]);
    }

    #[test]
    fn chain_folds_never_cascade() {
        // two stacked ±1 links: the first folds into the scale; the
        // second must leave the already-rewritten scale alone or its
        // audit certificate would be invalidated
        let (b, n) = (2usize, 8usize);
        let mut g = Graph::new();
        let x = g.input(&[b, n]);
        let xi = g.push(NodeOp::Reshape(vec![b, n, 1]), &[x]);
        let kg = g.constant(Tensor::randn(&[n, 1], 526));
        let pb = g.constant(Tensor::randn(&[n], 527));
        let scaled = g.push(NodeOp::DepthwiseConv1d, &[xi, kg, pb]);
        let bz = g.constant(Tensor::zeros(&[n]));
        let k1 = g.constant(alt_signs(n));
        let l1 = g.push_with_hint(NodeOp::DepthwiseConv1d, &[scaled, k1, bz], FusionHint::Chain);
        let k2 = g.constant(Tensor::new(&[n, 1], vec![-1.0; n]).unwrap());
        let l2 = g.push_with_hint(NodeOp::DepthwiseConv1d, &[l1, k2, bz], FusionHint::Chain);
        let kd = g.constant(Tensor::randn(&[n, n], 528));
        let pw = g.push(NodeOp::PointwiseConv, &[l2, kd, bz]);
        let out = g.push(NodeOp::Reshape(vec![b, n]), &[pw]);
        g.set_outputs(&[out]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.fused_steps(), 1, "only the first link may fold");
        check_bitwise(&g, &[Tensor::randn(&[b, n], 529)]);
    }

    #[test]
    fn beamform_gains_fold_into_delay_taps_at_every_bucket() {
        // the depthwise-producer window fold: the hinted M=1 gain stage
        // folds into the one-hot delay conv, leaving conv + channel sum
        let (c, l) = (4usize, 64usize);
        let delays = [0usize, 3, 1, 2];
        let gains = [1.0f32, 0.8, -0.6, 0.4];
        for b in [1usize, 2, 4, 8] {
            let g = lower::beamform(b, c, l, &delays, &gains).unwrap();
            let plan = ExecPlan::compile(&g).unwrap();
            assert_eq!(plan.fused_steps(), 1, "B={b}: gains must fold");
            assert_eq!(plan.materialize_count(), 0, "B={b}");
            assert_eq!(plan.step_count(), 2, "B={b}: delay conv + channel sum");
            check_bitwise(&g, &[Tensor::randn(&[b, c, l], 530 + b as u64)]);
        }
    }

    #[test]
    fn fx_correlate_compiles_fused_and_copy_free_at_every_bucket() {
        // two window folds (one per antenna STFT) + one chain fold
        // (conjugation into gain calibration); at B>1 the per-antenna
        // frame regroupings become split views
        let (l, nfft, hop) = (192usize, 16usize, 8usize);
        let gains: Vec<f32> = (0..nfft).map(|i| 0.5 + 0.05 * i as f32).collect();
        for b in [1usize, 2, 4] {
            let g = lower::fx_correlate(b, l, nfft, hop, &gains).unwrap();
            let plan = ExecPlan::compile(&g).unwrap();
            assert_eq!(plan.fused_steps(), 3, "B={b}: 2 windows + 1 chain");
            assert_eq!(plan.materialize_count(), 0, "B={b}");
            assert_eq!(
                plan.fusion_eliminated_copies(),
                2 * usize::from(b > 1),
                "B={b}"
            );
            let x1 = Tensor::randn(&[b, l], 540 + b as u64);
            let x2 = Tensor::randn(&[b, l], 550 + b as u64);
            check_bitwise(&g, &[x1, x2]);
        }
    }

    #[test]
    fn spectrometer_compiles_copy_free_at_every_bucket() {
        // the one-graph spectrometer: every intermediate movement is a
        // contiguous reshape, so the plan never materializes at any B
        let cfg = dsp::PfbConfig::new(8, 4);
        for b in [1usize, 2, 4, 8] {
            let g = lower::spectrometer(b, 8 * 32, cfg).unwrap();
            let plan = ExecPlan::compile(&g).unwrap();
            assert_eq!(plan.materialize_count(), 0, "B={b}");
            assert_eq!(plan.movement_materialize_count(), 0, "B={b}");
            check_bitwise(&g, &[Tensor::randn(&[b, 8 * 32], 560 + b as u64)]);
        }
    }

    #[test]
    fn view_reshape_algebra() {
        // contiguous reshape is free in both directions
        let v = View::contiguous(&[4, 6]);
        assert!(v.reshape(&[24]).is_some());
        assert!(v.reshape(&[2, 12]).is_some());
        // transposed views cannot merge across the transposed axes
        let t = v.transpose2();
        assert!(t.reshape(&[24]).is_none());
        assert!(t.reshape(&[3, 8]).is_none());
        // ...but size-1 insertion is always free (tail strides of size-1
        // axes are meaningless; only the first two matter)
        let t1 = t.reshape(&[6, 4, 1]).unwrap();
        assert_eq!(&t1.strides[..2], &[1, 6]);
        // strided slice blocks merging through the sliced axis
        let s = View::contiguous(&[2, 8, 3]).stride_axis(1, 3, 3);
        assert!(s.reshape(&[2, 9]).is_none());
        assert!(s.reshape(&[2, 3, 3, 1]).is_some());
        // the PFB window: split then permute stays affine
        let p = View::contiguous(&[2, 64])
            .reshape(&[2, 8, 8])
            .unwrap()
            .permute3([0, 2, 1]);
        assert_eq!(p.strides, vec![64, 1, 8]);
        assert!(!p.is_contiguous());
        // the STFT B=1 framing chain stays affine end to end
        let (l, nfft, hop) = (600usize, 64usize, 32usize);
        let w = l - nfft + 1;
        let frames = (l - nfft) / hop + 1;
        let f = View::contiguous(&[1, nfft, w])
            .stride_axis(2, hop, frames)
            .permute3([0, 2, 1])
            .reshape(&[frames, nfft, 1])
            .unwrap();
        assert_eq!(&f.strides[..2], &[hop, w]);
    }
}
