//! Graph -> [`ExecPlan`] compilation and plan execution.
//!
//! `ExecPlan::compile` runs once per (op, shape signature) and does all the
//! work the naive interpreter repeats on every request:
//!
//! * **constant baking** — `Constant` nodes are cloned into the plan once
//!   (the interpreter clones every weight tensor on every run);
//! * **alias analysis** — `Reshape` becomes a metadata-only view: the
//!   value shares its producer's buffer with a different shape;
//! * **elementwise fusion** — single-consumer `Add`/`Sub` chains collapse
//!   into one [`fused::fused_ew`] pass, and `Add`/`Sub` of a layer output
//!   with a per-channel-uniform constant folds into that layer's bias;
//! * **liveness analysis** — every surviving value gets a slot in a slab
//!   [`Arena`] via linear-scan allocation over the topological schedule;
//!   a buffer is recycled the moment its last consumer has run;
//! * **threaded execution** — the kernels in [`fused`] fan independent
//!   output rows across the thread pool.
//!
//! Plans are immutable and shareable (`Send + Sync`); the arena is the
//! only mutable run state, so one plan serves many concurrent requests
//! (see [`super::Planned`]).

use super::arena::Arena;
use super::fused;
use crate::tensor::Tensor;
use crate::tina::graph::{Graph, NodeOp, ValueId};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, HashSet};

/// Where a value's bytes live at execution time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Loc {
    /// Caller-provided input tensor (never copied).
    External(usize),
    /// Plan-owned constant (baked at compile time).
    Const(usize),
    /// Arena slot (recycled across values with disjoint lifetimes).
    Slot(usize),
}

/// One resolved kernel argument.
#[derive(Debug, Clone)]
struct ArgRef {
    loc: Loc,
    shape: Vec<usize>,
    /// Producing value id (diagnostics + liveness validation).
    root: usize,
}

#[derive(Debug, Clone)]
enum Kernel {
    StandardConv1d,
    DepthwiseConv1d,
    PointwiseConv,
    FullyConnected,
    Transpose2,
    Permute3([usize; 3]),
    StridedSlice {
        axis: usize,
        stride: usize,
        count: usize,
    },
    /// Collapsed Add/Sub chain; `signs[i]` applies to `args[i]`.
    FusedEw { signs: Vec<f32> },
}

#[derive(Debug, Clone)]
struct Step {
    kernel: Kernel,
    args: Vec<ArgRef>,
    out_slot: usize,
    out_shape: Vec<usize>,
    /// Value id this step produces (liveness validation).
    out_root: usize,
}

/// A compiled, immutable execution plan for one graph.
#[derive(Debug)]
pub struct ExecPlan {
    input_shapes: Vec<Vec<usize>>,
    constants: Vec<Tensor>,
    steps: Vec<Step>,
    slot_sizes: Vec<usize>,
    outputs: Vec<ArgRef>,
}

/// Compile-time storage class of a value (pass-A bookkeeping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Storage {
    External(usize),
    Const(usize),
    /// Produced by an emitted step; slot assigned in the liveness pass.
    Owned,
}

#[derive(Debug, Clone)]
struct ValInfo {
    st: Storage,
    root: usize,
}

#[derive(Debug, Clone)]
struct ProtoArg {
    shape: Vec<usize>,
    st: Storage,
    root: usize,
}

#[derive(Debug)]
struct ProtoStep {
    kernel: Kernel,
    args: Vec<ProtoArg>,
    out_vid: usize,
}

/// If `t` (shaped like a layer output, channel axis 1) is constant along
/// every non-channel coordinate, return the per-channel values.
fn per_channel_uniform(t: &Tensor, out_shape: &[usize]) -> Option<Vec<f32>> {
    let (outer, c, inner) = match *out_shape {
        [a, b, w] => (a, b, w),
        [a, b] => (a, b, 1),
        _ => return None,
    };
    if t.shape() != out_shape {
        return None;
    }
    let d = t.data();
    let vals: Vec<f32> = (0..c).map(|ch| d[ch * inner]).collect();
    for o in 0..outer {
        for (ch, &v) in vals.iter().enumerate() {
            for i in 0..inner {
                if d[(o * c + ch) * inner + i] != v {
                    return None;
                }
            }
        }
    }
    Some(vals)
}

/// Flatten an Add/Sub chain rooted at node `j` into signed terms, left to
/// right.  Only first operands are ever marked inlined (see the fusion
/// decision pass), so the flattened sequence reproduces the chain's f32
/// rounding exactly.
fn expand_terms(
    g: &Graph,
    inlined: &[bool],
    n_inputs: usize,
    j: usize,
    sign: f32,
    out: &mut Vec<(f32, usize)>,
) {
    let node = &g.nodes[j];
    let (sa, sb) = match node.op {
        NodeOp::Add => (sign, sign),
        NodeOp::Sub => (sign, -sign),
        _ => unreachable!("expand_terms on non-elementwise node"),
    };
    for (v, s) in [(node.inputs[0], sa), (node.inputs[1], sb)] {
        match v.0.checked_sub(n_inputs) {
            Some(cj) if inlined[cj] => expand_terms(g, inlined, n_inputs, cj, s, out),
            _ => out.push((s, v.0)),
        }
    }
}

impl ExecPlan {
    /// Compile a validated graph into an execution plan.
    pub fn compile(g: &Graph) -> Result<ExecPlan> {
        g.validate()?;
        let n_inputs = g.inputs.len();
        let n_values = g.value_count();
        for (i, (id, _)) in g.inputs.iter().enumerate() {
            if id.0 != i {
                bail!("exec plans require graph inputs declared before any node");
            }
        }
        let shapes = g.infer_shapes()?;
        let n_nodes = g.nodes.len();
        let node_of = |v: ValueId| v.0.checked_sub(n_inputs);

        // ---- use counts + single-consumer map -----------------------------
        let mut uses = vec![0usize; n_values];
        let mut consumer: Vec<Option<usize>> = vec![None; n_values];
        for (j, node) in g.nodes.iter().enumerate() {
            for v in &node.inputs {
                uses[v.0] += 1;
                consumer[v.0] = Some(j);
            }
        }
        for v in &g.outputs {
            uses[v.0] += 1;
        }

        // ---- fusion decision 1: fold ew-with-constant into layer bias -----
        // Add(layer, c) / Add(c, layer) / Sub(layer, c) where `layer` has a
        // constant bias and no other consumer, and `c` is per-channel
        // uniform: rewrite the layer's bias, alias the ew node to the layer.
        let mut fold_alias: Vec<Option<ValueId>> = vec![None; n_nodes];
        let mut fused_bias: HashMap<usize, Tensor> = HashMap::new();
        for (j, node) in g.nodes.iter().enumerate() {
            let base_sign = match node.op {
                NodeOp::Add => 1.0f32,
                NodeOp::Sub => -1.0,
                _ => continue,
            };
            let (a, b) = (node.inputs[0], node.inputs[1]);
            let mut candidates = vec![(a, b, base_sign)];
            if matches!(node.op, NodeOp::Add) {
                candidates.push((b, a, 1.0));
            }
            for (lv, cv, csign) in candidates {
                let (Some(li), Some(ci)) = (node_of(lv), node_of(cv)) else {
                    continue;
                };
                if !g.nodes[li].op.is_layer() || uses[lv.0] != 1 || fused_bias.contains_key(&li)
                {
                    continue;
                }
                let NodeOp::Constant(cd) = &g.nodes[ci].op else {
                    continue;
                };
                let Some(bi) = node_of(g.nodes[li].inputs[2]) else {
                    continue;
                };
                let NodeOp::Constant(bias_t) = &g.nodes[bi].op else {
                    continue;
                };
                let Some(chan) = per_channel_uniform(cd, &shapes[lv.0]) else {
                    continue;
                };
                let mut nb = bias_t.data().to_vec();
                for (o, v) in nb.iter_mut().zip(&chan) {
                    *o += csign * v;
                }
                fused_bias.insert(li, Tensor::new(bias_t.shape(), nb)?);
                fold_alias[j] = Some(lv);
                break;
            }
        }

        // ---- fusion decision 2: collapse single-consumer Add/Sub chains ---
        // Only a consumer's FIRST operand is inlined: left-to-right
        // evaluation of the flattened terms then performs exactly the same
        // f32 additions in the same order as the node-by-node chain, so the
        // fused pass stays bit-identical to the interpreter oracle.
        // (Inlining the second operand would turn x + (y + z) into
        // (x + y) + z — a different rounding.)
        let mut inlined = vec![false; n_nodes];
        for (j, node) in g.nodes.iter().enumerate() {
            if !matches!(node.op, NodeOp::Add | NodeOp::Sub) || fold_alias[j].is_some() {
                continue;
            }
            let vid = n_inputs + j;
            if uses[vid] != 1 {
                continue;
            }
            let Some(cj) = consumer[vid] else { continue };
            if matches!(g.nodes[cj].op, NodeOp::Add | NodeOp::Sub)
                && fold_alias[cj].is_none()
                && g.nodes[cj].inputs[0] == ValueId(vid)
            {
                inlined[j] = true;
            }
        }

        // ---- pass A: resolve storage, emit proto steps --------------------
        let mut info: Vec<Option<ValInfo>> = vec![None; n_values];
        for (i, (id, _)) in g.inputs.iter().enumerate() {
            info[id.0] = Some(ValInfo {
                st: Storage::External(i),
                root: id.0,
            });
        }
        let mut constants: Vec<Tensor> = Vec::new();
        let mut protos: Vec<ProtoStep> = Vec::new();
        let arg_of = |vid: usize, info: &[Option<ValInfo>], shapes: &[Vec<usize>]| -> Result<ProtoArg> {
            let vi = info[vid]
                .as_ref()
                .ok_or_else(|| anyhow!("value {vid} consumed before materialization"))?;
            Ok(ProtoArg {
                shape: shapes[vid].clone(),
                st: vi.st,
                root: vi.root,
            })
        };
        for (j, node) in g.nodes.iter().enumerate() {
            let vid = n_inputs + j;
            match &node.op {
                NodeOp::Constant(t) => {
                    constants.push(t.clone());
                    info[vid] = Some(ValInfo {
                        st: Storage::Const(constants.len() - 1),
                        root: vid,
                    });
                }
                NodeOp::Reshape(_) => {
                    // metadata-only view: same storage, new shape
                    let src = info[node.inputs[0].0]
                        .clone()
                        .ok_or_else(|| anyhow!("reshape of unmaterialized value"))?;
                    info[vid] = Some(src);
                }
                NodeOp::Add | NodeOp::Sub => {
                    if let Some(lv) = fold_alias[j] {
                        // folded into the producing layer's bias
                        info[vid] = Some(info[lv.0].clone().expect("layer before fold"));
                    } else if inlined[j] {
                        // expanded inside the consuming chain; no value
                    } else {
                        let mut terms: Vec<(f32, usize)> = Vec::new();
                        expand_terms(g, &inlined, n_inputs, j, 1.0, &mut terms);
                        let signs: Vec<f32> = terms.iter().map(|t| t.0).collect();
                        let args = terms
                            .iter()
                            .map(|&(_, v)| arg_of(v, &info, &shapes))
                            .collect::<Result<Vec<_>>>()?;
                        protos.push(ProtoStep {
                            kernel: Kernel::FusedEw { signs },
                            args,
                            out_vid: vid,
                        });
                        info[vid] = Some(ValInfo {
                            st: Storage::Owned,
                            root: vid,
                        });
                    }
                }
                op => {
                    let kernel = match op {
                        NodeOp::StandardConv1d => Kernel::StandardConv1d,
                        NodeOp::DepthwiseConv1d => Kernel::DepthwiseConv1d,
                        NodeOp::PointwiseConv => Kernel::PointwiseConv,
                        NodeOp::FullyConnected => Kernel::FullyConnected,
                        NodeOp::Transpose2 => Kernel::Transpose2,
                        NodeOp::Permute3(p) => Kernel::Permute3(*p),
                        NodeOp::StridedSlice {
                            axis,
                            stride,
                            count,
                        } => Kernel::StridedSlice {
                            axis: *axis,
                            stride: *stride,
                            count: *count,
                        },
                        _ => unreachable!("handled above"),
                    };
                    let mut args = node
                        .inputs
                        .iter()
                        .map(|v| arg_of(v.0, &info, &shapes))
                        .collect::<Result<Vec<_>>>()?;
                    if let Some(nb) = fused_bias.get(&j) {
                        constants.push(nb.clone());
                        args[2] = ProtoArg {
                            shape: nb.shape().to_vec(),
                            st: Storage::Const(constants.len() - 1),
                            root: usize::MAX,
                        };
                    }
                    protos.push(ProtoStep {
                        kernel,
                        args,
                        out_vid: vid,
                    });
                    info[vid] = Some(ValInfo {
                        st: Storage::Owned,
                        root: vid,
                    });
                }
            }
        }

        // ---- read counts over owned storages ------------------------------
        let mut reads: HashMap<usize, usize> = HashMap::new();
        for p in &protos {
            for a in &p.args {
                if a.st == Storage::Owned {
                    *reads.entry(a.root).or_default() += 1;
                }
            }
        }
        let mut pinned: HashSet<usize> = HashSet::new();
        for out in &g.outputs {
            let vi = info[out.0]
                .as_ref()
                .ok_or_else(|| anyhow!("graph output {out:?} never materialized"))?;
            if vi.st == Storage::Owned {
                pinned.insert(vi.root);
            }
        }

        // ---- pass B: linear-scan slot assignment --------------------------
        let mut slot_of: HashMap<usize, usize> = HashMap::new();
        let mut free: Vec<usize> = Vec::new();
        let mut slot_sizes: Vec<usize> = Vec::new();
        let mut remaining = reads.clone();
        let mut steps: Vec<Step> = Vec::with_capacity(protos.len());
        for p in protos {
            let out_len: usize = shapes[p.out_vid].iter().product();
            let slot = free.pop().unwrap_or_else(|| {
                slot_sizes.push(0);
                slot_sizes.len() - 1
            });
            slot_sizes[slot] = slot_sizes[slot].max(out_len);
            slot_of.insert(p.out_vid, slot);
            let args: Vec<ArgRef> = p
                .args
                .iter()
                .map(|a| ArgRef {
                    loc: match a.st {
                        Storage::External(i) => Loc::External(i),
                        Storage::Const(k) => Loc::Const(k),
                        Storage::Owned => Loc::Slot(slot_of[&a.root]),
                    },
                    shape: a.shape.clone(),
                    root: a.root,
                })
                .collect();
            // recycle inputs whose last consumer just ran
            for a in &p.args {
                if a.st == Storage::Owned {
                    let r = remaining.get_mut(&a.root).expect("counted");
                    *r -= 1;
                    if *r == 0 && !pinned.contains(&a.root) {
                        free.push(slot_of[&a.root]);
                    }
                }
            }
            // a value nobody reads (dead node) frees its slot immediately
            if reads.get(&p.out_vid).copied().unwrap_or(0) == 0 && !pinned.contains(&p.out_vid)
            {
                free.push(slot);
            }
            steps.push(Step {
                kernel: p.kernel,
                args,
                out_slot: slot,
                out_shape: shapes[p.out_vid].clone(),
                out_root: p.out_vid,
            });
        }

        let mut outputs: Vec<ArgRef> = g
            .outputs
            .iter()
            .map(|v| {
                let vi = info[v.0].as_ref().expect("checked above");
                ArgRef {
                    loc: match vi.st {
                        Storage::External(i) => Loc::External(i),
                        Storage::Const(k) => Loc::Const(k),
                        Storage::Owned => Loc::Slot(slot_of[&vi.root]),
                    },
                    shape: shapes[v.0].clone(),
                    root: vi.root,
                }
            })
            .collect();

        // ---- drop constants nothing references --------------------------
        // Fusion can orphan constants (a folded-away addend, a superseded
        // bias); plans live in the router cache for the process lifetime,
        // so compact them out instead of pinning dead tensors.
        let mut used = vec![false; constants.len()];
        for s in &steps {
            for a in &s.args {
                if let Loc::Const(k) = a.loc {
                    used[k] = true;
                }
            }
        }
        for o in &outputs {
            if let Loc::Const(k) = o.loc {
                used[k] = true;
            }
        }
        let mut remap = vec![usize::MAX; constants.len()];
        let mut compact: Vec<Tensor> = Vec::new();
        for (k, t) in constants.into_iter().enumerate() {
            if used[k] {
                remap[k] = compact.len();
                compact.push(t);
            }
        }
        let fix = |loc: &mut Loc| {
            if let Loc::Const(k) = *loc {
                *loc = Loc::Const(remap[k]);
            }
        };
        for s in &mut steps {
            for a in &mut s.args {
                fix(&mut a.loc);
            }
        }
        for o in &mut outputs {
            fix(&mut o.loc);
        }

        let plan = ExecPlan {
            input_shapes: g.inputs.iter().map(|(_, s)| s.clone()).collect(),
            constants: compact,
            steps,
            slot_sizes,
            outputs,
        };
        debug_assert!(plan.validate_liveness().is_ok());
        Ok(plan)
    }

    /// Execute with a throwaway arena (tests / one-shot callers).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut arena = Arena::new();
        self.run_in(&mut arena, inputs)
    }

    /// Execute reusing `arena`'s buffers (the serving hot path).
    pub fn run_in(&self, arena: &mut Arena, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.input_shapes.len() {
            bail!(
                "expected {} inputs, got {}",
                self.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (t, shape)) in inputs.iter().zip(&self.input_shapes).enumerate() {
            if t.shape() != shape.as_slice() {
                bail!(
                    "input {i} shape {:?} != declared {:?}",
                    t.shape(),
                    shape
                );
            }
        }
        arena.prepare(&self.slot_sizes);

        fn resolve<'a>(
            a: &ArgRef,
            inputs: &'a [Tensor],
            constants: &'a [Tensor],
            arena: &'a Arena,
        ) -> &'a [f32] {
            let n: usize = a.shape.iter().product();
            match a.loc {
                Loc::External(i) => &inputs[i].data()[..n],
                Loc::Const(k) => &constants[k].data()[..n],
                Loc::Slot(s) => &arena.slot(s)[..n],
            }
        }

        for step in &self.steps {
            let out_len: usize = step.out_shape.iter().product();
            let mut out_buf = arena.take(step.out_slot);
            debug_assert!(out_buf.len() >= out_len);
            {
                let out = &mut out_buf[..out_len];
                let arg = |i: usize| resolve(&step.args[i], inputs, &self.constants, arena);
                match &step.kernel {
                    Kernel::DepthwiseConv1d => {
                        let (xs, ks) = (&step.args[0].shape, &step.args[1].shape);
                        fused::depthwise_conv(
                            arg(0),
                            (xs[0], xs[1], xs[2]),
                            arg(1),
                            ks[1],
                            arg(2),
                            out,
                        );
                    }
                    Kernel::StandardConv1d => {
                        let (xs, ks) = (&step.args[0].shape, &step.args[1].shape);
                        fused::standard_conv(
                            arg(0),
                            (xs[0], xs[1], xs[2]),
                            arg(1),
                            (ks[0], ks[2]),
                            arg(2),
                            out,
                        );
                    }
                    Kernel::PointwiseConv => {
                        let (xs, ks) = (&step.args[0].shape, &step.args[1].shape);
                        fused::pointwise_conv(
                            arg(0),
                            (xs[0], xs[1], xs[2]),
                            arg(1),
                            ks[1],
                            arg(2),
                            out,
                        );
                    }
                    Kernel::FullyConnected => {
                        let (xs, ks) = (&step.args[0].shape, &step.args[1].shape);
                        fused::fully_connected(
                            arg(0),
                            (xs[0], xs[1]),
                            arg(1),
                            ks[1],
                            arg(2),
                            out,
                        );
                    }
                    Kernel::Transpose2 => {
                        let xs = &step.args[0].shape;
                        fused::transpose2(arg(0), (xs[0], xs[1]), out);
                    }
                    Kernel::Permute3(p) => {
                        let xs = &step.args[0].shape;
                        fused::permute3(arg(0), (xs[0], xs[1], xs[2]), *p, out);
                    }
                    Kernel::StridedSlice {
                        axis,
                        stride,
                        count,
                    } => {
                        fused::strided_slice(
                            arg(0),
                            &step.args[0].shape,
                            *axis,
                            *stride,
                            *count,
                            out,
                        );
                    }
                    Kernel::FusedEw { signs } => {
                        let terms: Vec<(f32, &[f32])> = signs
                            .iter()
                            .zip(&step.args)
                            .map(|(&s, a)| (s, resolve(a, inputs, &self.constants, arena)))
                            .collect();
                        fused::fused_ew(&terms, out);
                    }
                }
            }
            arena.put(step.out_slot, out_buf);
        }

        self.outputs
            .iter()
            .map(|o| {
                let data = resolve(o, inputs, &self.constants, arena).to_vec();
                Tensor::new(&o.shape, data)
            })
            .collect()
    }

    /// Number of arena slots the plan needs (its peak live-buffer count).
    pub fn slot_count(&self) -> usize {
        self.slot_sizes.len()
    }

    /// Number of kernel steps after fusion/aliasing.
    pub fn step_count(&self) -> usize {
        self.steps.len()
    }

    /// Bytes of arena the plan's slots occupy at their high-water sizes.
    pub fn arena_bytes(&self) -> usize {
        self.slot_sizes.iter().map(|&n| n * 4).sum()
    }

    /// Constants baked into the plan (after dead-constant compaction).
    pub fn constant_count(&self) -> usize {
        self.constants.len()
    }

    /// Declared input shapes, in call order.
    pub fn input_shapes(&self) -> &[Vec<usize>] {
        &self.input_shapes
    }

    /// Symbolically execute the schedule and verify that no step reads a
    /// slot after it has been recycled to another value, that no step's
    /// output slot aliases one of its inputs, and that pinned outputs are
    /// never overwritten.  Used by tests to prove the arena sound.
    pub fn validate_liveness(&self) -> Result<()> {
        let mut reads: HashMap<usize, usize> = HashMap::new();
        for s in &self.steps {
            for a in &s.args {
                if matches!(a.loc, Loc::Slot(_)) {
                    *reads.entry(a.root).or_default() += 1;
                }
            }
        }
        let mut pinned: HashSet<usize> = HashSet::new();
        for o in &self.outputs {
            if matches!(o.loc, Loc::Slot(_)) {
                pinned.insert(o.root);
            }
        }
        let mut owner: Vec<Option<usize>> = vec![None; self.slot_sizes.len()];
        let mut remaining = reads.clone();
        for (si, s) in self.steps.iter().enumerate() {
            for a in &s.args {
                if let Loc::Slot(slot) = a.loc {
                    if owner[slot] != Some(a.root) {
                        bail!(
                            "step {si}: reads value {} from slot {slot} holding {:?} (read-after-recycle)",
                            a.root,
                            owner[slot]
                        );
                    }
                    if slot == s.out_slot {
                        bail!("step {si}: output slot {slot} aliases an input");
                    }
                }
            }
            if let Some(prev) = owner[s.out_slot] {
                if remaining.get(&prev).copied().unwrap_or(0) > 0 {
                    bail!(
                        "step {si}: overwrites slot {} holding live value {prev}",
                        s.out_slot
                    );
                }
                if pinned.contains(&prev) {
                    bail!("step {si}: overwrites pinned output value {prev}");
                }
            }
            owner[s.out_slot] = Some(s.out_root);
            for a in &s.args {
                if matches!(a.loc, Loc::Slot(_)) {
                    *remaining.get_mut(&a.root).expect("counted") -= 1;
                }
            }
        }
        for (oi, o) in self.outputs.iter().enumerate() {
            if let Loc::Slot(slot) = o.loc {
                if owner[slot] != Some(o.root) {
                    bail!("output {oi}: slot {slot} recycled before return");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp;
    use crate::tina::lower;
    use crate::tina::Interpreter;

    fn check_against_interpreter(g: Graph, inputs: &[Tensor]) {
        let interp = Interpreter::new(g.clone()).unwrap();
        let plan = ExecPlan::compile(&g).unwrap();
        plan.validate_liveness().unwrap();
        let want = interp.run(inputs).unwrap();
        let got = plan.run(inputs).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.shape(), b.shape());
            assert!(
                a.allclose(b, 1e-5, 1e-6),
                "planned executor diverged (max diff {})",
                a.max_abs_diff(b).unwrap()
            );
        }
    }

    #[test]
    fn matches_interpreter_on_every_lowering() {
        let cfg = dsp::PfbConfig::new(8, 4);
        let taps = dsp::fir_lowpass(16, 0.2).unwrap();
        check_against_interpreter(
            lower::ewmult(5, 7),
            &[Tensor::randn(&[5, 7], 1), Tensor::randn(&[5, 7], 2)],
        );
        check_against_interpreter(
            lower::ewadd(3, 9),
            &[Tensor::randn(&[3, 9], 3), Tensor::randn(&[3, 9], 4)],
        );
        check_against_interpreter(
            lower::matmul(6, 10, 4),
            &[Tensor::randn(&[6, 10], 5), Tensor::randn(&[10, 4], 6)],
        );
        check_against_interpreter(lower::summation(500), &[Tensor::randn(&[500], 7)]);
        check_against_interpreter(lower::dft(2, 16), &[Tensor::randn(&[2, 16], 8)]);
        check_against_interpreter(
            lower::idft(2, 16),
            &[Tensor::randn(&[2, 16], 9), Tensor::randn(&[2, 16], 10)],
        );
        check_against_interpreter(
            lower::fir(2, 200, &taps).unwrap(),
            &[Tensor::randn(&[2, 200], 11)],
        );
        check_against_interpreter(
            lower::unfold(1, 50, 8).unwrap(),
            &[Tensor::randn(&[1, 50], 12)],
        );
        check_against_interpreter(
            lower::pfb_fir(2, 8 * 32, cfg).unwrap(),
            &[Tensor::randn(&[2, 8 * 32], 13)],
        );
        check_against_interpreter(
            lower::pfb(2, 8 * 32, cfg).unwrap(),
            &[Tensor::randn(&[2, 8 * 32], 14)],
        );
        check_against_interpreter(
            lower::stft(2, 600, 64, 32).unwrap(),
            &[Tensor::randn(&[2, 600], 15)],
        );
    }

    #[test]
    fn arena_slots_are_recycled() {
        // STFT has a long chain of intermediates; the linear-scan allocator
        // must map them onto fewer slots than steps.
        let g = lower::stft(1, 1024, 64, 32).unwrap();
        let plan = ExecPlan::compile(&g).unwrap();
        assert!(
            plan.slot_count() < plan.step_count(),
            "no reuse: {} slots for {} steps",
            plan.slot_count(),
            plan.step_count()
        );
        plan.validate_liveness().unwrap();
    }

    #[test]
    fn reshape_is_metadata_only() {
        // ewmult lowers to reshape/reshape/depthwise/reshape: only the
        // depthwise conv should materialize a buffer.
        let g = lower::ewmult(4, 4);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 1, "reshapes must not become steps");
        assert_eq!(plan.slot_count(), 1);
    }

    #[test]
    fn ew_chain_collapses_to_single_fused_pass() {
        // (a - b) + c with single consumers collapses into one FusedEw.
        let mut g = Graph::new();
        let a = g.input(&[4, 4]);
        let b = g.input(&[4, 4]);
        let c = g.input(&[4, 4]);
        let s = g.push(NodeOp::Sub, &[a, b]);
        let o = g.push(NodeOp::Add, &[s, c]);
        g.set_outputs(&[o]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 1, "chain must fuse into one pass");
        check_against_interpreter(
            g,
            &[
                Tensor::randn(&[4, 4], 20),
                Tensor::randn(&[4, 4], 21),
                Tensor::randn(&[4, 4], 22),
            ],
        );
    }

    #[test]
    fn constant_add_folds_into_layer_bias() {
        // FC output + per-channel-uniform constant folds into the bias.
        let mut g = Graph::new();
        let x = g.input(&[3, 5]);
        let k = g.constant(Tensor::randn(&[5, 4], 30));
        let bias = g.constant(Tensor::randn(&[4], 31));
        let fc = g.push(NodeOp::FullyConnected, &[x, k, bias]);
        // constant with each channel column uniform across the batch
        let chan = [0.5f32, -1.0, 2.0, 0.25];
        let mut cdata = Vec::new();
        for _ in 0..3 {
            cdata.extend_from_slice(&chan);
        }
        let c = g.constant(Tensor::new(&[3, 4], cdata).unwrap());
        let o = g.push(NodeOp::Add, &[fc, c]);
        g.set_outputs(&[o]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 1, "add must fold into the FC bias");
        // kernel + fused bias survive; the folded addend and the original
        // bias are compacted out of the plan
        assert_eq!(plan.constant_count(), 2, "dead constants must be dropped");
        check_against_interpreter(g, &[Tensor::randn(&[3, 5], 32)]);
    }

    #[test]
    fn non_uniform_constant_does_not_fold() {
        let mut g = Graph::new();
        let x = g.input(&[2, 3]);
        let k = g.constant(Tensor::randn(&[3, 3], 33));
        let bias = g.constant(Tensor::zeros(&[3]));
        let fc = g.push(NodeOp::FullyConnected, &[x, k, bias]);
        let c = g.constant(Tensor::randn(&[2, 3], 34)); // not per-channel uniform
        let o = g.push(NodeOp::Add, &[fc, c]);
        g.set_outputs(&[o]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 2, "must stay FC + FusedEw");
        check_against_interpreter(g, &[Tensor::randn(&[2, 3], 35)]);
    }

    #[test]
    fn shared_intermediate_is_not_inlined() {
        // d = a + b used twice: must materialize once, not be re-expanded.
        let mut g = Graph::new();
        let a = g.input(&[2, 2]);
        let b = g.input(&[2, 2]);
        let d = g.push(NodeOp::Add, &[a, b]);
        let e = g.push(NodeOp::Add, &[d, d]);
        let f = g.push(NodeOp::Sub, &[e, d]);
        g.set_outputs(&[f]);
        check_against_interpreter(
            g,
            &[Tensor::randn(&[2, 2], 40), Tensor::randn(&[2, 2], 41)],
        );
    }

    #[test]
    fn graph_input_passthrough_output() {
        // an output that is directly a graph input (External loc path)
        let mut g = Graph::new();
        let x = g.input(&[2, 3]);
        let r = g.push(NodeOp::Reshape(vec![3, 2]), &[x]);
        g.set_outputs(&[r, x]);
        let plan = ExecPlan::compile(&g).unwrap();
        assert_eq!(plan.step_count(), 0);
        let t = Tensor::randn(&[2, 3], 50);
        let out = plan.run(&[t.clone()]).unwrap();
        assert_eq!(out[0].shape(), &[3, 2]);
        assert_eq!(out[0].data(), t.data());
        assert_eq!(out[1], t);
    }

    #[test]
    fn rejects_wrong_inputs_like_interpreter() {
        let plan = ExecPlan::compile(&lower::ewmult(2, 2)).unwrap();
        assert!(plan.run(&[Tensor::zeros(&[2, 2])]).is_err());
        assert!(plan
            .run(&[Tensor::zeros(&[2, 3]), Tensor::zeros(&[2, 2])])
            .is_err());
    }

    #[test]
    fn repeat_runs_reuse_arena_without_corruption() {
        let g = lower::pfb(1, 8 * 32, dsp::PfbConfig::new(8, 4)).unwrap();
        let interp = Interpreter::new(g.clone()).unwrap();
        let plan = ExecPlan::compile(&g).unwrap();
        let mut arena = Arena::new();
        for seed in 0..4u64 {
            let x = Tensor::randn(&[1, 8 * 32], 60 + seed);
            let want = interp.run(std::slice::from_ref(&x)).unwrap();
            let got = plan.run_in(&mut arena, std::slice::from_ref(&x)).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!(a.allclose(b, 1e-5, 1e-6), "seed {seed}");
            }
        }
    }
}
