//! Planned fallback executor: compile a TINA [`Graph`](crate::tina::Graph)
//! once into an [`ExecPlan`], then execute it many times against a
//! recycled slab [`Arena`].
//!
//! This is the serving-path replacement for the node-at-a-time
//! [`Interpreter`](crate::tina::Interpreter): the interpreter allocates a
//! fresh tensor (and clones every constant) per node per request, while a
//! plan bakes constants, turns `Reshape` into metadata-only views, fuses
//! elementwise chains, recycles buffers via liveness analysis, and fans
//! independent batch rows across the thread pool.  The interpreter remains
//! the cross-check oracle: property tests assert plan output equality on
//! every lowering (see `rust/tests/properties.rs`).
//!
//! Module layout:
//! * [`plan`] — compilation (alias/fusion/liveness) and step execution;
//! * [`arena`] — the reusable buffer slab;
//! * [`fused`] — slice-level threaded kernels (same accumulation order as
//!   [`crate::tina::layers`], so results agree to rounding).

pub mod arena;
pub mod fused;
pub mod plan;

pub use arena::Arena;
pub use plan::ExecPlan;

use crate::tensor::Tensor;
use crate::tina::graph::Graph;
use anyhow::Result;
use std::sync::Mutex;

/// Upper bound on pooled arenas per plan (beyond this, concurrent requests
/// fall back to a throwaway arena rather than growing the pool forever).
const ARENA_POOL_CAP: usize = 8;

/// A shareable compiled plan plus a pool of recycled arenas — the object
/// the router caches and the coordinator executes fallback requests on.
#[derive(Debug)]
pub struct Planned {
    plan: ExecPlan,
    arenas: Mutex<Vec<Arena>>,
}

impl Planned {
    /// Compile a graph into a planned executor.
    pub fn new(graph: &Graph) -> Result<Planned> {
        Ok(Planned {
            plan: ExecPlan::compile(graph)?,
            arenas: Mutex::new(Vec::new()),
        })
    }

    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Execute, borrowing an arena from the pool (allocation-free in the
    /// steady state) and returning it afterwards.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut arena = self.arenas.lock().unwrap().pop().unwrap_or_default();
        let result = self.plan.run_in(&mut arena, inputs);
        let mut pool = self.arenas.lock().unwrap();
        if pool.len() < ARENA_POOL_CAP {
            pool.push(arena);
        }
        result
    }

    /// Arenas currently parked in the pool (tests/metrics).
    pub fn pooled_arenas(&self) -> usize {
        self.arenas.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tina::lower;

    #[test]
    fn planned_pools_arenas_across_runs() {
        let p = Planned::new(&lower::ewadd(8, 8)).unwrap();
        assert_eq!(p.pooled_arenas(), 0);
        let a = Tensor::randn(&[8, 8], 1);
        let b = Tensor::randn(&[8, 8], 2);
        p.run(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(p.pooled_arenas(), 1);
        p.run(&[a, b]).unwrap();
        assert_eq!(p.pooled_arenas(), 1, "arena must be reused, not re-added");
    }

    #[test]
    fn planned_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Planned>();
    }
}
