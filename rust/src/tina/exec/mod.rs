//! Planned fallback executor: compile a TINA [`Graph`](crate::tina::Graph)
//! once into an [`ExecPlan`], then execute it many times against a
//! recycled slab [`Arena`].
//!
//! This is the serving-path replacement for the node-at-a-time
//! [`Interpreter`](crate::tina::Interpreter): the interpreter allocates a
//! fresh tensor (and clones every constant) per node per request, while a
//! plan bakes (and pre-packs) constants, fuses elementwise chains,
//! recycles buffers via liveness analysis, and fans independent output
//! rows across the thread pool.
//!
//! # The view/materialize value model
//!
//! Every value in a plan is a strided *view* — `(backing location, offset,
//! shape, strides)` — not necessarily a dense buffer.  All four
//! data-movement ops (`Reshape`, `Transpose2`, `Permute3`, `StridedSlice`)
//! compile to metadata-only stride rewrites, and the layer kernels read
//! their activation input *through* the strides, so PFB's
//! reshape→permute→depthwise window and STFT's slice→permute framing run
//! with zero copies.  An explicit `Materialize` step (a tiled, threaded
//! gather) is inserted only where density is unavoidable:
//!
//! * a `Reshape` that merges axes a strided view cannot merge — though
//!   the fusion pass (below) eliminates the one such case the shipped
//!   lowerings produce;
//! * weight / bias / fused-elementwise operands (those kernels stream
//!   dense memory).
//!
//! # The plan-level fusion pass
//!
//! After view propagation and before liveness, `compile` rewrites
//! adjacent steps (see `plan::fuse_protos` and ARCHITECTURE.md's fusion
//! section for the full skip-rule catalog):
//!
//! * **merged-axis materialize elimination** — batched STFT's non-affine
//!   `(B, F, nfft) -> (B*F, nfft)` frame regrouping becomes a split-axis
//!   view the conv-family kernels reindex per output row, so every
//!   shipped lowering now compiles with `materialize_count() == 0` at
//!   every batch size;
//! * **window fold** — a [`crate::tina::FusionHint::Window`]-tagged M=1
//!   depthwise over a one-hot ±1 framing conv with zero bias folds into
//!   the conv by pre-scaling its taps at compile time (one conv executes
//!   instead of conv + elementwise multiply).
//!
//! Both rewrites preserve **bit-for-bit** interpreter equality; any
//! candidate whose rewrite would change a rounding is skipped.
//! [`ExecPlan::fused_steps`] / [`ExecPlan::fusion_eliminated_copies`]
//! introspect the pass and [`CompileOptions`] switches it off (the
//! fused-vs-unfused ablation).
//!
//! Plan outputs may themselves be views; the final gather copies them
//! straight into the response tensor, so terminal transposes/permutes cost
//! one copy total (the copy every execution must make anyway).  Liveness
//! is computed over *backing roots*: a view keeps its backing slot live —
//! and un-recycled — until the view's last consumer (or the output gather)
//! has run; the static verifier ([`verify`], `ExecPlan::verify`) re-proves
//! that symbolically per plan, along with bounds, shapes, reduction-order
//! certificates and fusion legality.  Arena slot sizes derive from
//! materialized extents only.
//!
//! # Oracle contract (tiling preserves rounding)
//!
//! The interpreter remains the cross-check oracle: property tests assert
//! **bit-for-bit** plan/interpreter equality on every lowering (see
//! `rust/tests/properties.rs`).  The register-tiled, weight-pre-packed
//! microkernels keep that promise by blocking over *output* coordinates
//! only — the reduction over input channels runs in the oracle's exact
//! order for every output element (see [`fused`]'s module docs).
//!
//! # Batched (bucketed) serving
//!
//! The coordinator's shape-bucketed batcher coalesces compatible fallback
//! requests into one execution at a power-of-two batch size B.  A plan
//! compiled at `(B, L)` serves such a batch through
//! [`Planned::run_rows`]/[`ExecPlan::run_rows_in`]: the schedule runs
//! once, then each real request's outputs are gathered row by row from
//! the terminal output views (leading axis = batch).  Because every
//! kernel reduces strictly within a row — blocking is over output
//! coordinates only — row i of a B-batch run is bit-identical to a solo
//! B=1 run of that row, and the bucket's zero-padding rows are never
//! gathered, so padding cannot leak into any reply (property-tested in
//! `rust/tests/properties.rs`).
//!
//! Module layout:
//! * [`plan`] — view propagation, fusion, liveness, weight packing, and
//!   step execution;
//! * [`arena`] — the reusable buffer slab;
//! * [`fused`] — stride-aware threaded kernels and the packed microkernels
//!   (same per-element accumulation order as [`crate::tina::layers`]);
//! * [`verify`] — the independent static verifier over compiled plans
//!   ("verify the artifact, don't trust the compiler"): always on in
//!   debug/test builds via [`CompileOptions::verify`], opt-in + metered
//!   in release;
//! * [`linear`] — the virtual accelerator's load-time specializer: an
//!   [`ExecPlan`] lowered once into a [`LinearProgram`] of pre-resolved
//!   kernel thunks (fixed strides/split tables, pre-sliced dense ranges,
//!   slot buffers sized at load), dispatching into the same [`fused`]
//!   kernels so output stays bit-for-bit equal to the oracle.

pub mod arena;
pub mod fused;
pub mod linear;
pub mod plan;
pub mod verify;

pub use arena::Arena;
pub use linear::LinearProgram;
pub use plan::{CompileOptions, ExecPlan};
pub use verify::VerifyError;

use crate::tensor::Tensor;
use crate::tina::graph::Graph;
use anyhow::Result;
use std::sync::Mutex;

/// Upper bound on pooled arenas per plan (beyond this, concurrent requests
/// fall back to a throwaway arena rather than growing the pool forever).
const ARENA_POOL_CAP: usize = 8;

/// A shareable compiled plan plus a pool of recycled arenas — the object
/// the router caches and the coordinator executes fallback requests on.
#[derive(Debug)]
pub struct Planned {
    plan: ExecPlan,
    arenas: Mutex<Vec<Arena>>,
}

impl Planned {
    /// Compile a graph into a planned executor with default options
    /// (fusion on; static verification on in debug/test builds).
    pub fn new(graph: &Graph) -> Result<Planned> {
        Self::new_with(graph, CompileOptions::default())
    }

    /// Compile a graph into a planned executor with explicit
    /// [`CompileOptions`] — the router uses this to control release-build
    /// plan verification (metered via `plans_verified` / `verify_ns`).
    pub fn new_with(graph: &Graph, opts: CompileOptions) -> Result<Planned> {
        Ok(Planned {
            plan: ExecPlan::compile_with(graph, opts)?,
            arenas: Mutex::new(Vec::new()),
        })
    }

    /// The compiled plan.
    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    /// Execute, borrowing an arena from the pool (allocation-free in the
    /// steady state) and returning it afterwards.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let mut arena = self.arenas.lock().unwrap().pop().unwrap_or_default();
        let result = self.plan.run_in(&mut arena, inputs);
        let mut pool = self.arenas.lock().unwrap();
        if pool.len() < ARENA_POOL_CAP {
            pool.push(arena);
        }
        result
    }

    /// Batched serving entry: execute once at the plan's (bucketed) batch
    /// size and scatter the first `rows` rows of every output into
    /// per-request tensors (leading dim 1).  Padding rows beyond `rows`
    /// are never gathered — see [`ExecPlan::run_rows_in`].
    pub fn run_rows(&self, inputs: &[Tensor], rows: usize) -> Result<Vec<Vec<Tensor>>> {
        let mut arena = self.arenas.lock().unwrap().pop().unwrap_or_default();
        let result = self.plan.run_rows_in(&mut arena, inputs, rows);
        let mut pool = self.arenas.lock().unwrap();
        if pool.len() < ARENA_POOL_CAP {
            pool.push(arena);
        }
        result
    }

    /// Arenas currently parked in the pool (tests/metrics).
    pub fn pooled_arenas(&self) -> usize {
        self.arenas.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tina::lower;

    #[test]
    fn planned_pools_arenas_across_runs() {
        let p = Planned::new(&lower::ewadd(8, 8)).unwrap();
        assert_eq!(p.pooled_arenas(), 0);
        let a = Tensor::randn(&[8, 8], 1);
        let b = Tensor::randn(&[8, 8], 2);
        p.run(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(p.pooled_arenas(), 1);
        p.run(&[a, b]).unwrap();
        assert_eq!(p.pooled_arenas(), 1, "arena must be reused, not re-added");
    }

    #[test]
    fn planned_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Planned>();
    }
}
