//! Pure-rust executor for TINA graphs: the portable fallback path and the
//! cross-check oracle for the PJRT artifacts.

use super::graph::{Graph, NodeOp, ValueId};
use super::layers;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// Executes TINA graphs on host tensors.
///
/// Stateless aside from holding the graph; `run` may be called from many
/// threads on the same interpreter ( &self ).
#[derive(Debug, Clone)]
pub struct Interpreter {
    graph: Graph,
}

impl Interpreter {
    /// Validate the graph once and wrap it.
    pub fn new(graph: Graph) -> Result<Interpreter> {
        graph.validate().context("invalid TINA graph")?;
        Ok(Interpreter { graph })
    }

    /// The validated graph being interpreted.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Execute with the given inputs; returns the graph outputs in order.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let g = &self.graph;
        if inputs.len() != g.inputs.len() {
            bail!(
                "expected {} inputs, got {}",
                g.inputs.len(),
                inputs.len()
            );
        }
        let mut values: Vec<Option<Tensor>> = vec![None; g.value_count()];
        for ((id, shape), t) in g.inputs.iter().zip(inputs) {
            if t.shape() != shape.as_slice() {
                bail!(
                    "input {id:?} shape {:?} != declared {:?}",
                    t.shape(),
                    shape
                );
            }
            values[id.0] = Some(t.clone());
        }
        let n_inputs = g.inputs.len();
        for (i, node) in g.nodes.iter().enumerate() {
            let out_id = n_inputs + i;
            let get = |v: ValueId| -> Result<&Tensor> {
                values[v.0]
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("value {v:?} not computed"))
            };
            let out = match &node.op {
                NodeOp::Constant(t) => t.clone(),
                NodeOp::Reshape(shape) => get(node.inputs[0])?.reshape(shape)?,
                NodeOp::Transpose2 => get(node.inputs[0])?.transpose2()?,
                NodeOp::Permute3(p) => get(node.inputs[0])?.permute3(*p)?,
                NodeOp::StridedSlice { axis, stride, count } => {
                    get(node.inputs[0])?.stride_axis(*axis, *stride, *count)?
                }
                NodeOp::Add => crate::tensor::add(get(node.inputs[0])?, get(node.inputs[1])?)?,
                NodeOp::Sub => crate::tensor::sub(get(node.inputs[0])?, get(node.inputs[1])?)?,
                NodeOp::DepthwiseConv1d => layers::depthwise_conv(
                    get(node.inputs[0])?,
                    get(node.inputs[1])?,
                    get(node.inputs[2])?,
                )?,
                NodeOp::StandardConv1d => layers::standard_conv(
                    get(node.inputs[0])?,
                    get(node.inputs[1])?,
                    get(node.inputs[2])?,
                )?,
                NodeOp::PointwiseConv => layers::pointwise_conv(
                    get(node.inputs[0])?,
                    get(node.inputs[1])?,
                    get(node.inputs[2])?,
                )?,
                NodeOp::FullyConnected => layers::fully_connected(
                    get(node.inputs[0])?,
                    get(node.inputs[1])?,
                    get(node.inputs[2])?,
                )?,
            };
            values[out_id] = Some(out);
        }
        g.outputs
            .iter()
            .map(|o| {
                values[o.0]
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("output {o:?} not computed"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::naive;
    use crate::dsp;
    use crate::tensor::ComplexTensor;
    use crate::tina::lower;

    fn interp(g: Graph) -> Interpreter {
        Interpreter::new(g).unwrap()
    }

    #[test]
    fn ewmult_matches_naive() {
        let a = Tensor::randn(&[5, 7], 1);
        let b = Tensor::randn(&[5, 7], 2);
        let out = interp(lower::ewmult(5, 7)).run(&[a.clone(), b.clone()]).unwrap();
        assert!(out[0].allclose(&naive::ewmult(&a, &b).unwrap(), 1e-6, 1e-6));
    }

    #[test]
    fn ewadd_matches_naive() {
        let a = Tensor::randn(&[3, 9], 3);
        let b = Tensor::randn(&[3, 9], 4);
        let out = interp(lower::ewadd(3, 9)).run(&[a.clone(), b.clone()]).unwrap();
        assert!(out[0].allclose(&naive::ewadd(&a, &b).unwrap(), 1e-6, 1e-6));
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Tensor::randn(&[6, 10], 5);
        let b = Tensor::randn(&[10, 4], 6);
        let out = interp(lower::matmul(6, 10, 4)).run(&[a.clone(), b.clone()]).unwrap();
        assert!(out[0].allclose(&naive::matmul(&a, &b).unwrap(), 1e-4, 1e-5));
    }

    #[test]
    fn summation_matches_sum() {
        let x = Tensor::randn(&[1000], 7);
        let out = interp(lower::summation(1000)).run(&[x.clone()]).unwrap();
        let want = crate::tensor::sum(&x);
        assert!((out[0].data()[0] - want).abs() < 1e-2 * want.abs().max(1.0));
    }

    #[test]
    fn dft_matches_direct() {
        let x = Tensor::randn(&[2, 16], 8);
        let out = interp(lower::dft(2, 16)).run(&[x.clone()]).unwrap();
        let want = naive::dft(&ComplexTensor::from_real(x)).unwrap();
        assert!(out[0].allclose(&want.re, 1e-4, 1e-4), "re mismatch");
        assert!(out[1].allclose(&want.im, 1e-4, 1e-4), "im mismatch");
    }

    #[test]
    fn idft_inverts_dft() {
        let x = Tensor::randn(&[1, 8], 9);
        let spec = interp(lower::dft(1, 8)).run(&[x.clone()]).unwrap();
        let back = interp(lower::idft(1, 8))
            .run(&[spec[0].clone(), spec[1].clone()])
            .unwrap();
        assert!(back[0].allclose(&x, 1e-4, 1e-4));
        assert!(back[1].allclose(&Tensor::zeros(&[1, 8]), 1e-4, 1e-4));
    }

    #[test]
    fn fir_matches_naive() {
        let taps = dsp::fir_lowpass(16, 0.2).unwrap();
        let x = Tensor::randn(&[2, 200], 10);
        let out = interp(lower::fir(2, 200, &taps).unwrap()).run(&[x.clone()]).unwrap();
        assert!(out[0].allclose(&naive::fir(&x, &taps).unwrap(), 1e-5, 1e-6));
    }

    #[test]
    fn unfold_matches_naive() {
        let x = Tensor::randn(&[1, 50], 11);
        let out = interp(lower::unfold(1, 50, 8).unwrap()).run(&[x.clone()]).unwrap();
        assert!(out[0].allclose(&naive::unfold(&x, 8).unwrap(), 0.0, 0.0));
    }

    #[test]
    fn pfb_matches_reference() {
        let cfg = dsp::PfbConfig::new(8, 4);
        let x = Tensor::randn(&[2, 8 * 32], 12);
        let out = interp(lower::pfb_fir(2, 8 * 32, cfg).unwrap())
            .run(&[x.clone()])
            .unwrap();
        let want = naive::pfb_fir(&x, cfg).unwrap();
        assert!(out[0].allclose(&want, 1e-4, 1e-5));

        let out = interp(lower::pfb(2, 8 * 32, cfg).unwrap()).run(&[x.clone()]).unwrap();
        let want = naive::pfb(&x, cfg).unwrap();
        assert!(out[0].allclose(&want.re, 1e-3, 1e-4));
        assert!(out[1].allclose(&want.im, 1e-3, 1e-4));
    }

    #[test]
    fn stft_matches_naive() {
        let x = Tensor::randn(&[2, 600], 13);
        let (nfft, hop) = (64, 32);
        let out = interp(lower::stft(2, 600, nfft, hop).unwrap())
            .run(&[x.clone()])
            .unwrap();
        let (want_re, want_im) = naive::stft(&x, nfft, hop).unwrap();
        assert!(out[0].allclose(&want_re, 1e-3, 1e-3), "re");
        assert!(out[1].allclose(&want_im, 1e-3, 1e-3), "im");
    }

    #[test]
    fn wrong_input_arity_rejected() {
        let it = interp(lower::ewmult(2, 2));
        assert!(it.run(&[Tensor::zeros(&[2, 2])]).is_err());
    }

    #[test]
    fn wrong_input_shape_rejected() {
        let it = interp(lower::ewmult(2, 2));
        assert!(it
            .run(&[Tensor::zeros(&[2, 3]), Tensor::zeros(&[2, 2])])
            .is_err());
    }
}
